"""Scheduler-overhead bench: dynamic HostScheduler vs compiled static host
plans (CI artifact: BENCH_sched.json).

Two legs, one persistent :class:`ExecutorPool` each:

1. **Decode-graph microbench** — a decode-shaped DAG (L layers of W
   parallel ops feeding a join) with ~free op fns, replayed R times through
   both runtimes.  With op cost ~0, wall time per op *is* per-op scheduling
   overhead: the dynamic path pays heap pushes, a placement decision, and
   two queue hops per op; the static plan pays a counter bump per edge and
   a queue hop only on cross-executor edges.
2. **Serve decode step** — the captured tiny-transformer decode graph
   (``jit_nodes=True``, the ContinuousEngine configuration); per-token step
   wall time, dynamic vs static, same Executable, same pool.

    PYTHONPATH=src python scripts/bench_sched_overhead.py [--out BENCH_sched.json]

A third leg benches the **simulator-guided schedule search**
(:mod:`repro.core.search`): every registered policy scored on two captured
model-family decode graphs at several executor configs; the
``schedule_search`` section of BENCH_sched.json records per-policy
simulated makespans and the winner per (family, config).

Gates (the ISSUE acceptance criteria):
  * microbench: static per-op overhead >= 1.5x lower than dynamic;
  * every measured static run is bit-identical to the sequential
    ``Graph.execute`` oracle;
  * decode step: static is no slower than dynamic;
  * schedule search: winner <= 1.0x CPF makespan on every (family,
    config); >= 1 family/config where a non-CPF policy strictly wins;
    decode outputs of the searched plan bit-exact vs the CPF baseline.
"""
import argparse
import json
import statistics
import time

from repro.core import (KNL7250, compile_host_plan, list_policies,
                        make_schedule, search_schedule)
from repro.core.engine import ExecutorPool, HostScheduler
from repro.core.static_host import layered_graph


def gate(cond, msg):
    """Acceptance gate that survives ``python -O`` (no bare asserts)."""
    if not cond:
        raise SystemExit(f"GATE FAILED: {msg}")


def bench_micro(repeats: int, n_exec: int) -> dict:
    g = layered_graph(L=24, W=4)
    oracle = g.execute({"x": 1.0})
    sched = make_schedule(g, KNL7250, n_executors=n_exec, team_size=1)
    plan = compile_host_plan(g, sched)
    n_ops = plan.n_ops
    with ExecutorPool(n_exec) as pool:
        host = HostScheduler(g, n_exec, costs=sched.op_costs, pool=pool)
        for _ in range(5):                              # warmup both paths
            host.run({"x": 1.0})
            plan.run({"x": 1.0}, pool=pool)
        dyn: list[float] = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = host.run({"x": 1.0})
            dyn.append(time.perf_counter() - t0)
        gate(res.outputs == oracle, "dynamic run diverged from the oracle")
        stat: list[float] = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = plan.run({"x": 1.0}, pool=pool)
            stat.append(time.perf_counter() - t0)
            gate(res.outputs == oracle,
                 "static run not bit-identical to Graph.execute")
    dyn_op = statistics.median(dyn) / n_ops
    stat_op = statistics.median(stat) / n_ops
    return {
        "bench": "decode_micro",
        "n_ops": n_ops,
        "n_executors": n_exec,
        "repeats": repeats,
        "dynamic_per_op_us": round(dyn_op * 1e6, 3),
        "static_per_op_us": round(stat_op * 1e6, 3),
        "overhead_ratio_x": round(dyn_op / stat_op, 3),
    }


def bench_check_overhead(repeats: int, n_exec: int) -> dict:
    """``check="strict"`` cost: host-plan build time with vs without the
    structural verifier (repro.checks S-*/P-* rules) on the bench decode
    graph.  The ISSUE gate: strict adds < 10% to plan-build time."""
    from repro.checks import check_plan, check_schedule

    g = layered_graph(L=24, W=4)

    def build():
        sched = make_schedule(g, KNL7250, n_executors=n_exec, team_size=1)
        return sched, compile_host_plan(g, sched)

    build()                                             # warm caches
    plain: list[float] = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        build()
        plain.append(time.perf_counter() - t0)
    strict: list[float] = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        sched, plan = build()
        rep = check_schedule(sched, g)
        rep.extend(check_plan(plan, g))
        rep.raise_if_errors()
        strict.append(time.perf_counter() - t0)
    p, s = statistics.median(plain), statistics.median(strict)
    return {
        "bench": "strict_check_overhead",
        "n_nodes": len(g),
        "n_executors": n_exec,
        "repeats": repeats,
        "plain_build_ms": round(p * 1e3, 3),
        "strict_build_ms": round(s * 1e3, 3),
        "overhead_pct": round((s / p - 1.0) * 100.0, 2),
    }


def bench_decode_step(steps: int) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.configs.base import get_config
    from repro.models import transformer
    from repro.serve.step import make_decode_step

    from repro.runtime import Runtime

    cfg = get_config("gemma-2b", smoke=True).reduced(vocab_size=128)
    params = transformer.init_params(cfg, jax.random.key(0))
    B, max_len = 4, 32
    cache = transformer.init_cache(cfg, B, max_len, per_slot=True)
    toks = jnp.ones((B, 1), jnp.int32)
    # the production wiring: one Runtime owns the executors, the decode
    # executable leases its calibrated width per run (admission overhead is
    # paid identically by both modes, so the ratio stays a pure
    # scheduler-overhead measurement)
    rt = Runtime()
    exe = api.compile(
        make_decode_step(cfg), params, cache, jnp.asarray(toks),
        hw=KNL7250, backend="host", jit_nodes=True, name="bench_decode",
        runtime=rt,
    )
    # profile-guided config + plan, exactly as the serve engine builds them:
    # measured per-op costs (calibrate jit-warms every node fn) drive the
    # executor-count search and the schedule the static plan freezes
    exe.calibrate(params, cache, toks)
    n_exec = min(exe.planned_executors, rt.n_workers)
    inputs = exe.captured.bind((params, cache, toks))
    walls: dict[str, list[float]] = {"dynamic": [], "static": []}
    outs = {}
    with rt:
        for mode in walls:                                      # warmup
            res = exe.execute_host(inputs, host_mode=mode)
            jax.block_until_ready(res.outputs)
        # interleave the modes so background-load drift on a shared box
        # hits both equally instead of biasing whichever ran second
        for _ in range(steps):
            for mode in walls:
                t0 = time.perf_counter()
                res = exe.execute_host(inputs, host_mode=mode)
                jax.block_until_ready(res.outputs)
                walls[mode].append(time.perf_counter() - t0)
                outs[mode] = jax.tree.leaves(
                    exe.captured.unflatten(res.outputs))
        gate(all(np.array_equal(np.asarray(a), np.asarray(b))
                 for a, b in zip(outs["static"], outs["dynamic"])),
             "decode step output diverged between static and dynamic modes")
    dyn = statistics.median(walls["dynamic"])
    stat = statistics.median(walls["static"])
    return {
        "bench": "serve_decode_step",
        "arch": cfg.name,
        "n_nodes": len(exe.graph),
        "n_ops": exe.host_plan(n_exec).n_ops,
        "n_executors": n_exec,
        "steps": steps,
        "dynamic_step_ms": round(dyn * 1e3, 3),
        "static_step_ms": round(stat * 1e3, 3),
        "speedup_x": round(dyn / stat, 3),
    }


SEARCH_FAMILIES = ("gemma-2b", "olmoe-1b-7b")
# configs narrower than the profiled best: contended widths are where the
# priority heuristic actually decides the makespan (at the profiler's wide
# optimum every policy saturates and ties)
SEARCH_CONFIGS = ((2, 8), (4, 4))


def bench_schedule_search() -> dict:
    """Score every registered policy on two captured model-family decode
    graphs; record per-policy simulated makespans + the winner, and prove
    the searched decode plan is output-bit-exact vs the CPF baseline."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.configs.base import get_config
    from repro.models import transformer
    from repro.runtime import Runtime
    from repro.serve.step import make_decode_step

    B, max_len = 4, 32
    families: dict[str, dict] = {}
    strict_wins: list[str] = []
    for arch in SEARCH_FAMILIES:
        cfg = get_config(arch, smoke=True).reduced(vocab_size=128)
        params = transformer.init_params(cfg, jax.random.key(0))
        cache = transformer.init_cache(cfg, B, max_len, per_slot=True)
        toks = jnp.ones((B, 1), jnp.int32)
        exe = api.compile(
            make_decode_step(cfg), params, cache, toks, hw=KNL7250,
            backend="sim", jit_nodes=True, schedule_search="off",
            name=f"sched_search[{arch}]",
        )
        costs = exe.profile.op_costs
        configs = []
        for n, k in SEARCH_CONFIGS:
            res = search_schedule(exe.graph, KNL7250, n_executors=n,
                                  team_size=k, costs=costs)
            gate(res.makespan_sim <= res.cpf_makespan + 1e-15,
                 f"{arch} {n}x{k}: searched winner {res.makespan_sim} "
                 f"worse than CPF {res.cpf_makespan}")
            if res.policy != "cpf" and \
                    res.makespan_sim < res.cpf_makespan * (1.0 - 1e-9):
                strict_wins.append(f"{arch}@{n}x{k}:{res.policy}")
            configs.append({
                "config": f"{n}x{k}",
                "winner": res.policy,
                "seed": res.seed,
                "winner_makespan_us": round(res.makespan_sim * 1e6, 4),
                "cpf_makespan_us": round(res.cpf_makespan * 1e6, 4),
                "gain_over_cpf_pct": round(100.0 * res.gain_over_cpf, 3),
                "runner_up_gap_pct": round(100.0 * res.runner_up_gap, 3),
                "per_policy_makespan_us": {
                    p: round(m * 1e6, 4) for p, m in res.by_policy().items()
                },
            })
        families[arch] = {"n_nodes": len(exe.graph),
                          "width": exe.graph.width(),
                          "configs": configs}
    gate(strict_wins,
         "no (family, config) where a non-CPF policy strictly beat CPF")

    # -- decode bit-exactness: searched plan vs CPF baseline ----------------
    n, k = SEARCH_CONFIGS[0]
    cfg = get_config(SEARCH_FAMILIES[0], smoke=True).reduced(vocab_size=128)
    params = transformer.init_params(cfg, jax.random.key(0))
    cache = transformer.init_cache(cfg, B, max_len, per_slot=True)
    toks = jnp.ones((B, 1), jnp.int32)
    outs = {}
    with Runtime() as rt:
        for mode in ("off", "force"):
            exe = api.compile(
                make_decode_step(cfg), params, cache, toks, hw=KNL7250,
                backend="host", jit_nodes=True, host_mode="static",
                n_executors=n, team_size=k, runtime=rt,
                schedule_search=mode, name=f"bitexact[{mode}]",
            )
            res = exe.execute_host(exe.captured.bind((params, cache, toks)))
            outs[mode] = jax.tree.leaves(exe.captured.unflatten(res.outputs))
            outs[mode] = [np.asarray(x) for x in jax.block_until_ready(outs[mode])]
    bit_exact = all(np.array_equal(a, b)
                    for a, b in zip(outs["off"], outs["force"]))
    gate(bit_exact,
         "decode outputs diverged between the searched plan and CPF")
    return {
        "bench": "schedule_search",
        "policies": list_policies(),
        "families": families,
        "strict_wins": strict_wins,
        "decode_bit_exact_vs_cpf": bit_exact,
    }


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="BENCH_sched.json")
    p.add_argument("--repeats", type=int, default=40)
    p.add_argument("--steps", type=int, default=30)
    p.add_argument("--executors", type=int, default=4)
    args = p.parse_args()

    t0 = time.time()
    micro = bench_micro(args.repeats, args.executors)
    step = bench_decode_step(args.steps)
    strict = bench_check_overhead(args.repeats, args.executors)
    search = bench_schedule_search()
    payload = {"total_wall_s": round(time.time() - t0, 2),
               "rows": [micro, step, strict],
               "schedule_search": search}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)

    print(f"{micro['bench']:18s} dyn={micro['dynamic_per_op_us']:8.2f}us/op "
          f"static={micro['static_per_op_us']:8.2f}us/op "
          f"ratio={micro['overhead_ratio_x']:.2f}x")
    print(f"{step['bench']:18s} dyn={step['dynamic_step_ms']:8.2f}ms/tok "
          f"static={step['static_step_ms']:8.2f}ms/tok "
          f"speedup={step['speedup_x']:.2f}x")
    print(f"{strict['bench']:18s} plain={strict['plain_build_ms']:8.2f}ms "
          f"strict={strict['strict_build_ms']:8.2f}ms "
          f"overhead={strict['overhead_pct']:+.1f}%")
    for arch, fam in search["families"].items():
        for c in fam["configs"]:
            print(f"schedule_search    {arch:12s} {c['config']:4s} "
                  f"winner={c['winner']}@{c['seed']} "
                  f"gain={c['gain_over_cpf_pct']:+.3f}% "
                  f"runner_up_gap={c['runner_up_gap_pct']:.3f}%")
    print(f"schedule_search    strict_wins={search['strict_wins']} "
          f"bit_exact={search['decode_bit_exact_vs_cpf']}")
    print(f"wrote {args.out} ({payload['total_wall_s']}s)")

    # ISSUE gates: static must cut per-op scheduling overhead >= 1.5x on the
    # decode-graph microbench and must not slow the real decode step down
    gate(micro["overhead_ratio_x"] >= 1.5,
         f"static per-op overhead only {micro['overhead_ratio_x']}x lower "
         f"than dynamic (need >= 1.5x)")
    # real compute dominates the decode step, so the overhead win shrinks to
    # its scheduling share; gate it as a no-regression guard with tolerance
    # for shared-runner noise (the hard >= 1.5x gate is the microbench's)
    gate(step["static_step_ms"] <= 1.1 * step["dynamic_step_ms"],
         f"static decode step {step['static_step_ms']}ms regressed vs dynamic "
         f"{step['dynamic_step_ms']}ms (> 10%)")
    gate(strict["strict_build_ms"] <= 1.1 * strict["plain_build_ms"],
         f"check=strict plan build {strict['strict_build_ms']}ms is "
         f"{strict['overhead_pct']}% over plain {strict['plain_build_ms']}ms "
         "(gate: < 10%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
