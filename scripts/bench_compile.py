"""Perf smoke for the ``repro.compile`` path (CI artifact: BENCH_compile.json).

Two legs:

1. **Paper nets** — compile each of the four Table-1 networks (small size),
   recording compile wall-clock (profile + CPF schedule), node count, best
   executor config, simulated makespan, and the speedup over the
   one-executor sequential baseline (all on the KNL cost model).
2. **Captured model** — capture a tiny transformer ``lm_loss`` into a
   graph, run it through the host runtime, and record capture wall-clock,
   host-run wall-clock vs the direct (uncompiled) call, and the numeric
   parity error.

    PYTHONPATH=src python scripts/bench_compile.py [--out BENCH_compile.json]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.configs.base import ModelConfig, ShapeSpec
from repro.core import KNL7250, sequential_makespan
from repro.models import api as model_api
from repro.models import transformer
from repro.models.paper_nets import PAPER_NETS, paper_graph
from repro.train.step import lm_loss_fn


def bench_paper_nets() -> list[dict]:
    rows = []
    for net in PAPER_NETS:
        g = paper_graph(net, "small")
        t0 = time.perf_counter()
        exe = repro.compile(g, hw=KNL7250, backend="sim")
        sched = exe.schedule                      # forces profile + schedule
        compile_s = time.perf_counter() - t0
        seq = sequential_makespan(KNL7250, g, sched.team_size)
        rows.append({
            "bench": "paper_net",
            "name": f"{net}_small",
            "n_nodes": len(g),
            "width": g.width(),
            "compile_wall_s": round(compile_s, 4),
            "n_executors": sched.n_executors,
            "team_size": sched.team_size,
            "sim_makespan_s": sched.makespan,
            "sequential_s": seq,
            "speedup_x": round(seq / sched.makespan, 3) if sched.makespan else None,
        })
    return rows


def bench_captured_loss() -> dict:
    cfg = ModelConfig(
        name="bench-tiny", family="dense", n_layers=2, d_model=64, n_heads=2,
        n_kv_heads=1, d_ff=128, vocab_size=256, act="silu",
        scan_layers=False, dtype=jnp.float32,
    )
    shape = ShapeSpec("bench", 32, 2, "train")
    params = transformer.init_params(cfg, jax.random.key(0))
    batch = model_api.make_batch(cfg, shape, jax.random.key(1))
    fn = lm_loss_fn(cfg)

    t0 = time.perf_counter()
    exe = repro.compile(fn, params, batch, backend="host")
    _ = exe.schedule
    capture_s = time.perf_counter() - t0

    ref = fn(params, batch)
    jax.block_until_ready(ref)
    t0 = time.perf_counter()
    ref = jax.block_until_ready(fn(params, batch))
    direct_s = time.perf_counter() - t0

    out = exe(params, batch)                      # warm the host path
    t0 = time.perf_counter()
    out = jax.block_until_ready(exe(params, batch))
    host_s = time.perf_counter() - t0

    return {
        "bench": "captured_lm_loss",
        "name": cfg.name,
        "n_nodes": len(exe.graph),
        "width": exe.graph.width(),
        "capture_wall_s": round(capture_s, 4),
        "host_run_wall_s": round(host_s, 4),
        "direct_call_wall_s": round(direct_s, 4),
        "executors_used": len({e.executor for e in exe.last_run.trace}),
        "host_makespan_s": exe.last_run.makespan,
        "sim_makespan_s": exe.schedule.makespan,
        "parity_abs_err": float(abs(np.asarray(out) - np.asarray(ref))),
    }


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="BENCH_compile.json")
    args = p.parse_args()

    t0 = time.time()
    rows = bench_paper_nets()
    rows.append(bench_captured_loss())
    payload = {"total_wall_s": round(time.time() - t0, 2), "rows": rows}

    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    for r in rows:
        keys = [k for k in ("compile_wall_s", "capture_wall_s", "sim_makespan_s",
                            "speedup_x", "parity_abs_err") if k in r and r[k] is not None]
        print(f"{r['bench']:16s} {r['name']:20s} n={r['n_nodes']:4d} "
              + " ".join(f"{k}={r[k]:.4g}" for k in keys))
    print(f"wrote {args.out} ({payload['total_wall_s']}s)")

    # smoke gates: parity must hold and every compile must have finished
    cap = rows[-1]
    assert cap["parity_abs_err"] < 1e-4, cap
    assert all(r["sim_makespan_s"] > 0 for r in rows)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
