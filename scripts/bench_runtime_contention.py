"""Runtime-contention bench: two concurrent graphs on one shared
``repro.Runtime`` (disjoint executor leases) vs two private pools
(CI artifact: BENCH_runtime.json).

The workload is two decode-shaped DAGs of real numpy matmuls (GIL-releasing
ops, so executor threads genuinely compute in parallel), each replayed by
its own client thread through a compiled static host plan — the serving hot
path.  Per-graph width ``W`` adapts to the machine (half the cores, floor
1) so the two legs sum to the core count instead of oversubscribing it.
Both legs get the same total executor budget:

* **dedicated** — each client owns a private ``ExecutorPool(W)`` (the
  pre-Runtime wiring: per-component pools, 2W threads total);
* **shared** — one ``Runtime(n_workers=2W)``; each client's executable
  leases ``W`` executors per run through FIFO admission, so the two plans
  run on *disjoint* subsets of one machine-sized pool.

Both legs stay alive for the whole bench and every client **alternates
dedicated/shared run by run**, so the two samples of each pair execute
under the same instantaneous background load — time-varying load on a
shared CI box (the dominant noise source, easily 3x between seconds)
cancels out of the ratio instead of deciding it.  Idle executor threads of
the out-of-phase leg cost nothing: they block on their buffer queues.  A
loaded runner can still freeze one leg's sample for hundreds of ms (VM
steal time), so a failing measurement is retried from scratch up to
``--attempts`` times: a genuine admission regression fails every attempt,
a machine-load burst does not.

    PYTHONPATH=src python scripts/bench_runtime_contention.py [--out BENCH_runtime.json]

Gates (the ISSUE acceptance criteria):
  * every run of both legs is bit-identical to the ``Graph.execute`` oracle;
  * shared-runtime p95 per-step latency <= 1.1x the dedicated-pool baseline
    for each graph (admission must cost a lock hop, not a stall).
"""
import argparse
import json
import os
import statistics
import threading
import time

import numpy as np

from repro import api
from repro.core import KNL7250, Graph
from repro.core.engine import ExecutorPool
from repro.runtime import Runtime

# executors per graph: two graphs together fill the machine, never
# oversubscribe it (both legs budget the same 2W executor threads)
W = max(1, (os.cpu_count() or 2) // 2)


def gate(cond, msg):
    """Acceptance gate that survives ``python -O`` (no bare asserts)."""
    if not cond:
        raise SystemExit(f"GATE FAILED: {msg}")


def percentile(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(len(xs) * q))]


def work_graph(name: str, L: int, width: int, n: int = 64) -> Graph:
    """Decode-shaped DAG whose ops are real numpy matmuls: ``width``
    parallel GEMMs per layer feeding a join, ``L`` layers deep.  numpy
    releases the GIL inside ``@``, so executor threads compute
    concurrently and the measured latency is dominated by work, not
    interpreter scheduling."""
    rng = np.random.default_rng(len(name))
    A = (rng.standard_normal((n, n)) * (0.5 / n)).astype(np.float64)
    g = Graph(name)
    g.add_op("x", kind="input")
    prev = "x"
    flops = 2.0 * n * n * n
    for layer in range(L):
        for w in range(width):
            g.add_op(f"l{layer}w{w}", deps=(prev,), flops=flops,
                     fn=lambda v, w=w, A=A: (v + w) @ A)
        g.add_op(f"j{layer}", deps=tuple(f"l{layer}w{w}" for w in range(width)),
                 flops=flops, fn=lambda *xs, A=A: sum(xs) @ A)
        prev = f"j{layer}"
    g.add_op("out", deps=(prev,), flops=n * n, fn=lambda v: np.tanh(v))
    return g


def _client(exes_by_leg, oracle, repeats, out_by_leg):
    """One graph's serving client: each iteration runs the step once per
    leg, back to back, so both legs sample the same load window.  The leg
    order flips every iteration — neither leg systematically runs first
    into a load ramp."""
    legs = list(exes_by_leg)
    for k in range(repeats):
        x, want = oracle[k % 7]
        for leg in (legs if k % 2 == 0 else legs[::-1]):
            t0 = time.perf_counter()
            res = exes_by_leg[leg].execute_host({"x": x})
            out_by_leg[leg].append(time.perf_counter() - t0)
            gate(np.array_equal(res.outputs["out"], want),
                 f"{exes_by_leg[leg].graph.name}[{leg}]: run diverged "
                 "from Graph.execute")


def run_pass(exes, graphs, oracles, repeats):
    """Replay both graphs concurrently, legs interleaved run-by-run;
    returns per-graph {leg: samples}."""
    samples = [{leg: [] for leg in exes} for _ in graphs]
    ths = [
        threading.Thread(
            target=_client,
            args=({leg: exes[leg][i] for leg in exes}, oracles[i],
                  repeats, samples[i]))
        for i in range(len(graphs))
    ]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    return samples


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="BENCH_runtime.json")
    p.add_argument("--repeats", type=int, default=120,
                   help="runs per graph per pass")
    p.add_argument("--passes", type=int, default=5,
                   help="measurement passes (samples pool across them)")
    p.add_argument("--attempts", type=int, default=3,
                   help="full-measurement retries before the gate fails")
    args = p.parse_args()

    n = 96      # per-op GEMM size: real work dominates the run, scheduling
    #             overhead and OS jitter are a small fraction of it
    graphs = [work_graph("decode_a", L=6, width=max(2, W), n=n),
              work_graph("decode_b", L=4, width=max(2, W), n=n)]
    rng = np.random.default_rng(7)
    oracles = []
    for g in graphs:
        xs = [rng.standard_normal((n, n)) for _ in range(7)]
        oracles.append({k: (x, g.execute({"x": x})["out"])
                        for k, x in enumerate(xs)})

    def dedicated():
        pools = [ExecutorPool(W) for _ in graphs]
        exes = [
            api.compile(g, hw=KNL7250, backend="host", host_mode="static",
                        n_executors=W, team_size=1, pool=pool)
            for g, pool in zip(graphs, pools)
        ]
        return exes, lambda: [pool.close() for pool in pools]

    def shared():
        rt = Runtime(n_workers=2 * W)
        exes = [
            rt.compile(g, backend="host", host_mode="static",
                       n_executors=W, team_size=1)
            for g in graphs
        ]
        return exes, rt.close

    def measure():
        ded_exes, ded_cleanup = dedicated()
        sh_exes, sh_cleanup = shared()
        exes = {"dedicated": ded_exes, "shared": sh_exes}
        try:
            for leg in exes:                          # warm plans + executors
                for i, exe in enumerate(exes[leg]):
                    exe.execute_host({"x": oracles[i][0][0]})
            samples = [{leg: [] for leg in exes} for _ in graphs]
            for _pass in range(args.passes):
                got = run_pass(exes, graphs, oracles, args.repeats)
                for i in range(len(graphs)):
                    for leg in exes:
                        samples[i][leg].extend(got[i][leg])
        finally:
            ded_cleanup()
            sh_cleanup()
        rows = []
        for i, g in enumerate(graphs):
            row = {"bench": "runtime_contention", "graph": g.name,
                   "n_ops": len(g) - 1, "width_per_graph": W,
                   "runs_per_leg": args.passes * args.repeats}
            for leg in exes:
                xs = samples[i][leg]
                row[f"{leg}_p50_ms"] = round(statistics.median(xs) * 1e3, 4)
                row[f"{leg}_p95_ms"] = round(percentile(xs, 0.95) * 1e3, 4)
            row["p95_ratio_x"] = round(
                row["shared_p95_ms"] / row["dedicated_p95_ms"], 3)
            rows.append(row)
        return rows

    t0 = time.time()
    attempts = []
    for attempt in range(max(1, args.attempts)):
        rows = measure()
        attempts.append(rows)
        for r in rows:
            print(f"[attempt {attempt + 1}] {r['graph']:10s} "
                  f"dedicated p95={r['dedicated_p95_ms']:8.3f}ms "
                  f"shared p95={r['shared_p95_ms']:8.3f}ms "
                  f"ratio={r['p95_ratio_x']:.2f}x")
        if all(r["p95_ratio_x"] <= 1.1 for r in rows):
            break

    payload = {"total_wall_s": round(time.time() - t0, 2),
               "total_executors_per_leg": 2 * W,
               "attempts": len(attempts), "rows": rows}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out} ({payload['total_wall_s']}s, "
          f"{len(attempts)} attempt(s))")

    # ISSUE gate: leasing from one shared Runtime must not cost more than
    # 10% p95 step latency over per-component dedicated pools.  Gated on
    # the last attempt: a load burst fails one measurement, a genuine
    # admission regression fails them all.
    for r in rows:
        gate(r["p95_ratio_x"] <= 1.1,
             f"{r['graph']}: shared-Runtime p95 {r['shared_p95_ms']}ms > "
             f"1.1x dedicated {r['dedicated_p95_ms']}ms in every one of "
             f"{len(attempts)} attempts")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
