"""Spike: verify the 512-host-device dry-run machinery works on CPU.

Checks:
  1. XLA_FLAGS host device count 512 -> jax sees 512 CpuDevices.
  2. make_mesh((16,16)) and ((2,16,16)) work.
  3. jit().lower(ShapeDtypeStruct).compile() with NamedSharding succeeds.
  4. compiled.cost_analysis() / memory_analysis() / as_text() contents.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

print("devices:", len(jax.devices()))

mesh = jax.make_mesh((16, 16), ("data", "model"))
print("mesh:", mesh)

D, F = 1024, 4096


def train_step(params, batch):
    w1, w2 = params
    x = batch["x"]

    def loss_fn(w1, w2):
        h = jnp.einsum("bd,df->bf", x, w1)
        h = jax.nn.relu(h)
        y = jnp.einsum("bf,fd->bd", h, w2)
        return jnp.mean((y - x) ** 2)

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(w1, w2)
    new = (w1 - 1e-3 * grads[0], w2 - 1e-3 * grads[1])
    return new, loss


x_spec = jax.ShapeDtypeStruct((256, D), jnp.bfloat16)
w1_spec = jax.ShapeDtypeStruct((D, F), jnp.bfloat16)
w2_spec = jax.ShapeDtypeStruct((F, D), jnp.bfloat16)

w1_sh = NamedSharding(mesh, P(None, "model"))
w2_sh = NamedSharding(mesh, P("model", None))
x_sh = NamedSharding(mesh, P("data", None))

jitted = jax.jit(
    train_step,
    in_shardings=((w1_sh, w2_sh), {"x": x_sh}),
    out_shardings=((w1_sh, w2_sh), NamedSharding(mesh, P())),
)

import time

t0 = time.time()
lowered = jitted.lower((w1_spec, w2_spec), {"x": x_spec})
t1 = time.time()
print(f"lower time: {t1-t0:.2f}s")
compiled = lowered.compile()
t2 = time.time()
print(f"compile time: {t2-t1:.2f}s")

print("=== cost_analysis ===")
ca = compiled.cost_analysis()
if isinstance(ca, list):
    ca = ca[0]
for k in sorted(ca):
    if "flops" in k or "bytes" in k or "utilization" not in k:
        print(f"  {k}: {ca[k]}")
        if len(str(k)) > 60:
            break

print("=== memory_analysis ===")
try:
    ma = compiled.memory_analysis()
    print(ma)
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        print(" ", attr, getattr(ma, attr, None))
except Exception as e:
    print("memory_analysis failed:", e)

print("=== as_text collectives ===")
txt = compiled.as_text()
import re
colls = [ln.strip()[:200] for ln in txt.splitlines()
         if re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", ln)]
print(f"{len(colls)} collective lines; first 5:")
for c in colls[:5]:
    print(" ", c)

# multi-pod mesh
mesh3 = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
print("multi-pod mesh ok:", mesh3.shape)
x_sh3 = NamedSharding(mesh3, P(("pod", "data"), None))
w1_sh3 = NamedSharding(mesh3, P(None, "model"))
w2_sh3 = NamedSharding(mesh3, P("model", None))
jit3 = jax.jit(train_step, in_shardings=((w1_sh3, w2_sh3), {"x": x_sh3}),
               out_shardings=((w1_sh3, w2_sh3), NamedSharding(mesh3, P())))
t0 = time.time()
c3 = jit3.lower((w1_spec, w2_spec), {"x": x_spec}).compile()
print(f"multi-pod compile ok in {time.time()-t0:.2f}s")
ca3 = c3.cost_analysis()
if isinstance(ca3, list):
    ca3 = ca3[0]
print("multi-pod flops:", ca3.get("flops"))
print("SPIKE OK")
