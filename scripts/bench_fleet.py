"""Fleet bench: replica scaling, burst tails, shedding, recovery (BENCH_fleet.json).

Four experiments against the supervised fleet tier:

1. **Scaling** — the same Poisson workload at 1..4 replicas; reports
   tokens/s per replica count.  Workers run the deterministic toy engine
   whose per-token cost is a *service-time sleep* (it releases the core),
   so throughput measures the fleet tier itself — router, supervisor loop,
   pipe transport — and legitimately scales on boxes with fewer cores than
   replicas.  ``--real`` swaps in real graphi-scheduled engines (needs
   cores to actually scale; not the CI default).
2. **Burst tail** — steady arrivals with a 4x burst in the middle; p50/p99
   per-request latency across the fleet.
3. **Recovery** — SIGKILL one of 4 replicas mid-decode; reports time from
   failure detection to the first replayed token, plus a bit-exactness
   check of every stream against the pure-function reference.
4. **Shedding** — offered load at 2x a single replica's capacity with a
   small admission cap: accepted-request p99 must stay bounded (the
   in-runtime analogue is ``Runtime.lease(shed_after_s=...)``).

    PYTHONPATH=src python scripts/bench_fleet.py [--out BENCH_fleet.json]

Smoke gates (ISSUE 9 acceptance criteria):
  * 4-replica tokens/s >= 3x 1-replica tokens/s (toy/service-time mode);
  * kill drill: zero lost requests, every stream bit-identical;
  * p99 recovery gap < 10x steady-state p50 step gap;
  * shed run: accepted p99 <= 2x the unshed burst p99 bound.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.fleet import Fleet, FleetConfig, FaultInjector, FaultSpec
from repro.fleet.worker import toy_next_token

VOCAB = 211
SERVICE_S = 0.004        # per decode step per replica (sleep — releases core)


def gate(cond, msg):
    """Acceptance gate that survives ``python -O`` (no bare asserts)."""
    if not cond:
        raise SystemExit(f"GATE FAILED: {msg}")


def fleet_cfg(n_workers: int, *, real: bool, arch: str,
              max_inflight: int = 4) -> FleetConfig:
    if real:
        engine = {"kind": "continuous", "arch": arch, "smoke": True,
                  "reduced_vocab": VOCAB, "max_batch": max_inflight,
                  "calibration_store": "/tmp/fleet_calib.json"}
    else:
        engine = {"kind": "toy", "vocab_size": VOCAB,
                  "service_time_s": SERVICE_S}
    # real engines jit-compile on their first post-ready steps, and
    # heartbeats ride the serve loop: the liveness window must cover a
    # compile-length step (see launch/serve.serve_fleet)
    return FleetConfig(n_workers=n_workers, engine=engine,
                       heartbeat_s=0.5 if real else 0.05,
                       liveness_s=120.0 if real else None,
                       startup_grace_s=300.0 if real else 30.0,
                       max_inflight_per_worker=max_inflight)


def workload(n_requests: int, *, rate: float, max_new: int, seed: int = 0):
    """(arrival_time, prompt, max_new) with Poisson arrivals (rate=0: t=0)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        plen = int(rng.integers(4, 12))
        prompt = [int(x) for x in rng.integers(1, VOCAB, size=plen)]
        out.append((t, prompt, max_new))
    return out


def drive(fleet: Fleet, arrivals, *, injector=None, timeout_s=180.0):
    """Feed arrivals at their times; returns (done, latency_by_rid, wall,
    token_times).  Latency = completion - arrival."""
    fleet.wait_ready()
    t0 = time.monotonic()
    todo = list(arrivals)
    arrive: dict[int, float] = {}
    finish: dict[int, float] = {}
    token_times: list[float] = []
    fleet.on_token = lambda rid, tok, idx: token_times.append(
        time.monotonic() - t0)
    submitted = []
    deadline = t0 + timeout_s
    while todo or fleet.has_work:
        if time.monotonic() > deadline:
            raise SystemExit(f"bench drive timed out after {timeout_s}s")
        now = time.monotonic() - t0
        while todo and todo[0][0] <= now:
            t, prompt, max_new = todo.pop(0)
            rid = fleet.submit(prompt, max_new)
            arrive[rid] = t
            submitted.append(rid)
        fleet.pump()
        if injector is not None:
            injector.tick(fleet)
        for req in fleet.completed:
            if req.rid not in finish:
                finish[req.rid] = time.monotonic() - t0
    done = sorted(fleet.completed, key=lambda r: r._order)
    fleet.completed = []
    for req in done:
        finish.setdefault(req.rid, time.monotonic() - t0)
    lat = {rid: finish[rid] - arrive[rid] for rid in finish}
    return done, lat, time.monotonic() - t0, token_times


def percentile(xs, q: float) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(len(xs) * q))] if xs else 0.0


def check_streams(done) -> None:
    for r in done:
        ref = []
        for _ in range(r.max_new):
            ref.append(toy_next_token(r.prompt, ref, VOCAB, seed=0))
        gate(list(r.tokens) == ref,
             f"request {r.rid} stream diverged from reference "
             f"(requeues={r.n_requeues})")
        gate(len(r.tokens) == r.max_new,
             f"request {r.rid} truncated: {len(r.tokens)}/{r.max_new}")


def bench_scaling(args) -> dict:
    out = {}
    work = workload(args.requests, rate=0.0, max_new=args.max_new)
    for n in (1, 2, 4):
        with Fleet(fleet_cfg(n, real=args.real, arch=args.arch)) as fleet:
            done, lat, wall, _ = drive(fleet, work)
        toks = sum(len(r.tokens) for r in done)
        if not args.real:
            check_streams(done)
        out[str(n)] = {"tokens_per_s": toks / wall, "wall_s": wall,
                       "n_done": len(done)}
        print(f"scaling: {n} replica(s): {toks / wall:.0f} tok/s "
              f"({len(done)} requests, {wall:.2f}s)")
    return out


def bench_burst(args) -> dict:
    steady = workload(args.requests, rate=args.rate, max_new=args.max_new)
    burst_at = steady[len(steady) // 2][0]
    burst = [(burst_at, p, m) for _, p, m in
             workload(args.requests // 2, rate=0.0, max_new=args.max_new,
                      seed=7)]
    work = sorted(steady + burst, key=lambda x: x[0])
    with Fleet(fleet_cfg(4, real=args.real, arch=args.arch)) as fleet:
        done, lat, wall, _ = drive(fleet, work)
    if not args.real:
        check_streams(done)
    res = {"p50_s": percentile(list(lat.values()), 0.50),
           "p99_s": percentile(list(lat.values()), 0.99),
           "n_done": len(done), "wall_s": wall}
    print(f"burst: p50={res['p50_s'] * 1e3:.0f}ms p99={res['p99_s'] * 1e3:.0f}ms "
          f"({len(done)} requests)")
    return res


def bench_recovery(args) -> dict:
    work = workload(args.requests, rate=args.rate, max_new=args.max_new)
    with Fleet(fleet_cfg(4, real=args.real, arch=args.arch)) as fleet:
        inj = FaultInjector(
            [FaultSpec(kind="kill", at_tokens=args.requests * args.max_new // 4)],
            seed=args.seed)
        done, lat, wall, token_times = drive(fleet, work, injector=inj)
        stats = fleet.stats()
        events = list(fleet.events)
    gate(len(done) == len(work), f"lost requests: {len(done)}/{len(work)}")
    if not args.real:
        check_streams(done)
    gate(stats["n_failovers"] >= 1, "kill fault never fired")
    # recovery gap: largest inter-token silence around the failure vs the
    # steady-state p50 inter-token gap
    fail_t = next(t for t, kind, _, _ in events if kind == "fail")
    gaps = np.diff(token_times)
    steady_p50 = float(np.median(gaps)) if len(gaps) else 0.0
    after = [t for t in token_times if t >= fail_t]
    recovery = (after[0] - fail_t) if after else 0.0
    res = {"recovery_s": recovery, "steady_p50_gap_s": steady_p50,
           "n_requeued": stats["n_requeued"],
           "n_failovers": stats["n_failovers"], "faults": inj.log}
    print(f"recovery: {recovery * 1e3:.0f}ms to first replayed token "
          f"(steady p50 gap {steady_p50 * 1e3:.1f}ms, "
          f"requeued {stats['n_requeued']})")
    return res


def bench_shed(args) -> dict:
    """2x-overload: a single replica with a tiny admission cap; offered
    load outruns it, the queue backs up, and the supervisor-side cap keeps
    accepted-request latency bounded by rejecting the excess up front."""
    cap = 8
    # one replica drains max_inflight requests concurrently, one token per
    # service tick: capacity = 4 / (SERVICE_S * max_new) requests/s
    rate = 2.0 * 4 / (SERVICE_S * args.max_new)
    work = workload(args.requests * 2, rate=rate, max_new=args.max_new,
                    seed=3)
    accepted_lat, rejected = [], 0
    with Fleet(fleet_cfg(1, real=False, arch=args.arch,
                         max_inflight=4)) as fleet:
        fleet.wait_ready()
        t0 = time.monotonic()
        todo = list(work)
        arrive: dict[int, float] = {}
        finish: dict[int, float] = {}
        while todo or fleet.has_work:
            now = time.monotonic() - t0
            while todo and todo[0][0] <= now:
                t, prompt, max_new = todo.pop(0)
                backlog = len(fleet._pending) + sum(
                    len(w.inflight) for w in fleet._workers.values())
                if backlog >= cap:
                    rejected += 1            # 429: retry elsewhere/later
                    continue
                arrive[fleet.submit(prompt, max_new)] = t
            fleet.pump()
            for req in fleet.completed:
                finish.setdefault(req.rid, time.monotonic() - t0)
    accepted_lat = [finish[r] - arrive[r] for r in finish]
    res = {"accepted": len(accepted_lat), "rejected": rejected,
           "p50_s": percentile(accepted_lat, 0.50),
           "p99_s": percentile(accepted_lat, 0.99)}
    print(f"shed: accepted={res['accepted']} rejected={rejected} "
          f"p99={res['p99_s'] * 1e3:.0f}ms")
    return res


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="BENCH_fleet.json")
    # 32 = full waves at every replica count (1 rep: 8 waves of 4; 4 reps:
    # 2 waves of 16), so the ideal scaling ratio is 4.0x, not quantized down
    p.add_argument("--requests", type=int, default=32)
    p.add_argument("--max-new", type=int, default=24)
    p.add_argument("--rate", type=float, default=60.0,
                   help="steady Poisson arrival rate (requests/s)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--real", action="store_true",
                   help="real graphi engines instead of the toy "
                        "(service-time) worker — needs cores to scale")
    p.add_argument("--arch", default="gemma-2b")
    args = p.parse_args()

    results = {
        "mode": "real" if args.real else "toy-service-time",
        "scaling": bench_scaling(args),
        "burst": bench_burst(args),
        "recovery": bench_recovery(args),
        "shed": bench_shed(args),
    }

    sc = results["scaling"]
    speedup = sc["4"]["tokens_per_s"] / max(sc["1"]["tokens_per_s"], 1e-9)
    results["speedup_4v1"] = speedup
    if not args.real:
        gate(speedup >= 3.0, f"4-replica speedup {speedup:.2f}x < 3x")
        rec = results["recovery"]
        gate(rec["recovery_s"] < 10 * max(rec["steady_p50_gap_s"], 0.05),
             f"recovery {rec['recovery_s']:.3f}s >= 10x steady p50 gap")
        gate(results["shed"]["p99_s"] <= 2 * results["burst"]["p99_s"]
             + 10 * SERVICE_S * args.max_new,
             "shed p99 unbounded despite admission cap")
    else:
        print("note: --real mode skips the scaling/recovery gates "
              "(core-bound, machine-dependent)")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"4v1 speedup {speedup:.2f}x -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
