"""Format EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSON.

    PYTHONPATH=src python scripts/make_roofline_table.py results/dryrun_full.json
"""
import json
import sys


def fmt_bytes(b):
    return f"{b/1e9:.1f}"


def main(path: str) -> None:
    with open(path) as f:
        recs = json.load(f)

    pod = [r for r in recs if "pod=" not in r["mesh"]]
    multi = [r for r in recs if "pod=" in r["mesh"]]

    print("### §Dry-run — single pod (16x16 = 256 chips)\n")
    print("| arch | shape | kind | mb | fsdp | GB/dev raw | GB/dev bf16-est | fits | compile s |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in pod:
        if r["status"] == "skip":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | SKIP | — |")
        elif r["status"] == "fail":
            print(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | |")
        else:
            fit = "yes" if r["fits_hbm_bf16_est"] else "**NO**"
            print(f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['microbatches']} "
                  f"| {'y' if r.get('fsdp') else 'n'} | {fmt_bytes(r['bytes_per_device'])} "
                  f"| {fmt_bytes(r['bytes_per_device_bf16_est'])} | {fit} | {r['compile_s']} |")

    print("\n### §Dry-run — multi-pod (2x16x16 = 512 chips): compile proof\n")
    ok = sum(1 for r in multi if r["status"] == "ok")
    sk = sum(1 for r in multi if r["status"] == "skip")
    fl = [r for r in multi if r["status"] == "fail"]
    print(f"{ok} compiled OK, {sk} skipped (long_500k x full-attention), {len(fl)} failed.")
    for r in fl:
        print(f"- FAIL {r['arch']}/{r['shape']}: {r['error'][:200]}")

    print("\n### §Roofline — per-device terms (v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant | useful (6ND/HLO) | roofline frac | MFU bound |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in pod:
        if r["status"] != "ok" or "roofline" not in r:
            continue
        rf = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4g} | {rf['memory_s']:.4g} "
              f"| {rf['collective_s']:.4g} | {rf['dominant']} | {rf['useful_ratio']:.3f} "
              f"| {rf['roofline_fraction']:.3f} | {rf['mfu_bound']:.3f} |")

    # dominant-term census + hillclimb candidates
    doms = {}
    worst = []
    for r in pod:
        if r["status"] == "ok" and "roofline" in r:
            rf = r["roofline"]
            doms[rf["dominant"]] = doms.get(rf["dominant"], 0) + 1
            worst.append((rf["roofline_fraction"], rf["collective_s"] / max(1e-30, max(rf["compute_s"], rf["memory_s"])), r["arch"], r["shape"]))
    print(f"\ndominant-term census: {doms}")
    worst.sort()
    print("lowest roofline fraction (compute/max-term):")
    for frac, collr, a, s in worst[:5]:
        print(f"  {a}/{s}: frac={frac:.3f} coll-ratio={collr:.2f}")
    worst.sort(key=lambda t: -t[1])
    print("most collective-bound:")
    for frac, collr, a, s in worst[:5]:
        print(f"  {a}/{s}: coll/max-other={collr:.2f} frac={frac:.3f}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_full.json")
