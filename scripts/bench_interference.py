"""Interference bench: measured co-location slowdowns, the contention model
they fit, and contention-aware placement quality (CI artifact:
BENCH_interference.json).

Four legs:

1. **Co-location** (:mod:`repro.hwperf.colocate`) — op-class workload pairs
   run concurrently on pinned disjoint core sets vs unpinned vs solo; the
   measured slowdown matrix is the real axis of the paper's Fig 3.
2. **Contention model** — fit a :class:`~repro.hwperf.model.ContentionModel`
   from the pinned matrix, persist it into a format-3 calibration store,
   and check sim-vs-measured makespan ordering on captured decode graphs.
3. **Placement** — the ``cpf-contention`` policy vs plain CPF: simulated
   makespan under the measured contention model on two model families at
   two executor configs, plus measured decode-step wall time.
4. **Pinned decode** — decode outputs bit-exact with executor pinning on
   vs off (pinning moves threads, never numerics).

    PYTHONPATH=src python scripts/bench_interference.py [--smoke] \
        [--out BENCH_interference.json]

Degraded mode: on a box where pinning cannot take (no ``sched_setaffinity``,
``REPRO_HWPERF_NO_AFFINITY`` set, restricted cpuset, or < 2 usable CPUs)
the hardware gates are skipped — a 1-CPU container cannot exhibit pinned
vs unpinned separation — and the run records ``degraded: true``.  The
simulator-side and bit-exactness gates always apply.
"""
import argparse
import json
import statistics
import time

from repro.core import KNL7250, SimConfig, simulate
from repro.hwperf import (ContentionModel, affinity_supported,
                          default_workloads, detect_topology,
                          install_contention_policy, measure_interference)
from repro.core.policies import unregister_policy

# declared bound for the pinned co-location gate: co-scheduled per-op p95
# may cost at most this much over solo on disjoint pinned core sets
# (shared LLC/DRAM still contend; execution ports must not)
PINNED_P95_BOUND = 3.0

FAMILIES = ("gemma-2b", "olmoe-1b-7b")
CONFIGS = ((2, 8), (4, 4))


def gate(cond, msg):
    """Acceptance gate that survives ``python -O`` (no bare asserts)."""
    if not cond:
        raise SystemExit(f"GATE FAILED: {msg}")


def p95(xs):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(0.95 * len(xs)))] if xs else 0.0


def bench_colocate(topo, *, iters: int, repeats: int) -> tuple[dict, ContentionModel, bool]:
    """Leg 1: pinned vs unpinned co-location slowdowns."""
    wls = default_workloads(scale=96 if iters <= 4 else 192)
    pinned_m = measure_interference(wls, topo, iters=iters, repeats=repeats,
                                    pinned=True)
    unpinned_m = measure_interference(wls, topo, iters=iters, repeats=repeats,
                                      pinned=False)
    degraded = (not affinity_supported() or topo.n_cpus < 2
                or not pinned_m.pinned or not pinned_m.disjoint)
    pin_slow = [pinned_m.slowdown(a, b)
                for a in pinned_m.classes() for b in pinned_m.classes()]
    unpin_slow = [unpinned_m.slowdown(a, b)
                  for a in unpinned_m.classes() for b in unpinned_m.classes()]
    row = {
        "bench": "colocation",
        "topology": topo.describe(),
        "pinned": pinned_m.pinned,
        "disjoint": pinned_m.disjoint,
        "degraded": degraded,
        "solo_us": {k: round(v * 1e6, 2) for k, v in pinned_m.solo.items()},
        "pinned_slowdown": {
            f"{a}|{b}": round(pinned_m.slowdown(a, b), 3)
            for a in pinned_m.classes() for b in pinned_m.classes()},
        "unpinned_slowdown": {
            f"{a}|{b}": round(unpinned_m.slowdown(a, b), 3)
            for a in unpinned_m.classes() for b in unpinned_m.classes()},
        "pinned_p95_x": round(p95(pin_slow), 3),
        "unpinned_p95_x": round(p95(unpin_slow), 3),
        "bound_x": PINNED_P95_BOUND,
    }
    model = ContentionModel.from_matrix(pinned_m)
    if not degraded:
        gate(row["pinned_p95_x"] <= PINNED_P95_BOUND,
             f"pinned co-scheduled p95 {row['pinned_p95_x']}x over solo "
             f"exceeds the declared bound {PINNED_P95_BOUND}x")
        gate(row["pinned_p95_x"] < row["unpinned_p95_x"],
             f"pinned co-location p95 {row['pinned_p95_x']}x not better "
             f"than the unpinned leg {row['unpinned_p95_x']}x")
    return row, model, degraded


def _decode_exe(arch: str, *, backend: str, runtime=None, policy="cpf",
                n=None, k=None):
    import jax
    import jax.numpy as jnp

    from repro import api
    from repro.configs.base import get_config
    from repro.models import transformer
    from repro.serve.step import make_decode_step

    cfg = get_config(arch, smoke=True).reduced(vocab_size=128)
    params = transformer.init_params(cfg, jax.random.key(0))
    cache = transformer.init_cache(cfg, 4, 32, per_slot=True)
    toks = jnp.ones((4, 1), jnp.int32)
    exe = api.compile(
        make_decode_step(cfg), params, cache, toks, hw=KNL7250,
        backend=backend, jit_nodes=True, schedule_search="off",
        policy=policy, n_executors=n, team_size=k, runtime=runtime,
        name=f"interf[{arch}:{policy}]",
    )
    return exe, (params, cache, toks)


def bench_placement(model: ContentionModel, degraded: bool,
                    *, steps: int) -> dict:
    """Legs 2+3: cpf-contention never worsens simulated makespan vs CPF
    under the measured model; measured decode step time cpf vs contention;
    sim-vs-measured ordering across configs."""
    import time as _t

    import jax

    from repro.runtime import Runtime

    install_contention_policy(model)
    fams: dict[str, dict] = {}
    sim_points: list[float] = []
    meas_points: list[float] = []
    try:
        for arch in FAMILIES:
            exe, _ = _decode_exe(arch, backend="sim")
            costs = exe.profile.op_costs
            rows = []
            for n, k in CONFIGS:
                base = simulate(exe.graph, KNL7250,
                                SimConfig(n_executors=n, team_size=k,
                                          policy="cpf", contention=model),
                                costs=costs)
                aware = simulate(exe.graph, KNL7250,
                                 SimConfig(n_executors=n, team_size=k,
                                           policy="cpf-contention",
                                           contention=model),
                                 costs=costs)
                gate(aware.makespan <= base.makespan * (1.0 + 1e-9),
                     f"{arch} {n}x{k}: cpf-contention makespan "
                     f"{aware.makespan:.3e}s worse than CPF "
                     f"{base.makespan:.3e}s under the measured model")
                rows.append({
                    "config": f"{n}x{k}",
                    "cpf_makespan_us": round(base.makespan * 1e6, 3),
                    "contention_makespan_us": round(aware.makespan * 1e6, 3),
                    "gain_pct": round(
                        100.0 * (1.0 - aware.makespan / base.makespan), 4),
                })
                sim_points.append(base.makespan)
            fams[arch] = {"n_nodes": len(exe.graph), "configs": rows}

        # measured decode step: cpf vs cpf-contention placement, same
        # runtime, interleaved so load drift hits both legs equally
        step_rows = []
        for arch in FAMILIES:
            walls = {"cpf": [], "cpf-contention": []}
            with Runtime() as rt:
                exes = {}
                for pol in walls:
                    exe, args = _decode_exe(arch, backend="host", runtime=rt,
                                            policy=pol, n=2, k=8)
                    inputs = exe.captured.bind(args)
                    exes[pol] = (exe, inputs)
                    res = exe.execute_host(inputs, host_mode="static")
                    jax.block_until_ready(res.outputs)       # warm + compile
                for _ in range(steps):
                    for pol, (exe, inputs) in exes.items():
                        t0 = _t.perf_counter()
                        res = exe.execute_host(inputs, host_mode="static")
                        jax.block_until_ready(res.outputs)
                        walls[pol].append(_t.perf_counter() - t0)
            cpf = statistics.median(walls["cpf"])
            aware = statistics.median(walls["cpf-contention"])
            meas_points.append(cpf)
            step_rows.append({
                "arch": arch,
                "cpf_step_ms": round(cpf * 1e3, 3),
                "contention_step_ms": round(aware * 1e3, 3),
                "improvement_pct": round(100.0 * (1.0 - aware / cpf), 2),
            })
            if not degraded:
                # multi-core runner: contention-aware placement must not
                # regress the measured step (5% noise floor for shared CI)
                gate(aware <= cpf * 1.05,
                     f"{arch}: cpf-contention measured step {aware * 1e3:.2f}"
                     f"ms regressed vs CPF {cpf * 1e3:.2f}ms (> 5%)")

        # sim-vs-measured ordering: across (family at 2x8), the graph the
        # simulator says is slower must measure slower (rank agreement)
        sim_rank = sorted(range(len(FAMILIES)),
                          key=lambda i: sim_points[i * len(CONFIGS)])
        meas_rank = sorted(range(len(FAMILIES)), key=lambda i: meas_points[i])
        rank_agree = sim_rank == meas_rank
        if not degraded:
            gate(rank_agree,
                 f"sim-vs-measured makespan ordering disagrees: sim {sim_rank} "
                 f"vs measured {meas_rank}")
    finally:
        unregister_policy("cpf-contention")
    return {
        "bench": "placement",
        "hot_classes": sorted(model.hot_classes()),
        "families": fams,
        "measured_steps": step_rows,
        "sim_vs_measured_rank_agree": rank_agree,
    }


def bench_pinned_decode(degraded: bool) -> dict:
    """Leg 4 (always gated): decode outputs bit-exact, pinning on vs off."""
    import jax
    import numpy as np

    from repro.runtime import Runtime

    outs = {}
    for mode in ("off", "on"):
        with Runtime(pinning=mode) as rt:
            exe, args = _decode_exe(FAMILIES[0], backend="host", runtime=rt,
                                    n=2, k=8)
            res = exe.execute_host(exe.captured.bind(args),
                                   host_mode="static")
            leaves = jax.tree.leaves(exe.captured.unflatten(res.outputs))
            outs[mode] = [np.asarray(x) for x in jax.block_until_ready(leaves)]
            applied = rt.pinning_applied
    bit_exact = all(np.array_equal(a, b)
                    for a, b in zip(outs["off"], outs["on"]))
    gate(bit_exact, "decode outputs diverged with pinning on vs off")
    return {
        "bench": "pinned_decode",
        "bit_exact": bit_exact,
        "pinning_took": bool(applied and applied.pinned),
        "degraded": degraded,
    }


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="BENCH_interference.json")
    p.add_argument("--smoke", action="store_true",
                   help="tiny iteration counts (CI smoke legs)")
    p.add_argument("--calibration-store", default=None,
                   help="persist the measured contention model into this "
                        "format-3 calibration store")
    args = p.parse_args()
    iters = 3 if args.smoke else 12
    repeats = 2 if args.smoke else 5
    steps = 3 if args.smoke else 15

    t0 = time.time()
    topo = detect_topology()
    coloc, model, degraded = bench_colocate(topo, iters=iters, repeats=repeats)
    if args.calibration_store:
        from repro.runtime import CalibrationStore

        CalibrationStore(args.calibration_store).put_interference(
            model.to_dict())
    placement = bench_placement(model, degraded, steps=steps)
    pinned = bench_pinned_decode(degraded)
    payload = {
        "total_wall_s": round(time.time() - t0, 2),
        "degraded": degraded,
        "affinity_supported": affinity_supported(),
        "rows": [coloc, placement, pinned],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)

    mode = "DEGRADED (hardware gates skipped)" if degraded else "full"
    print(f"colocation [{mode}] pinned_p95={coloc['pinned_p95_x']}x "
          f"unpinned_p95={coloc['unpinned_p95_x']}x "
          f"bound={PINNED_P95_BOUND}x on {coloc['topology']}")
    for arch, fam in placement["families"].items():
        for c in fam["configs"]:
            print(f"placement  {arch:12s} {c['config']:4s} "
                  f"cpf={c['cpf_makespan_us']:9.2f}us "
                  f"contention={c['contention_makespan_us']:9.2f}us "
                  f"gain={c['gain_pct']:+.3f}%")
    for s in placement["measured_steps"]:
        print(f"measured   {s['arch']:12s} cpf={s['cpf_step_ms']:8.2f}ms "
              f"contention={s['contention_step_ms']:8.2f}ms "
              f"improvement={s['improvement_pct']:+.2f}%")
    print(f"pinned_decode bit_exact={pinned['bit_exact']} "
          f"pinning_took={pinned['pinning_took']}")
    print(f"wrote {args.out} ({payload['total_wall_s']}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
