"""Paged-KV serving perf: paged engine vs per-slot continuous batching
(BENCH_serve_paged.json).

Two workloads, one model, both engines leasing executors from one Runtime.

Workload A (throughput + memory): every prompt is a shared page-aligned
system prefix plus a short unique tail, arriving Poisson — the regime
prefix sharing is built for.  The per-slot engine re-prefills the system
prompt for every request; the paged engine maps the already-computed pages
and prefills only the tail.  Paged and per-slot timed legs are interleaved
(P, C, P, C, ...) so machine drift cancels; the gate compares median legs.

Workload B (admission): short prompts with and without one long prompt
(>= 8x the median short) in flight.  Chunked prefill must keep
admission-to-first-token bounded by the chunk size, not by the stranger's
prompt length.

    PYTHONPATH=src python scripts/bench_serve_paged.py [--out BENCH_serve_paged.json]

Gates (the ISSUE acceptance criteria):
  * paged token streams match the per-slot engine's bit-exactly;
  * paged tokens/s >= 1.3x per-slot on the shared-prefix workload;
  * paged peak hot KV bytes <= 0.6x the per-slot engine's resident cache;
  * short-prompt p95 admission-to-first-token with the long prompt in
    flight <= 2x the short-only p95.
"""
import argparse
import json
import statistics
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.launch.serve import drive, percentile
from repro.models import transformer
from repro.serve.engine import ContinuousEngine, Request, ServeConfig
from repro.serve.paged import PagedConfig, PagedEngine


def gate(cond, msg):
    """Acceptance gate that survives ``python -O`` (no bare asserts)."""
    if not cond:
        raise SystemExit(f"GATE FAILED: {msg}")


def reset(workload):
    return [(t, Request(request_id=r.request_id, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens, eos_id=r.eos_id))
            for t, r in workload]


def build_shared_prefix_requests(cfg, *, n_requests, system, tail_lens,
                                 max_new, arrival_rate, seed=0):
    """Poisson arrivals, every prompt = shared system prefix + unique tail."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        if arrival_rate > 0:
            t += float(rng.exponential(1.0 / arrival_rate))
        tail = rng.integers(1, cfg.vocab_size,
                            size=tail_lens[i % len(tail_lens)]).astype(np.int32)
        out.append((t, Request(request_id=i, prompt=np.concatenate([system, tail]),
                               max_new_tokens=max_new)))
    return out


def drive_first_token(engine, arrivals):
    """Like ``launch.serve.drive`` but stamps each request's *first* emitted
    token; returns {request_id: admission_to_first_token_seconds}."""
    t0 = time.perf_counter()
    todo = list(arrivals)
    submit_t, first_t = {}, {}
    while True:
        now = time.perf_counter() - t0
        while todo and todo[0][0] <= now:
            r = todo.pop(0)[1]
            engine.submit(r)
            submit_t[r.request_id] = time.perf_counter() - t0
        if engine.has_work:
            engine.step()
            stamp = time.perf_counter() - t0
            live = engine.completed + [s for s in engine.slots if s is not None]
            for r in live:
                if r.output and r.request_id not in first_t:
                    first_t[r.request_id] = stamp
        elif todo:
            time.sleep(max(0.0, todo[0][0] - (time.perf_counter() - t0)))
        else:
            break
    done = engine.run()
    return done, {i: first_t[i] - submit_t[i] for i in first_t}


def timed_leg(engine, workload):
    done, lat, wall = drive(engine, reset(workload), continuous=True)
    n_tokens = sum(len(r.output) for r in done)
    return done, {
        "n_tokens": n_tokens,
        "wall_s": round(wall, 4),
        "tok_per_s": round(n_tokens / wall, 2),
        "lat_p95_ms": round(percentile(list(lat.values()), 0.95) * 1e3, 2),
    }


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="BENCH_serve_paged.json")
    p.add_argument("--requests", type=int, default=24)
    p.add_argument("--max-new", type=int, default=6)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--legs", type=int, default=3)
    p.add_argument("--system-len", type=int, default=256)
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--prefill-chunk", type=int, default=16)
    p.add_argument("--arrival-rate", type=float, default=40.0)
    p.add_argument("--d-model", type=int, default=256)
    args = p.parse_args()

    # wider than the default smoke config: prefill must cost real compute
    # relative to a decode step, or prefix sharing has nothing to save
    cfg = get_config("gemma-2b", smoke=True).reduced(
        vocab_size=300, d_model=args.d_model, n_heads=8, n_kv_heads=2,
        d_ff=4 * args.d_model)
    params = transformer.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    system = rng.integers(1, cfg.vocab_size, size=args.system_len).astype(np.int32)
    tail_lens = [4, 8, 12, 16]
    scfg = ServeConfig(
        max_batch=args.max_batch,
        max_len=args.system_len + max(tail_lens) + 2 * args.max_new)
    workload = build_shared_prefix_requests(
        cfg, n_requests=args.requests, system=system, tail_lens=tail_lens,
        max_new=args.max_new, arrival_rate=args.arrival_rate)

    import repro
    runtime = repro.Runtime()
    repro.set_default_runtime(runtime)

    t0 = time.time()
    paged = PagedEngine(cfg, params, scfg, runtime=runtime,
                        paged=PagedConfig(page_size=args.page_size,
                                          prefill_chunk=args.prefill_chunk))
    cont = ContinuousEngine(cfg, params, scfg, runtime=runtime)
    cont.warmup([len(r.prompt) for _, r in workload])

    # one unmeasured pass each (captures compile; seeds the prefix registry
    # with the system prompt's pages — the steady state under measurement)
    drive(paged, reset(workload), continuous=True)
    drive(cont, reset(workload), continuous=True)
    # the cold-start pass prefills the system prompt in every slot at once
    # (nothing is registered until the first prefill completes); the peak
    # under measurement is the steady state with a warm prefix cache
    paged.page_pool.peak_used = paged.page_pool.hot()

    # ---- workload A: interleaved timed legs -------------------------------
    paged_legs, cont_legs = [], []
    paged_done = cont_done = None
    for _ in range(args.legs):
        paged_done, row = timed_leg(paged, workload)
        paged_legs.append(row)
        cont_done, row = timed_leg(cont, workload)
        cont_legs.append(row)
    paged_tps = statistics.median(r["tok_per_s"] for r in paged_legs)
    cont_tps = statistics.median(r["tok_per_s"] for r in cont_legs)

    # per-slot KV is resident for every slot at full width the whole time;
    # paged peak counts hot pages only (cold prefix cache is reclaimable)
    cache_len = transformer._attn_cache_len(cfg, scfg.max_len)
    per_slot_kv_bytes = int(
        args.max_batch * cache_len * paged.page_bytes // args.page_size)
    paged_kv_bytes = paged.stats()["peak_kv_bytes"]

    # ---- workload B: admission-to-first-token under a long prefill --------
    shorts = build_shared_prefix_requests(
        cfg, n_requests=12, system=np.empty(0, np.int32), tail_lens=[8],
        max_new=args.max_new, arrival_rate=30.0, seed=11)
    long_prompt = rng.integers(
        1, cfg.vocab_size, size=8 * 8 + 8).astype(np.int32)   # >= 8x median
    for _, r in shorts:
        r.request_id += 100
    _, base_ft = drive_first_token(paged, reset(shorts))
    with_long = [(0.0, Request(request_id=99, prompt=long_prompt,
                               max_new_tokens=args.max_new))] + reset(shorts)
    _, long_ft = drive_first_token(paged, with_long)
    base_p95 = percentile(list(base_ft.values()), 0.95)
    mixed_p95 = percentile(
        [v for i, v in long_ft.items() if i != 99], 0.95)

    stats = paged.stats()
    payload = {
        "total_wall_s": round(time.time() - t0, 2),
        "workload": {
            "arch": cfg.name, "vocab_size": cfg.vocab_size,
            "requests": args.requests, "system_len": args.system_len,
            "tail_lens": tail_lens, "max_new": args.max_new,
            "arrival_rate": args.arrival_rate, "max_batch": args.max_batch,
            "page_size": args.page_size, "prefill_chunk": paged.chunk,
            "n_pages": paged.page_pool.n_pages, "legs": args.legs,
        },
        "rows": [
            {"bench": "serve_paged", "tok_per_s": paged_tps,
             "legs": paged_legs, **stats, "peak_kv_bytes": paged_kv_bytes},
            {"bench": "serve_per_slot", "tok_per_s": cont_tps,
             "legs": cont_legs, "peak_kv_bytes": per_slot_kv_bytes,
             "n_executors": cont.n_executors},
        ],
        "admission": {
            "short_only_p95_ms": round(base_p95 * 1e3, 2),
            "with_long_p95_ms": round(mixed_p95 * 1e3, 2),
            "long_prompt_len": len(long_prompt),
        },
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    for r in payload["rows"]:
        print(f"{r['bench']:14s} tok/s={r['tok_per_s']:8.1f} "
              f"peak_kv={r['peak_kv_bytes']:>9d}B")
    print(f"admission p95: short-only={base_p95 * 1e3:.1f}ms "
          f"with-long={mixed_p95 * 1e3:.1f}ms")
    print(f"wrote {args.out} ({payload['total_wall_s']}s)")

    # ---- gates (ISSUE acceptance criteria) --------------------------------
    cont_out = {r.request_id: r.output for r in cont_done}
    gate(all(r.output == cont_out[r.request_id] for r in paged_done),
         "paged outputs diverge from per-slot outputs")
    gate(stats["n_shared_pages"] > 0, "prefix sharing never engaged")
    gate(paged_tps >= 1.3 * cont_tps,
         f"paged {paged_tps} tok/s < 1.3x per-slot {cont_tps}")
    gate(paged_kv_bytes <= 0.6 * per_slot_kv_bytes,
         f"paged peak KV {paged_kv_bytes}B > 0.6x per-slot {per_slot_kv_bytes}B")
    gate(mixed_p95 <= 2.0 * base_p95,
         f"admission p95 with long prefill {mixed_p95 * 1e3:.1f}ms > 2x "
         f"short-only {base_p95 * 1e3:.1f}ms")
    paged.close()
    cont.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
