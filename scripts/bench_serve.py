"""Serving perf smoke: wave batcher vs continuous batching (BENCH_serve.json).

One workload, two engines.  Staggered Poisson arrivals with mixed prompt
lengths — the regime the wave batcher handles worst (length bucketing +
whole-wave stalls) and the continuous engine is built for (slot admission
between decode steps).  Each engine first runs the workload once unmeasured
(shape warmup: jit compiles for the wave engine, capture + first eager
execution for the continuous engine), then the timed pass records tokens/s
and per-request latency (completion - arrival).

    PYTHONPATH=src python scripts/bench_serve.py [--out BENCH_serve.json]

Smoke gates (the ISSUE acceptance criteria):
  * every emitted token id is < cfg.vocab_size (pad-vocab mask);
  * continuous beats wave on p95 per-request latency;
  * continuous tokens/s is no worse than 0.9x wave.
"""
import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.launch.serve import build_requests, drive, percentile
from repro.models import transformer
from repro.serve.engine import ContinuousEngine, Request, ServeConfig, ServeEngine


def gate(cond, msg):
    """Acceptance gate that survives ``python -O`` (no bare asserts)."""
    if not cond:
        raise SystemExit(f"GATE FAILED: {msg}")


def reset(workload):
    return [(t, Request(request_id=r.request_id, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens, eos_id=r.eos_id))
            for t, r in workload]


def warm_wave_shapes(eng, cfg, scfg, prompt_lens, max_batch):
    """Compile every (wave_size, prompt_len) shape the wave engine can hit.

    Wave batching's batch dimension follows queue occupancy, so each new
    wave size is a fresh XLA compile; warming the whole zoo up front keeps
    the timed pass compile-free (the continuous engine has one decode shape
    by construction).
    """
    import jax.numpy as jnp

    from repro.models import transformer
    for b in range(1, max_batch + 1):
        cache = transformer.init_cache(cfg, b, scfg.max_len)
        for s in prompt_lens:
            toks = jnp.zeros((b, s), jnp.int32)
            logits, filled = eng._prefill(eng.params, cache, {"tokens": toks})
            out = eng._decode(eng.params, filled, jnp.zeros((b, 1), jnp.int32))
            jax.block_until_ready(out[0])


def run_engine(make_engine, workload, *, continuous, warm=None):
    # unmeasured warmup (shape compiles) + one unmeasured pass, then timed
    eng = make_engine()
    if warm is not None:
        warm(eng)
    drive(eng, reset(workload), continuous=continuous)
    if continuous:
        # the artifact's loop counters must describe the timed pass only
        eng.n_steps = eng.n_decode_steps = eng.n_overlapped_prefills = 0
    done, lat, wall = drive(eng, reset(workload), continuous=continuous)
    n_tokens = sum(len(r.output) for r in done)
    row = {
        "n_requests": len(done),
        "n_tokens": n_tokens,
        "wall_s": round(wall, 4),
        "tok_per_s": round(n_tokens / wall, 2),
        "lat_p50_ms": round(percentile(list(lat.values()), 0.50) * 1e3, 2),
        "lat_p95_ms": round(percentile(list(lat.values()), 0.95) * 1e3, 2),
        "max_token_id": max(t for r in done for t in r.output),
    }
    if continuous:
        row.update({
            "n_steps": eng.n_steps,
            "n_decode_steps": eng.n_decode_steps,
            "n_overlapped_prefills": eng.n_overlapped_prefills,
            "n_executors": eng.n_executors,
            "runtime_workers": eng.runtime.n_workers if eng.runtime else None,
            "profiled_config": list(eng.profile.best_config),
        })
        eng.close()
    return row, done


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="BENCH_serve.json")
    p.add_argument("--requests", type=int, default=32)
    p.add_argument("--max-new", type=int, default=24)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--arrival-rate", type=float, default=40.0)
    args = p.parse_args()

    cfg = get_config("gemma-2b", smoke=True)
    # padded-vocab head: random weight in vocab_size..padded_vocab would be
    # sampleable without the serve-path mask (the headline bugfix gate)
    cfg = cfg.reduced(vocab_size=300)
    assert cfg.padded_vocab > cfg.vocab_size
    params = transformer.init_params(cfg, jax.random.key(0))
    prompt_lens = [4, 12, 20, 28]
    scfg = ServeConfig(max_batch=args.max_batch,
                       max_len=max(prompt_lens) + args.max_new + 1)
    workload = build_requests(
        cfg, n_requests=args.requests, prompt_lens=prompt_lens,
        max_new=args.max_new, arrival_rate=args.arrival_rate,
    )

    # the continuous engine leases executors per step from one process
    # Runtime (the production wiring) instead of constructing its own pool
    import repro
    runtime = repro.Runtime()
    repro.set_default_runtime(runtime)

    t0 = time.time()
    wave_row, wave_done = run_engine(
        lambda: ServeEngine(cfg, params, scfg), workload, continuous=False,
        warm=lambda e: warm_wave_shapes(e, cfg, scfg, prompt_lens, args.max_batch))
    cont_row, cont_done = run_engine(
        lambda: ContinuousEngine(cfg, params, scfg, runtime=runtime),
        workload, continuous=True,
        warm=lambda e: e.warmup(prompt_lens))
    wave_row["bench"] = "serve_wave"
    cont_row["bench"] = "serve_continuous"

    payload = {
        "total_wall_s": round(time.time() - t0, 2),
        "workload": {
            "arch": cfg.name, "vocab_size": cfg.vocab_size,
            "padded_vocab": cfg.padded_vocab, "requests": args.requests,
            "prompt_lens": prompt_lens, "max_new": args.max_new,
            "arrival_rate": args.arrival_rate, "max_batch": args.max_batch,
        },
        "rows": [wave_row, cont_row],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    for r in payload["rows"]:
        print(f"{r['bench']:18s} tok/s={r['tok_per_s']:8.1f} "
              f"p50={r['lat_p50_ms']:7.1f}ms p95={r['lat_p95_ms']:7.1f}ms "
              f"wall={r['wall_s']:.2f}s")
    print(f"wrote {args.out} ({payload['total_wall_s']}s)")

    # smoke gates (ISSUE acceptance criteria)
    for done in (wave_done, cont_done):
        bad = [t for r in done for t in r.output if t >= cfg.vocab_size]
        gate(not bad, f"emitted out-of-vocab ids: {bad[:5]}")
    # per-request parity across engines: same workload, greedy decode
    wave_out = {r.request_id: r.output for r in wave_done}
    gate(all(r.output == wave_out[r.request_id] for r in cont_done),
         "continuous outputs diverge from wave outputs")
    gate(cont_row["lat_p95_ms"] < wave_row["lat_p95_ms"],
         f"continuous p95 {cont_row['lat_p95_ms']}ms >= wave {wave_row['lat_p95_ms']}ms")
    gate(cont_row["tok_per_s"] >= 0.9 * wave_row["tok_per_s"],
         f"continuous {cont_row['tok_per_s']} tok/s < 0.9x wave {wave_row['tok_per_s']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
