"""``graphi.compile()`` — one capture → profile → plan → execute API.

The paper's Fig-4 pipeline as an object model::

    import repro
    exe = repro.compile(loss_fn, params_spec, batch_spec, hw=repro.KNL7250)
    exe.graph            # the captured OpNode DAG
    exe.profile          # best (n_executors, team_size) + per-op cost table
    exe.schedule         # frozen critical-path-first schedule
    exe.critical_path    # (length_s, [op, ...])
    out = exe(params, batch)   # dispatch through the chosen backend

``compile`` accepts either a JAX callable plus input specs (captured via
``core.capture``) or an already-built :class:`~repro.core.graph.Graph`
(the paper nets).  All planning artifacts are lazy, cached properties;
``Executable`` is the one handle the rest of the stack (launch, train,
benchmarks, examples) talks to.  ``core.engine.GraphiEngine`` survives only
as a deprecated shim over this module.

Backends
--------
* ``"host"`` — the paper-faithful dynamic runtime (:class:`HostScheduler`):
  real execution on executor threads, returns ``fn``'s output pytree.
* ``"sim"``  — cost-model replay only; calling the executable returns the
  :class:`SimResult` (no numerics — the only callable backend for stat-only
  graphs such as the paper nets).
* ``"mesh"`` — freezes the CPF schedule into barrier slots bound to
  disjoint executor sub-meshes (``repro.dist.executor_mesh``) and executes
  slot-by-slot (reference semantics on this box).
"""
from __future__ import annotations

from typing import Any, Mapping

from repro.core.capture import CapturedGraph, capture
from repro.core.cost_model import KNL7250, HardwareModel, sequential_makespan
from repro.core.engine import ExecutorPool, HostRunResult, HostScheduler
from repro.core.graph import Graph
from repro.core.profiler import ProfileResult, profile
from repro.core.scheduler import Schedule, make_schedule, slot_assignment
from repro.core.simulate import SimConfig, SimResult, simulate

__all__ = ["Executable", "compile", "serve_engine"]

_BACKENDS = ("host", "sim", "mesh")


class Executable:
    """A scheduled computation graph: callable, introspectable, lazy.

    Planning artifacts (``profile`` → ``schedule`` → ``slots``) are computed
    on first access and cached; mutating knobs after first use is not
    supported — recompile instead.
    """

    def __init__(
        self,
        graph: Graph,
        hw: HardwareModel,
        *,
        captured: CapturedGraph | None = None,
        backend: str = "host",
        policy: str = "cpf",
        n_workers: int | None = None,
        reserved_workers: int = 2,
        n_executors: int | None = None,
        team_size: int | None = None,
        mesh: Any = None,
        pool: ExecutorPool | None = None,
    ):
        if backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
        self._graph = graph
        self.hw = hw
        self.captured = captured
        self.backend = backend
        self.policy = policy
        self.n_workers = n_workers
        self.reserved_workers = reserved_workers
        self._pin = (n_executors, team_size)
        self.mesh = mesh
        self.pool = pool
        self._host: HostScheduler | None = None
        self._host_key: tuple | None = None
        self._profile: ProfileResult | None = None
        self._schedule: Schedule | None = None
        self._slots: list[list[str]] | None = None
        self._plan: Any = None
        self.last_run: HostRunResult | SimResult | None = None
        self.last_plan: Any = None

    # -- introspection (the .lower()-style surface) -------------------------
    @property
    def graph(self) -> Graph:
        return self._graph

    @property
    def usable_workers(self) -> int:
        n = self.n_workers if self.n_workers is not None else self.hw.n_workers
        return max(1, n - self.reserved_workers)

    @property
    def profile(self) -> ProfileResult:
        if self._profile is None:
            self._profile = profile(
                self._graph, self.hw, n_workers=self.usable_workers, policy=self.policy
            )
        return self._profile

    def profile_with(self, **kw: Any) -> ProfileResult:
        """Re-run the configuration search with profiler kwargs
        (``extra_configs=``, ``measured_costs=``, ...) and cache the result."""
        self._profile = profile(
            self._graph, self.hw, n_workers=self.usable_workers, policy=self.policy, **kw
        )
        self._schedule = None
        self._slots = None
        return self._profile

    @property
    def schedule(self) -> Schedule:
        if self._schedule is None:
            self._schedule = self.schedule_for(self.policy)
        return self._schedule

    def schedule_for(self, policy: str) -> Schedule:
        n_exec, team = self._pin
        if n_exec is None or team is None:
            p = self.profile
            n_exec = n_exec or p.best_n_executors
            team = team or p.best_team_size
        return make_schedule(
            self._graph, self.hw, n_executors=n_exec, team_size=team, policy=policy
        )

    @property
    def slots(self) -> list[list[str]]:
        """Barrier-slot structure of the frozen schedule (static plan)."""
        if self._slots is None:
            self._slots = slot_assignment(self._graph, self.schedule)
        return self._slots

    @property
    def critical_path(self) -> tuple[float, list[str]]:
        return self._graph.critical_path(self.schedule.op_costs)

    def simulate(self, **kw: Any) -> SimResult:
        p = self.profile
        cfg = SimConfig(
            n_executors=kw.pop("n_executors", self._pin[0] or p.best_n_executors),
            team_size=kw.pop("team_size", self._pin[1] or p.best_team_size),
            policy=kw.pop("policy", self.policy),
            **kw,
        )
        return simulate(self._graph, self.hw, cfg, costs=p.op_costs)

    def static_plan(self, mesh: Any = None, *, axis: str | None = None):
        """Bind the frozen schedule to disjoint executor sub-meshes.

        The default-argument plan (the compile-time mesh) is cached like
        every other planning artifact; passing an explicit mesh/axis
        recomputes for that binding.
        """
        from repro.dist.executor_mesh import plan_from_schedule

        is_default = mesh is None and axis is None
        if is_default and self._plan is not None:
            return self._plan
        mesh = mesh if mesh is not None else self.mesh
        if mesh is None:
            raise ValueError("static_plan needs a mesh (pass one or compile(mesh=...))")
        plan = plan_from_schedule(self._graph, self.schedule, mesh, axis=axis)
        if is_default:
            self._plan = plan
        return plan

    def describe(self) -> str:
        g = self._graph
        sched = self.schedule
        cp_len, cp = self.critical_path
        seq = sequential_makespan(self.hw, g, sched.team_size)
        return (
            f"Executable({g.name!r}, backend={self.backend!r}, hw={self.hw.name})\n"
            f"  nodes={len(g)} width={g.width()} flops={g.total_flops():.3g}\n"
            f"  config: {sched.n_executors} executors x {sched.team_size} workers "
            f"({self.policy})\n"
            f"  makespan={sched.makespan:.3e}s sequential={seq:.3e}s "
            f"speedup={seq / sched.makespan if sched.makespan else 0.0:.2f}x\n"
            f"  critical path ({cp_len:.3e}s, {len(cp)} ops): "
            f"{' -> '.join(cp[:6])}{' ...' if len(cp) > 6 else ''}"
        )

    # -- execution ----------------------------------------------------------
    def _host_executors(self, n_executors: int | None = None) -> int:
        explicit = n_executors if n_executors is not None else self._pin[0]
        if explicit is not None:
            n = explicit
        else:
            n = self.profile.best_n_executors
            # the modelled best config may be one wide executor (team-size
            # trade-off); executor *threads* have no team dimension, so the
            # profiled default always exploits available DAG width — an
            # explicitly requested count is honored as-is
            if self._graph.width() >= 2:
                n = max(n, 2)
        # input passthroughs resolve inline in the scheduler — only real
        # ops occupy executor threads
        n_real = sum(1 for nd in self._graph.nodes if nd.kind != "input")
        return min(n, max(1, n_real))

    @property
    def planned_executors(self) -> int:
        """Executor-thread count the host backend will actually use."""
        return self._host_executors()

    def execute_host(
        self,
        inputs: Mapping[str, Any] | None = None,
        n_executors: int | None = None,
        pool: ExecutorPool | None = None,
    ) -> HostRunResult:
        """Run the dynamic host runtime on a name→value input mapping.

        With a ``pool`` (given here or at compile time) the run submits to
        those persistent executors — a serving decode loop reuses one
        HostScheduler instead of paying thread startup per step — and the
        pool's size wins over the planned executor count.
        """
        pool = pool if pool is not None else self.pool
        n = self._host_executors(n_executors)
        key = (n, id(pool))
        if self._host is None or self._host_key != key:
            self._host = HostScheduler(
                self._graph, n, costs=self.schedule.op_costs or None, pool=pool
            )
            self._host_key = key
        res = self._host.run(inputs)
        self.last_run = res
        return res

    def __call__(self, *args: Any) -> Any:
        if self.backend == "sim":
            self.last_run = self.simulate()
            return self.last_run
        if self.captured is None:
            # raw-graph executables take a single name→value mapping
            inputs: Mapping[str, Any] | None = args[0] if args else None
        else:
            inputs = self.captured.bind(args)
        if self.backend == "host":
            res = self.execute_host(inputs)
            results = res.outputs
        else:
            results = self._run_static(inputs)
        if self.captured is None:
            return results
        return self.captured.unflatten(results)

    def _run_static(self, inputs: Mapping[str, Any] | None) -> dict[str, Any]:
        """mesh backend: execute the static plan slot-by-slot (barrier
        semantics; per-slot lanes are independent — reference execution on
        this box)."""
        plan = self.static_plan()
        inputs = dict(inputs or {})
        g = self._graph
        results: dict[str, Any] = {}
        for slot in plan.slots:
            for op in slot:
                node = g[op]
                if not node.deps and op in inputs and node.fn is None:
                    results[op] = inputs[op]
                elif node.fn is None:
                    raise ValueError(f"node {op!r} has no fn and no input")
                else:
                    results[op] = node.fn(*[results[d] for d in node.deps])
        self.last_plan = plan
        return results

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Executable({self._graph.name!r}, backend={self.backend!r}, "
            f"hw={self.hw.name}, n={len(self._graph)})"
        )


def compile(
    target: Any,
    *specs: Any,
    hw: HardwareModel = KNL7250,
    backend: str = "host",
    name: str | None = None,
    policy: str = "cpf",
    n_workers: int | None = None,
    reserved_workers: int = 2,
    n_executors: int | None = None,
    team_size: int | None = None,
    fuse: bool = True,
    jit_nodes: bool = False,
    mesh: Any = None,
    pool: ExecutorPool | None = None,
) -> Executable:
    """Turn a JAX function (or a pre-built :class:`Graph`) into a scheduled
    :class:`Executable`.

    ``specs`` are the function's example inputs — concrete arrays or
    ``jax.ShapeDtypeStruct`` pytrees (capture reads shapes/dtypes only).
    ``n_executors``/``team_size`` pin the executor configuration instead of
    profiling for the best one.  ``pool`` shares one persistent
    :class:`ExecutorPool` across executables (e.g. a serve engine's prefill
    and decode graphs submitting to the same executors).  ``jit_nodes``
    wraps every node ``fn`` in ``jax.jit`` — one compiled XLA call per node
    instead of eager per-equation dispatch, the right trade for graphs
    executed thousands of times (a serving decode loop).
    """
    captured: CapturedGraph | None = None
    if isinstance(target, CapturedGraph):
        if specs:
            raise TypeError("compile(captured_graph) takes no input specs "
                            "(they were fixed at capture time)")
        captured, graph = target, target.graph
    elif isinstance(target, Graph):
        if specs:
            raise TypeError("compile(graph) takes no input specs")
        graph = target
    else:
        captured = capture(target, *specs, name=name, fuse=fuse)
        graph = captured.graph
    if jit_nodes:
        graph = _jit_graph(graph)
    return Executable(
        graph,
        hw,
        captured=captured,
        backend=backend,
        policy=policy,
        n_workers=n_workers,
        reserved_workers=reserved_workers,
        n_executors=n_executors,
        team_size=team_size,
        mesh=mesh,
        pool=pool,
    )


def _jit_graph(graph: Graph) -> Graph:
    """A copy of ``graph`` with every node ``fn`` wrapped in ``jax.jit``.

    A copy, not an in-place rewrite: callers may hand ``compile`` a graph
    they still execute directly (the capture oracle, parity tests), and
    re-compiling must not stack ``jit`` wrappers.
    """
    import jax
    from dataclasses import replace

    out = Graph(graph.name)
    for name in graph.names:
        node = graph[name]
        out.add(replace(node, fn=jax.jit(node.fn) if node.fn is not None else None))
    return out


def serve_engine(
    cfg: Any,
    params: Any,
    serve_cfg: Any = None,
    *,
    continuous: bool = True,
    **kw: Any,
) -> Any:
    """Serve-shaped entry point: a serving engine over ``repro.compile``.

    ``continuous=True`` (default) returns the
    :class:`~repro.serve.engine.ContinuousEngine` — prefill and decode
    captured as graphi Executables, a profiler-chosen executor config, and
    per-request slot admission.  ``continuous=False`` returns the
    length-bucketed wave :class:`~repro.serve.engine.ServeEngine`.
    Extra kwargs go to the engine constructor — ``rng_seed=`` for either
    engine; ``hw=``, ``max_executors=``, ``pool=`` are continuous-only.
    """
    from repro.serve.engine import ContinuousEngine, ServeConfig, ServeEngine

    scfg = serve_cfg if serve_cfg is not None else ServeConfig()
    eng_cls = ContinuousEngine if continuous else ServeEngine
    return eng_cls(cfg, params, scfg, **kw)
