"""``graphi.compile()`` — one capture → profile → plan → execute API.

The paper's Fig-4 pipeline as an object model::

    import repro
    exe = repro.compile(loss_fn, params_spec, batch_spec, hw=repro.KNL7250)
    exe.graph            # the captured OpNode DAG
    exe.profile          # best (n_executors, team_size) + per-op cost table
    exe.schedule         # frozen critical-path-first schedule
    exe.critical_path    # (length_s, [op, ...])
    out = exe(params, batch)   # dispatch through the chosen backend

``compile`` accepts either a JAX callable plus input specs (captured via
``core.capture``) or an already-built :class:`~repro.core.graph.Graph`
(the paper nets).  All planning artifacts are lazy, cached properties;
``Executable`` is the one handle the rest of the stack (launch, train,
benchmarks, examples) talks to.

Every executable belongs to a :class:`repro.runtime.Runtime` — the
process-wide session that owns the single executor pool, the persistent
calibration store, and the admission layer.  Bare ``repro.compile(...)``
binds to :func:`repro.runtime.default_runtime`; a host run leases its
calibrated executor width from the runtime for exactly the duration of the
run, so concurrent executables share the machine with bounded interference
instead of each spawning threads.  An explicit ``pool=`` bypasses admission
(the caller owns sharing).

Backends
--------
* ``"host"`` — the paper-faithful dynamic runtime (:class:`HostScheduler`):
  real execution on executor threads, returns ``fn``'s output pytree.
* ``"sim"``  — cost-model replay only; calling the executable returns the
  :class:`SimResult` (no numerics — the only callable backend for stat-only
  graphs such as the paper nets).
* ``"mesh"`` — freezes the CPF schedule into barrier slots bound to
  disjoint executor sub-meshes (``repro.dist.executor_mesh``) and executes
  slot-by-slot (reference semantics on this box).
"""
from __future__ import annotations

import hashlib
from typing import Any, Mapping

from repro.core.capture import CapturedGraph, capture
from repro.core.cost_model import KNL7250, HardwareModel, sequential_makespan
from repro.core.engine import (DeadlineExceeded, ExecutorPool, HostRunResult,
                               HostScheduler)
from repro.core.graph import Graph
from repro.core.profiler import ProfileResult, measure_op_costs, profile
from repro.core.scheduler import Schedule, make_schedule, slot_assignment
from repro.core.search import SearchResult, search_schedule
from repro.core.simulate import SimConfig, SimResult, simulate
from repro.core.static_host import StaticHostPlan, compile_host_plan
from repro.runtime import Runtime, default_runtime, graph_signature

__all__ = ["Executable", "compile", "serve_engine"]


def _cost_fp(costs: Mapping[str, float] | None) -> str | None:
    """Content fingerprint of a cost table (two executables over one graph
    share plans only when their cost models agree).  A *stable* sha over
    sorted items — not ``hash(frozenset)`` — because the fingerprint is also
    part of the persisted schedule-search config key, which must mean the
    same thing across processes (``PYTHONHASHSEED`` varies ``hash``)."""
    if costs is None:
        return None
    h = hashlib.sha256()
    for k in sorted(costs):
        h.update(f"{k}:{float(costs[k])!r};".encode())
    return h.hexdigest()[:16]

_BACKENDS = ("host", "sim", "mesh")
_HOST_MODES = ("dynamic", "static")
_CHECK_MODES = ("off", "basic", "strict")
_SEARCH_MODES = ("off", "auto", "force")


class Executable:
    """A scheduled computation graph: callable, introspectable, lazy.

    Planning artifacts (``profile`` → ``schedule`` → ``slots``) are computed
    on first access and cached; mutating knobs after first use is not
    supported — recompile instead.
    """

    def __init__(
        self,
        graph: Graph,
        hw: HardwareModel,
        *,
        captured: CapturedGraph | None = None,
        backend: str = "host",
        policy: str = "cpf",
        n_workers: int | None = None,
        reserved_workers: int = 2,
        n_executors: int | None = None,
        team_size: int | None = None,
        mesh: Any = None,
        pool: ExecutorPool | None = None,
        host_mode: str = "dynamic",
        runtime: Runtime | None = None,
        signature: str | None = None,
        check: str = "basic",
        schedule_search: str = "auto",
    ):
        if backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
        if host_mode not in _HOST_MODES:
            raise ValueError(
                f"host_mode must be one of {_HOST_MODES}, got {host_mode!r}")
        if check not in _CHECK_MODES:
            raise ValueError(
                f"check must be one of {_CHECK_MODES}, got {check!r}")
        if schedule_search not in _SEARCH_MODES:
            raise ValueError(
                f"schedule_search must be one of {_SEARCH_MODES}, "
                f"got {schedule_search!r}")
        if check != "off":
            # structural graph verification (repro.checks G-* rules): O(V+E),
            # runs once per executable — a malformed graph fails loudly here,
            # not as a stuck run or a wrong plan deep in the host runtime
            from repro.checks import check_graph

            check_graph(graph).raise_if_errors()
        self.check = check
        self._graph = graph
        self.hw = hw
        self.captured = captured
        self.backend = backend
        self.policy = policy
        self.n_workers = n_workers
        self.reserved_workers = reserved_workers
        self._pin = (n_executors, team_size)
        self.mesh = mesh
        self.pool = pool
        self.host_mode = host_mode
        self.runtime = runtime
        self.signature = signature
        self.schedule_search = schedule_search
        self._search: SearchResult | None = None   # last search this exe ran
        self._search_hit: dict | None = None       # last store-replayed record
        self._host: HostScheduler | None = None
        self._host_key: tuple | None = None
        self._host_plans: dict[int, StaticHostPlan] = {}
        self._lease_ids: tuple[int, ...] = ()   # sticky-lease affinity hint
        self._measured: Any = None   # measured_costs fn from the last profile
        self._planned: int | None = None   # cached default executor count
        self._n_real: int | None = None    # cached non-input node count
        self._profile: ProfileResult | None = None
        self._schedule: Schedule | None = None
        self._slots: list[list[str]] | None = None
        self._plan: Any = None
        self.last_run: HostRunResult | SimResult | None = None
        self.last_plan: Any = None

    # -- introspection (the .lower()-style surface) -------------------------
    @property
    def graph(self) -> Graph:
        return self._graph

    @property
    def usable_workers(self) -> int:
        n = self.n_workers if self.n_workers is not None else self.hw.n_workers
        return max(1, n - self.reserved_workers)

    @property
    def profile(self) -> ProfileResult:
        if self._profile is None:
            kw: dict[str, Any] = {}
            if self._measured is not None:
                # seeded from the runtime's calibration store (or a prior
                # calibrate): the lazy first profile must use the measured
                # table too, not silently fall back to analytic costs
                kw["measured_costs"] = self._measured
            self._profile = profile(
                self._graph, self.hw, n_workers=self.usable_workers,
                policy=self.policy, **kw
            )
        return self._profile

    def profile_with(self, **kw: Any) -> ProfileResult:
        """Re-run the configuration search with profiler kwargs
        (``extra_configs=``, ``measured_costs=``, ...) and cache the result.

        ``measured_costs`` sticks: subsequent schedules (and the static
        host plans frozen from them) — and later ``profile_with`` calls —
        use the measured table instead of the analytic cost model, so the
        config search and the frozen placements always agree on one cost
        model.  Pass ``measured_costs=None`` to revert."""
        if "measured_costs" in kw:
            self._measured = kw["measured_costs"]
        elif self._measured is not None:
            kw = {**kw, "measured_costs": self._measured}
        self._profile = profile(
            self._graph, self.hw, n_workers=self.usable_workers, policy=self.policy, **kw
        )
        self._schedule = None
        self._slots = None
        self._host = None           # dynamic CPF priorities follow the costs
        self._host_key = None
        self._host_plans.clear()    # plans froze the invalidated schedule
        self._planned = None        # best executor count may have moved
        self._search = None         # a searched winner is per cost model
        self._search_hit = None
        if self.runtime is not None:
            self.runtime.invalidate(self._graph)
        return self._profile

    @property
    def schedule(self) -> Schedule:
        if self._schedule is None:
            n_exec, team = self._pin
            if n_exec is None or team is None:
                p = self.profile
                n_exec = n_exec or p.best_n_executors
                team = team or p.best_team_size
            self._schedule = self._plan_schedule(n_exec, team)
        return self._schedule

    def schedule_for(self, policy: str) -> Schedule:
        """A schedule under an *explicit* policy (registry name or naive
        baseline) at the profiled config — comparison runs; never searched."""
        n_exec, team = self._pin
        if n_exec is None or team is None:
            p = self.profile
            n_exec = n_exec or p.best_n_executors
            team = team or p.best_team_size
        costs = dict(self._measured(team)) if self._measured is not None else None
        return make_schedule(
            self._graph, self.hw, n_executors=n_exec, team_size=team,
            policy=policy, costs=costs,
        )

    @property
    def search_active(self) -> bool:
        """Whether schedule planning runs the simulator-guided policy search
        (:mod:`repro.core.search`).  ``"force"`` always searches; ``"auto"``
        (the default) searches once a *measured* cost table backs the
        executable — searching on analytic costs would optimize the model,
        not the machine — and only for the default CPF policy (an explicit
        ``policy=`` pin means the caller chose their heuristic)."""
        if self.schedule_search == "off":
            return False
        if self.schedule_search == "force":
            return True
        return self._measured is not None and self.policy == "cpf"

    def _config_key(self, n_exec: int, team: int,
                    costs: Mapping[str, float] | None) -> str:
        """The per-signature store key a searched winner persists under:
        executor config x cost-model fingerprint (search once per graph,
        width, and cost table — across processes)."""
        return f"{n_exec}x{team}|{_cost_fp(costs) or 'analytic'}"

    def _plan_schedule(self, n_exec: int, team: int) -> Schedule:
        """The schedule the executable freezes at config (n_exec, team):
        plain ``self.policy`` when search is off, else the searched winner —
        replayed from the runtime store when this (graph signature, config,
        cost model) was already searched, run (and persisted) otherwise."""
        costs = dict(self._measured(team)) if self._measured is not None else None
        if not self.search_active:
            return make_schedule(
                self._graph, self.hw, n_executors=n_exec, team_size=team,
                policy=self.policy, costs=costs,
            )
        store = (self.runtime.calibration
                 if self.runtime is not None and self.signature is not None
                 else None)
        ck = self._config_key(n_exec, team, costs)
        if store is not None:
            rec = store.get_schedule(self.signature, ck)
            if rec is not None:
                try:
                    sched = make_schedule(
                        self._graph, self.hw, n_executors=n_exec,
                        team_size=team, policy=rec["policy"],
                        seed=int(rec.get("seed", 0)), costs=costs,
                    )
                except (ValueError, KeyError):
                    # record names a policy this build doesn't register —
                    # fall through and search again rather than fail compile
                    pass
                else:
                    self._search_hit = dict(rec)
                    return sched
        # module-level entry point on purpose: tests monkeypatch
        # repro.api.search_schedule to prove a second compile() replays the
        # stored winner without re-searching
        res = search_schedule(
            self._graph, self.hw, n_executors=n_exec, team_size=team,
            costs=costs,
        )
        self._search = res
        self._search_hit = None
        if store is not None:
            # search_schedule already verified the winner against the
            # repro.checks S-rules — only vetted schedules are persisted
            store.put_schedule(self.signature, ck, res.record())
        return res.schedule

    @property
    def slots(self) -> list[list[str]]:
        """Barrier-slot structure of the frozen schedule (static plan)."""
        if self._slots is None:
            self._slots = slot_assignment(self._graph, self.schedule)
        return self._slots

    @property
    def critical_path(self) -> tuple[float, list[str]]:
        return self._graph.critical_path(self.schedule.op_costs)

    def calibrate(
        self,
        *args: Any,
        inputs: Mapping[str, Any] | None = None,
        warmup: int = 1,
        iters: int = 3,
        max_executors: int | None = None,
    ) -> ProfileResult:
        """Profile-guided replanning: time every node ``fn`` on concrete
        values (the paper's first-iterations profiling) and re-run the
        configuration search with the measured table.  Subsequent schedules
        — and the static host plans frozen from them — place ops by how
        long they *actually* take, not by the analytic cost model, which
        misranks tiny jitted ops whose cost is dispatch, not flops.

        Pass the executable's call args (captured graphs) or a name→value
        mapping via ``inputs``.  Node fns should be warm (run the
        executable once first) so compile time is not measured.

        When the executable belongs to a :class:`~repro.runtime.Runtime`,
        the measured table is written to the runtime's
        :class:`~repro.runtime.CalibrationStore` under the graph's
        signature — a later ``compile`` of the same graph (this process or,
        with a store path, the next one) starts calibrated without
        re-measuring.
        """
        import jax

        if args:
            if self.captured is None:
                raise TypeError("calibrate(*args) needs a captured graph; "
                                "pass inputs= for raw graphs")
            inputs = self.captured.bind(args)
        costs = measure_op_costs(
            self._graph, inputs, warmup=warmup, iters=iters,
            block=jax.block_until_ready,
        )
        if self.runtime is not None and self.signature is not None:
            self.runtime.calibration.put(self.signature, costs)
        kw: dict[str, Any] = {"measured_costs": lambda _team: costs}
        if max_executors is not None:
            kw["max_executors"] = max_executors
        return self.profile_with(**kw)

    @property
    def calibrated(self) -> bool:
        """Whether a measured cost table backs this executable's schedules
        (from :meth:`calibrate` or seeded from the runtime's store)."""
        return self._measured is not None

    def simulate(self, **kw: Any) -> SimResult:
        p = self.profile
        cfg = SimConfig(
            n_executors=kw.pop("n_executors", self._pin[0] or p.best_n_executors),
            team_size=kw.pop("team_size", self._pin[1] or p.best_team_size),
            policy=kw.pop("policy", self.policy),
            **kw,
        )
        return simulate(self._graph, self.hw, cfg, costs=p.op_costs)

    def static_plan(self, mesh: Any = None, *, axis: str | None = None):
        """Bind the frozen schedule to disjoint executor sub-meshes.

        The default-argument plan (the compile-time mesh) is cached like
        every other planning artifact; passing an explicit mesh/axis
        recomputes for that binding.
        """
        from repro.dist.executor_mesh import plan_from_schedule

        is_default = mesh is None and axis is None
        if is_default and self._plan is not None:
            return self._plan
        mesh = mesh if mesh is not None else self.mesh
        if mesh is None:
            raise ValueError("static_plan needs a mesh (pass one or compile(mesh=...))")
        plan = plan_from_schedule(self._graph, self.schedule, mesh, axis=axis)
        if is_default:
            self._plan = plan
        return plan

    def verify(self, *, hazards: bool = True, plan: bool = True):
        """Run the full static verifier over this executable's artifacts.

        Returns the :class:`repro.checks.Report`: graph structural rules,
        schedule feasibility, compiled host-plan invariants (``plan=True``
        builds/fetches the default :meth:`host_plan`), and — with
        ``hazards=True`` — buffer effect inference plus unordered
        read/write hazard detection over the captured jaxpr equations.
        Raises nothing itself; gate on ``report.ok`` or call
        ``report.raise_if_errors()``.
        """
        from repro.checks import verify_all

        return verify_all(
            self._graph,
            self.schedule,
            self.host_plan() if plan else None,
            hazards=hazards,
        )

    def describe(self, *, trace: bool | str = False) -> str:
        """One-paragraph summary of the executable; with ``trace`` a
        per-executor timeline is appended (paper §5.2's visualization).

        ``trace=True`` renders an ASCII timeline, ``trace="csv"`` the CSV
        table (:mod:`repro.core.trace`).  The timeline shows the **last
        run** when one exists (measured, host or sim backend) and falls
        back to a fresh cost-model simulation otherwise — the source is
        labeled, so measured-vs-simulated timelines are distinguishable.
        """
        g = self._graph
        sched = self.schedule
        cp_len, cp = self.critical_path
        seq = sequential_makespan(self.hw, g, sched.team_size)
        if self._search is not None:
            r = self._search
            search_line = (
                f"\n  schedule search: winner={r.policy!r} seed={r.seed} "
                f"makespan_sim={r.makespan_sim:.3e}s "
                f"gain_over_cpf={100.0 * r.gain_over_cpf:.2f}% "
                f"runner_up_gap={100.0 * r.runner_up_gap:.2f}%"
            )
        elif self._search_hit is not None:
            r = self._search_hit
            search_line = (
                f"\n  schedule search: winner={r['policy']!r} "
                f"seed={r.get('seed', 0)} "
                f"makespan_sim={r['makespan_sim']:.3e}s (replayed from store)"
            )
        else:
            search_line = ""
        text = (
            f"Executable({g.name!r}, backend={self.backend!r}, hw={self.hw.name})\n"
            f"  nodes={len(g)} width={g.width()} flops={g.total_flops():.3g}\n"
            f"  config: {sched.n_executors} executors x {sched.team_size} workers "
            f"({sched.policy})\n"
            f"  makespan={sched.makespan:.3e}s sequential={seq:.3e}s "
            f"speedup={seq / sched.makespan if sched.makespan else 0.0:.2f}x\n"
            f"  critical path ({cp_len:.3e}s, {len(cp)} ops): "
            f"{' -> '.join(cp[:6])}{' ...' if len(cp) > 6 else ''}"
            f"{search_line}"
        )
        if trace:
            text += "\n" + self.render_trace(
                fmt="csv" if trace == "csv" else "ascii")
        return text

    def render_trace(self, *, fmt: str = "ascii") -> str:
        """The per-executor execution timeline: the last run's measured
        trace when one exists, else a fresh cost-model simulation.
        ``fmt="ascii"`` or ``"csv"`` (:mod:`repro.core.trace`)."""
        from repro.core.trace import ascii_timeline, trace_csv

        run = self.last_run
        if run is not None and getattr(run, "trace", None):
            source = ("simulated" if isinstance(run, SimResult)
                      else "measured")
        else:
            run = self.simulate()
            source = "simulated"
        n = (run.config.n_executors if isinstance(run, SimResult)
             else 1 + max((e.executor for e in run.trace), default=0))
        if fmt == "csv":
            return trace_csv(run.trace)
        if fmt != "ascii":
            raise ValueError(f"fmt must be 'ascii' or 'csv', got {fmt!r}")
        return (f"trace ({source}, {len(run.trace)} ops):\n"
                + ascii_timeline(run.trace, n))

    # -- execution ----------------------------------------------------------
    def _host_executors(self, n_executors: int | None = None) -> int:
        explicit = n_executors if n_executors is not None else self._pin[0]
        if explicit is None and self._planned is not None:
            return self._planned    # O(1) on the per-step decode hot path
        if self._n_real is None:
            # input passthroughs resolve inline in the scheduler — only
            # real ops occupy executor threads
            self._n_real = sum(
                1 for nd in self._graph.nodes if nd.kind != "input")
        if explicit is not None:
            n = explicit
        else:
            n = self.profile.best_n_executors
            # the modelled best config may be one wide executor (team-size
            # trade-off); executor *threads* have no team dimension, so the
            # profiled default always exploits available DAG width — an
            # explicitly requested count is honored as-is
            if self._graph.width() >= 2:
                n = max(n, 2)
        n = min(n, max(1, self._n_real))
        if explicit is None:
            self._planned = n
        return n

    @property
    def planned_executors(self) -> int:
        """Executor-thread count the host backend will actually use."""
        return self._host_executors()

    def host_plan(self, n_executors: int | None = None) -> StaticHostPlan:
        """The compiled static host plan, cached per (graph, n_executors).

        Freezes the CPF schedule into per-executor integer-id programs
        (``core.static_host``); when the requested width differs from the
        cached schedule's config, a schedule is made for exactly that width
        (same policy and team size) rather than folding executors.  The
        default width is the *planned* executor count, capped at the bound
        pool's (or the runtime's) size — never widened to fill a larger
        shared pool: a plan frozen wider than the profiled config pays
        cross-executor wakeups the calibration chose to avoid.

        Plans live in the runtime's per-graph cache when the executable has
        one (two executables over one graph freeze placements once); a
        runtime-less executable keeps a local cache.
        """
        if n_executors is None:
            n_executors = self._host_executors()
            if self.pool is not None:
                n_executors = min(n_executors, self.pool.n_executors)
            elif self.runtime is not None:
                n_executors = min(n_executors, self.runtime.n_workers)

        def build() -> StaticHostPlan:
            sched = self.schedule
            if sched.n_executors != n_executors:
                # re-plan at exactly the requested width — through the same
                # search-or-policy path as the default schedule, so a
                # searched executable freezes searched placements at every
                # width it runs at
                sched = self._plan_schedule(n_executors, sched.team_size)
            plan = compile_host_plan(self._graph, sched, n_executors=n_executors)
            if self.check == "strict":
                # verify every freshly-built plan (repro.checks S-*/P-*
                # rules); cached fetches stay O(1) — the artifact is frozen,
                # re-verifying the same plan per step would buy nothing
                from repro.checks import check_plan, check_schedule

                rep = check_schedule(sched, self._graph)
                rep.extend(check_plan(plan, self._graph))
                rep.raise_if_errors()
            return plan

        plan = self._host_plans.get(n_executors)
        if plan is not None:                 # O(1) on the per-step hot path
            return plan
        if self.runtime is not None:
            sched = self.schedule
            # keyed by the *frozen schedule's* identity (policy, seed) — a
            # searched executable must not collide with a plain-CPF one over
            # the same graph — plus the search mode, since at a different
            # width build() re-plans through search-or-policy again
            key = ("plan", n_executors, sched.team_size, sched.policy,
                   sched.seed, self.search_active,
                   _cost_fp(sched.op_costs or None))
            plan = self.runtime.cached(self._graph, key, build)
        else:
            plan = build()
        self._host_plans[n_executors] = plan
        return plan

    def _host_scheduler(self, n: int) -> HostScheduler:
        """The dynamic scheduler for width ``n`` (pool passed per run, so one
        scheduler serves every lease).  The runtime cache shares schedulers
        across executables of one graph; the exe-level slot in front of it
        keeps the per-step lookup O(1)."""
        if self._host is not None and self._host_key == (n,):
            return self._host

        def build() -> HostScheduler:
            return HostScheduler(
                self._graph, n, costs=self.schedule.op_costs or None)

        if self.runtime is not None:
            key = ("host", n, _cost_fp(self.schedule.op_costs or None))
            host = self.runtime.cached(self._graph, key, build)
        else:
            host = build()
        self._host = host
        self._host_key = (n,)
        return host

    def execute_host(
        self,
        inputs: Mapping[str, Any] | None = None,
        n_executors: int | None = None,
        pool: Any = None,
        *,
        host_mode: str | None = None,
        plan: StaticHostPlan | None = None,
        collect_trace: bool = False,
        deadline: float | None = None,
    ) -> HostRunResult:
        """Run the host runtime on a name→value input mapping.

        With a ``pool`` (given here or at compile time) the run submits to
        those persistent executors — the caller owns sharing — and the
        pool's size wins over the planned executor count.  Without one, the
        run **leases** its executor width from the executable's
        :class:`~repro.runtime.Runtime` (the process default if none was
        bound) for exactly the duration of the run: concurrent executables
        queue for disjoint executor subsets instead of oversubscribing the
        machine.

        ``host_mode`` overrides the compile-time knob for this run:
        ``"static"`` executes the cached :meth:`host_plan` (lock-free
        dependency counters, no per-op scheduler round-trip) and is the
        right mode for a graph replayed many times; ``"dynamic"`` is the
        paper-faithful centralized scheduler.  An explicit ``plan`` forces
        static execution of exactly that plan.  ``collect_trace`` turns on
        per-op timestamps for static runs (dynamic runs always trace).

        ``deadline`` (absolute, ``time.monotonic``) bounds the whole run —
        the lease wait *and* execution.  On expiry the run raises
        :class:`~repro.core.engine.DeadlineExceeded` and its lease is
        released with the still-busy executors **quarantined** (their
        threads are stuck inside the abandoned op; admission returns them
        to service when the op finally finishes) so a hung op degrades
        capacity instead of wedging the pool.
        """
        pool = pool if pool is not None else self.pool
        mode = host_mode if host_mode is not None else self.host_mode
        if mode not in _HOST_MODES:
            raise ValueError(
                f"host_mode must be one of {_HOST_MODES}, got {mode!r}")
        rt: Runtime | None = None
        if pool is None:
            rt = self.runtime
            if rt is None:
                # a bare Executable still shares the process pool — nothing
                # in the stack owns private executor threads any more
                rt = self.runtime = default_runtime()
        lease = None
        try:
            if plan is not None or mode == "static":
                if plan is None:
                    n = self._host_executors(n_executors)
                    if pool is not None:
                        n = min(n, pool.n_executors)
                    else:
                        n = min(n, rt.n_workers)
                    plan = self.host_plan(n)
                if pool is None:
                    if plan.n_executors > rt.n_workers:
                        # admission clamps leases to the pool — an oversized
                        # explicit plan must fail here, naming the remedy,
                        # not deep in plan.run after a silent clamp
                        raise ValueError(
                            f"plan needs {plan.n_executors} executors but the "
                            f"runtime has {rt.n_workers}; recompile the plan "
                            "for the runtime width or pass an explicit pool"
                        )
                    lease = rt.lease(plan.n_executors, prefer=self._lease_ids,
                                     deadline=deadline)
                    self._lease_ids = lease.executor_ids
                    pool = lease
                res = plan.run(inputs, pool=pool, collect_trace=collect_trace,
                               deadline=deadline)
                self.last_run = res
                return res
            n = self._host_executors(n_executors)
            if pool is not None:
                n = pool.n_executors
            else:
                n = min(n, rt.n_workers)
            host = self._host_scheduler(n)
            if pool is None:
                lease = rt.lease(n, prefer=self._lease_ids, deadline=deadline)
                self._lease_ids = lease.executor_ids
                pool = lease
            res = host.run(inputs, pool=pool, deadline=deadline)
            self.last_run = res
            return res
        except DeadlineExceeded:
            if lease is not None:
                # the abandoned op still owns its executor thread: releasing
                # it into the free set would hand the next run a busy
                # executor — quarantine it until the op finally returns
                lease.release(quarantine_busy=True)
                lease = None
            raise
        finally:
            if lease is not None:
                lease.release()

    def close(self) -> None:
        """Back-compat no-op: executables no longer own executor threads.
        Runs lease executors from the runtime and return them when the run
        completes; the pool itself is the runtime's to close."""

    def __enter__(self) -> "Executable":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def __call__(self, *args: Any) -> Any:
        if self.backend == "sim":
            self.last_run = self.simulate()
            return self.last_run
        if self.captured is None:
            # raw-graph executables take a single name→value mapping
            inputs: Mapping[str, Any] | None = args[0] if args else None
        else:
            inputs = self.captured.bind(args)
        if self.backend == "host":
            res = self.execute_host(inputs)
            results = res.outputs
        else:
            results = self._run_static(inputs)
        if self.captured is None:
            return results
        return self.captured.unflatten(results)

    def _run_static(self, inputs: Mapping[str, Any] | None) -> dict[str, Any]:
        """mesh backend: execute the static plan slot-by-slot (barrier
        semantics; per-slot lanes are independent — reference execution on
        this box)."""
        plan = self.static_plan()
        inputs = dict(inputs or {})
        g = self._graph
        results: dict[str, Any] = {}
        for slot in plan.slots:
            for op in slot:
                node = g[op]
                if not node.deps and op in inputs and node.fn is None:
                    results[op] = inputs[op]
                elif node.fn is None:
                    raise ValueError(f"node {op!r} has no fn and no input")
                else:
                    results[op] = node.fn(*[results[d] for d in node.deps])
        self.last_plan = plan
        return results

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Executable({self._graph.name!r}, backend={self.backend!r}, "
            f"hw={self.hw.name}, n={len(self._graph)})"
        )


def compile(
    target: Any,
    *specs: Any,
    hw: HardwareModel = KNL7250,
    backend: str = "host",
    name: str | None = None,
    policy: str = "cpf",
    n_workers: int | None = None,
    reserved_workers: int = 2,
    n_executors: int | None = None,
    team_size: int | None = None,
    fuse: bool = True,
    jit_nodes: bool = False,
    mesh: Any = None,
    pool: ExecutorPool | None = None,
    host_mode: str = "dynamic",
    runtime: Runtime | None = None,
    check: str = "basic",
    schedule_search: str = "auto",
    pinning: str | None = None,
) -> Executable:
    """Turn a JAX function (or a pre-built :class:`Graph`) into a scheduled
    :class:`Executable`.

    ``specs`` are the function's example inputs — concrete arrays or
    ``jax.ShapeDtypeStruct`` pytrees (capture reads shapes/dtypes only).
    ``n_executors``/``team_size`` pin the executor configuration instead of
    profiling for the best one.  ``runtime`` binds the executable to a
    :class:`~repro.runtime.Runtime` session (defaulting to the process-wide
    one): host runs lease executors from its pool, planning artifacts land
    in its caches, and a calibration-store hit seeds the cost model without
    re-measuring.  ``pool`` instead shares one explicit persistent
    :class:`ExecutorPool` across executables, bypassing admission (e.g. a
    serve engine's prefill and decode graphs submitting to the same
    executors).  ``jit_nodes`` wraps every node ``fn`` in ``jax.jit`` — one
    compiled XLA call per node instead of eager per-equation dispatch, the
    right trade for graphs executed thousands of times (a serving decode
    loop).  ``host_mode`` picks the host-backend runtime: ``"dynamic"``
    (paper-faithful centralized scheduler) or ``"static"`` (compiled
    :class:`~repro.core.static_host.StaticHostPlan` — per-op scheduling
    overhead amortized to ~zero, the right mode for replayed graphs).
    ``check`` picks the static-verification level (``repro.checks``):
    ``"off"`` — none; ``"basic"`` (default) — O(V+E) graph structural rules
    at compile time; ``"strict"`` — additionally verify every freshly built
    host plan (schedule feasibility + plan invariants) before it runs.
    ``schedule_search`` controls the simulator-guided policy search
    (:mod:`repro.core.search`): ``"auto"`` (default) searches every
    registered policy for the min-makespan schedule once a *measured* cost
    table backs the executable (``calibrate()`` or a calibration-store
    hit); ``"force"`` searches even on analytic costs; ``"off"`` always
    schedules with ``policy``.  Winners persist in the runtime's store per
    graph signature, so the search runs once per (graph, executor config,
    cost model) across processes.
    ``pinning`` sets the bound runtime's executor-thread core pinning
    (:mod:`repro.hwperf`): ``"off"``, ``"auto"`` (pin where supported,
    silent no-op elsewhere), or ``"on"`` (pin, one warning where
    unsupported); ``None`` leaves the runtime's current mode alone.
    """
    captured: CapturedGraph | None = None
    if isinstance(target, CapturedGraph):
        if specs:
            raise TypeError("compile(captured_graph) takes no input specs "
                            "(they were fixed at capture time)")
        captured, graph = target, target.graph
    elif isinstance(target, Graph):
        if specs:
            raise TypeError("compile(graph) takes no input specs")
        graph = target
    else:
        captured = capture(target, *specs, name=name, fuse=fuse)
        graph = captured.graph
    if jit_nodes:
        graph = _jit_graph(graph)
    if runtime is None and pool is None:
        runtime = default_runtime()
    if pinning is not None and runtime is not None:
        runtime.set_pinning(pinning)
    signature = graph_signature(graph, variant="jit" if jit_nodes else "")
    exe = Executable(
        graph,
        hw,
        captured=captured,
        backend=backend,
        policy=policy,
        n_workers=n_workers,
        reserved_workers=reserved_workers,
        n_executors=n_executors,
        team_size=team_size,
        mesh=mesh,
        pool=pool,
        host_mode=host_mode,
        runtime=runtime,
        signature=signature,
        check=check,
        schedule_search=schedule_search,
    )
    if runtime is not None:
        costs = runtime.calibration.get(signature)
        if costs is not None:
            # a prior calibrate() of this graph (this process or a saved
            # store): schedules and plans start from measured costs
            exe._measured = lambda _team, _costs=costs: _costs
    return exe


def _jit_graph(graph: Graph) -> Graph:
    """A copy of ``graph`` with every node ``fn`` wrapped in ``jax.jit``.

    A copy, not an in-place rewrite: callers may hand ``compile`` a graph
    they still execute directly (the capture oracle, parity tests), and
    re-compiling must not stack ``jit`` wrappers.
    """
    import jax
    from dataclasses import replace

    out = Graph(graph.name)
    for name in graph.names:
        node = graph[name]
        out.add(replace(node, fn=jax.jit(node.fn) if node.fn is not None else None))
    return out


def serve_engine(
    cfg: Any,
    params: Any,
    serve_cfg: Any = None,
    *,
    continuous: bool = True,
    paged: Any = False,
    **kw: Any,
) -> Any:
    """Serve-shaped entry point: a serving engine over ``repro.compile``.

    ``continuous=True`` (default) returns the
    :class:`~repro.serve.engine.ContinuousEngine` — prefill and decode
    captured as graphi Executables, a profiler-chosen executor config, and
    per-request slot admission.  ``continuous=False`` returns the
    length-bucketed wave :class:`~repro.serve.engine.ServeEngine`.
    ``paged=True`` (or a :class:`~repro.serve.paged.PagedConfig`) returns
    the :class:`~repro.serve.paged.PagedEngine` instead — block-paged KV
    with prefix sharing and chunked prefill (attention-only archs).
    Extra kwargs go to the engine constructor — ``rng_seed=`` for any
    engine; ``hw=``, ``max_executors=``, ``pool=``, ``runtime=`` (the
    :class:`~repro.runtime.Runtime` whose executors the engine leases per
    step; defaults to the process-wide one), and ``decode_host_mode=``
    ("static" default: the fixed decode graph runs a compiled host plan)
    are continuous/paged-only.
    """
    from repro.serve.engine import ContinuousEngine, ServeConfig, ServeEngine
    from repro.serve.paged import PagedConfig, PagedEngine

    scfg = serve_cfg if serve_cfg is not None else ServeConfig()
    if paged:
        pcfg = paged if isinstance(paged, PagedConfig) else None
        return PagedEngine(cfg, params, scfg, paged=pcfg, **kw)
    eng_cls = ContinuousEngine if continuous else ServeEngine
    return eng_cls(cfg, params, scfg, **kw)
