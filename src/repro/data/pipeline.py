"""Deterministic synthetic token pipeline (sharded, prefetched).

Fault-tolerance contract: batches are a pure function of ``(seed, step)`` —
no iterator state — so a trainer restarted from a step-k checkpoint consumes
exactly the token stream it would have seen without the failure, on any host
count (each host slices its rows from the same global batch).

The default generator is a noisy bigram chain over the vocab: structured
enough that an LM's loss visibly descends within a few hundred steps (the
end-to-end example's acceptance check), stochastic enough that it cannot be
memorized to zero loss.
"""
from __future__ import annotations

import logging
import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np

_log = logging.getLogger(__name__)

IGNORE = -1

__all__ = ["DataConfig", "SyntheticTokens", "Prefetcher", "make_pipeline"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "bigram"      # bigram | uniform | copy
    bigram_noise: float = 0.1


class SyntheticTokens:
    """Stateless step-indexed batch source."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(np.random.Philox(cfg.seed))
        # fixed bigram successor table + a second table for the noise mixture
        self._table = rng.integers(0, cfg.vocab_size, size=cfg.vocab_size, dtype=np.int64)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Global batch for ``step``: tokens + next-token labels [B, S]."""
        cfg = self.cfg
        rng = np.random.default_rng(np.random.Philox(key=cfg.seed + 1, counter=step))
        B, S = cfg.global_batch, cfg.seq_len
        if cfg.kind == "uniform":
            toks = rng.integers(0, cfg.vocab_size, size=(B, S + 1), dtype=np.int64)
        elif cfg.kind == "copy":
            half = (S + 1) // 2 + 1
            head = rng.integers(0, cfg.vocab_size, size=(B, half), dtype=np.int64)
            toks = np.concatenate([head, head], axis=1)[:, : S + 1]
        elif cfg.kind == "bigram":
            toks = np.empty((B, S + 1), dtype=np.int64)
            toks[:, 0] = rng.integers(0, cfg.vocab_size, size=B)
            noise = rng.random((B, S)) < cfg.bigram_noise
            randoms = rng.integers(0, cfg.vocab_size, size=(B, S), dtype=np.int64)
            for t in range(S):
                nxt = self._table[toks[:, t]]
                toks[:, t + 1] = np.where(noise[:, t], randoms[:, t], nxt)
        else:
            raise ValueError(f"unknown data kind {self.cfg.kind!r}")
        tokens = toks[:, :S].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def host_batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict[str, np.ndarray]:
        """This host's row-slice of the global batch (multi-controller)."""
        g = self.batch(step)
        B = self.cfg.global_batch
        if B % n_hosts != 0:
            raise ValueError(
                f"global batch {B} not divisible by {n_hosts} hosts")
        per = B // n_hosts
        lo = host_id * per
        return {k: v[lo : lo + per] for k, v in g.items()}

    def stream(self, start_step: int = 0) -> Iterator[dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a step-indexed source (depth-bounded).

    The TPU input pipeline analogue: host CPU builds batch k+1..k+depth while
    the device runs step k.  ``get(step)`` preserves the stateless contract —
    out-of-order or repeated requests (restart!) fall back to direct calls.
    """

    def __init__(self, source: SyntheticTokens, start_step: int = 0, depth: int = 2):
        self.source = source
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next_to_produce = start_step
        self._stop = threading.Event()
        self._stage = "starting"      # what the producer is doing right now
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        while not self._stop.is_set():
            step = self._next_to_produce
            self._stage = f"generate(step={step})"
            batch = self.source.batch(step)
            self._next_to_produce = step + 1
            self._stage = f"enqueue(step={step})"
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
        self._stage = "stopped"

    def get(self, step: int) -> dict[str, np.ndarray]:
        while True:
            try:
                s, batch = self._q.get_nowait()
            except queue.Empty:
                return self.source.batch(step)
            if s == step:
                return batch
            if s > step:          # restart to an earlier step: direct call
                return self.source.batch(step)
            # s < step: stale entry (skipped ahead) — drop and keep draining

    def close(self, timeout: float = 2.0) -> None:
        """Stop the producer and join it.  A producer that fails to exit
        within ``timeout`` (e.g. a wedged generator) is abandoned — it is a
        daemon thread — but close names the stage it is stuck in rather than
        returning silently, so leaks are attributable."""
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            _log.warning(
                "Prefetcher.close: producer thread did not exit within "
                "%.1fs — stuck in %s; abandoning daemon thread",
                timeout, self._stage)


def make_pipeline(cfg: DataConfig, start_step: int = 0, prefetch: int = 2):
    src = SyntheticTokens(cfg)
    return Prefetcher(src, start_step=start_step, depth=prefetch) if prefetch else src
