"""Graphi reproduction: scheduling computation graphs of deep-learning
models, grown onto JAX/Pallas SPMD meshes.

Public surface (lazily resolved so ``import repro`` stays cheap and never
imports jax before entry points set their ``XLA_FLAGS``)::

    import repro
    rt = repro.Runtime()                     # or rely on repro.default_runtime()
    exe = rt.compile(fn, *specs)             # capture -> plan -> run on leases
    exe = repro.compile(fn, *specs)          # same, via the process default
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    # the redesigned public API (repro.api / repro.runtime)
    "compile": "repro.api",
    "Executable": "repro.api",
    "serve_engine": "repro.api",
    "Runtime": "repro.runtime",
    "default_runtime": "repro.runtime",
    "set_default_runtime": "repro.runtime",
    "CalibrationStore": "repro.runtime",
    "ExecutorLease": "repro.runtime",
    "graph_signature": "repro.runtime",
    "AdmissionRejected": "repro.runtime",
    "DeadlineExceeded": "repro.core.engine",
    # the multi-replica serving fleet (supervised worker processes)
    "Fleet": "repro.fleet.supervisor",
    "FleetConfig": "repro.fleet.supervisor",
    "FleetRequest": "repro.fleet.supervisor",
    "FaultSpec": "repro.fleet.faults",
    # capture + graph IR
    "capture": "repro.core.capture",
    "CapturedGraph": "repro.core.capture",
    "Graph": "repro.core.graph",
    "OpNode": "repro.core.graph",
    "GraphValidationError": "repro.core.graph",
    # hardware models + planning artifacts
    "HardwareModel": "repro.core.cost_model",
    "KNL7250": "repro.core.cost_model",
    "TPUV5E": "repro.core.cost_model",
    "ProfileResult": "repro.core.profiler",
    "Schedule": "repro.core.scheduler",
    "SimConfig": "repro.core.simulate",
    "SimResult": "repro.core.simulate",
    "simulate": "repro.core.simulate",
    # host runtimes
    "ExecutorPool": "repro.core.engine",
    "HostScheduler": "repro.core.engine",
    "HostRunResult": "repro.core.engine",
    # compiled static host plans (host_mode="static")
    "StaticHostPlan": "repro.core.static_host",
    "compile_host_plan": "repro.core.static_host",
    # measured hardware performance (topology, pinning, interference)
    "CpuTopology": "repro.hwperf",
    "detect_topology": "repro.hwperf",
    "ContentionModel": "repro.hwperf",
    "measure_interference": "repro.hwperf",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(__all__))
