"""The pjit train step: loss -> grad -> AdamW, with microbatch gradient
accumulation (``lax.scan``) and per-layer remat.

State layout (a flat dict so dist/sharding.state_pspecs can rule-match):

    {"params": ..., "m": ..., "v": ..., "step": i32[]}

Microbatching reshapes every batch leaf [B, ...] -> [n_micro, B/n_micro, ...]
and accumulates fp32 grads across a scan — the standard pod-scale recipe for
fitting large global batches; it also bounds activation memory to one
microbatch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import api as model_api
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import linear_warmup_cosine

__all__ = [
    "TrainStepConfig",
    "init_train_state",
    "make_train_step",
    "lm_loss_fn",
    "compile_lm_loss",
]


@dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    remat: bool = True
    adamw: AdamWConfig = field(default_factory=AdamWConfig)
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_dtype: Any = jnp.float32    # accumulation dtype


def init_train_state(cfg: ModelConfig, key, adamw_cfg: AdamWConfig | None = None) -> dict:
    from repro.models import transformer

    params = transformer.init_params(cfg, key)
    opt = adamw_init(params, adamw_cfg)
    return {"params": params, **opt}


def lm_loss_fn(model_cfg: ModelConfig, *, remat: bool = False) -> Callable:
    """The scalar LM loss as a plain ``(params, batch) -> loss`` callable —
    the capture target for ``repro.api.compile``."""

    def loss(params, batch):
        return model_api.lm_loss(model_cfg, params, batch, remat=remat)[0]

    loss.__name__ = f"{model_cfg.name}.lm_loss"
    return loss


def compile_lm_loss(
    model_cfg: ModelConfig,
    shape: ShapeSpec,
    *,
    hw=None,
    backend: str = "host",
    remat: bool = False,
    grad: bool = False,
    unroll_layers: bool = True,
    runtime=None,
    **kw: Any,
):
    """``repro.api.compile`` the loss graph of a model at an input shape.

    Captures on abstract specs (no allocation); ``unroll_layers`` disables
    ``lax.scan`` over layers so the scheduler sees the per-layer operator
    DAG (leave it off to call the executable with real scanned params).
    ``grad=True`` captures ``value_and_grad`` instead — the paper's "one
    complete execution = one training iteration" graph.  ``runtime`` binds
    the executable to a shared :class:`repro.Runtime` (the process default
    otherwise), so a train step run next to a serve engine leases executors
    from — and shares calibration with — the same session.
    """
    from repro import api as graphi
    from repro.core import KNL7250
    from repro.models import transformer

    cfg = model_cfg.reduced(scan_layers=False) if unroll_layers else model_cfg
    fn = lm_loss_fn(cfg, remat=remat)
    if grad:
        fn = jax.value_and_grad(fn)
    params_spec = jax.eval_shape(lambda k: transformer.init_params(cfg, k), jax.random.key(0))
    batch_spec = model_api.input_specs(cfg, shape, kind="train")
    return graphi.compile(
        fn, params_spec, batch_spec,
        hw=hw or KNL7250, backend=backend, runtime=runtime,
        name=f"{cfg.name}.lm_loss" + ("+grad" if grad else ""),
        **kw,
    )


def make_train_step(
    model_cfg: ModelConfig, tcfg: TrainStepConfig | None = None
) -> Callable[[dict, dict], tuple[dict, dict]]:
    tcfg = tcfg or TrainStepConfig()

    def loss_fn(params, mb):
        loss, parts = model_api.lm_loss(model_cfg, params, mb, remat=tcfg.remat)
        return loss, parts

    def grads_of(params, batch):
        n = tcfg.microbatches
        if n == 1:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(tcfg.grad_dtype), grads)
            return grads, loss, parts

        def split(x):
            b = x.shape[0]
            if b % n != 0:
                raise ValueError(
                    f"batch {b} not divisible by microbatches {n}")
            return x.reshape((n, b // n) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def acc_step(carry, mb):
            g_acc, loss_acc, ce_acc, aux_acc = carry
            (loss, parts), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(tcfg.grad_dtype), g_acc, g
            )
            return (g_acc, loss_acc + loss, ce_acc + parts["ce"], aux_acc + parts["aux"]), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, tcfg.grad_dtype), params)
        z = jnp.zeros((), jnp.float32)
        (g, loss, ce, aux), _ = jax.lax.scan(acc_step, (g0, z, z, z), micro)
        inv = 1.0 / n
        grads = jax.tree.map(lambda x: x * inv, g)
        return grads, loss * inv, {"ce": ce * inv, "aux": aux * inv}

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        grads, loss, parts = grads_of(params, batch)
        lr = linear_warmup_cosine(
            state["step"] + 1, tcfg.adamw.lr, tcfg.warmup_steps, tcfg.total_steps
        )
        opt_state = {"m": state["m"], "v": state["v"], "step": state["step"]}
        new_params, new_opt, om = adamw_update(grads, params, opt_state, tcfg.adamw, lr=lr)
        new_state = {"params": new_params, **new_opt}
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"], **om}
        return new_state, metrics

    return train_step
