"""Fault-tolerant training loop: checkpoint/restart, straggler watchdog,
failure injection, elastic re-mesh on restore.

The loop's recovery contract (tested in tests/test_trainer.py):

* any exception inside a step (injected or real — a down-node manifests as a
  failed collective) rolls the loop back to the last published checkpoint;
  the data pipeline is stateless-by-step, so the replayed token stream is
  byte-identical to the no-failure run;
* checkpoints are atomic (see checkpoint/store.py), so a crash *during* a
  save can't corrupt the restore point;
* restore accepts a different mesh than the one that saved (elastic
  re-scaling): leaves are full arrays, re-device_put under the new specs.

The straggler watchdog EWMAs the step wall-time; a step slower than
``straggler_factor`` x EWMA is recorded and reported to ``on_straggler``
(at pod scale: the hook re-balances microbatch counts or evicts the slow
host; on this box the tests assert detection fires).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from repro.checkpoint import CheckpointManager

__all__ = ["TrainerConfig", "Trainer", "TrainReport"]


@dataclass(frozen=True)
class TrainerConfig:
    total_steps: int
    checkpoint_every: int = 50
    log_every: int = 10
    max_restarts: int = 8
    straggler_alpha: float = 0.3      # EWMA smoothing
    straggler_factor: float = 2.5     # threshold multiple
    straggler_warmup: int = 3         # steps before the watchdog arms


@dataclass
class TrainReport:
    history: list[dict] = field(default_factory=list)
    restarts: int = 0
    stragglers: list[int] = field(default_factory=list)
    steps_run: int = 0

    @property
    def final_loss(self) -> float | None:
        for rec in reversed(self.history):
            if "loss" in rec:
                return rec["loss"]
        return None


class Trainer:
    def __init__(
        self,
        step_fn: Callable[[Any, Any], tuple[Any, dict]],
        state: Any,
        batch_for_step: Callable[[int], Any],
        cfg: TrainerConfig,
        *,
        checkpoint: CheckpointManager | None = None,
        fault_hook: Callable[[int], None] | None = None,
        on_straggler: Callable[[int, float], None] | None = None,
        scheduled_makespan: float | None = None,
    ):
        self.step_fn = step_fn
        self.state = state
        self.batch_for_step = batch_for_step
        self.cfg = cfg
        self.ckpt = checkpoint
        self.fault_hook = fault_hook
        self.on_straggler = on_straggler
        # Graphi-modelled makespan of the captured loss graph (see
        # train/step.py::compile_lm_loss) — reported next to wall-clock so
        # logs show how far the real step sits from the scheduled bound
        self.scheduled_makespan = scheduled_makespan
        self._template = jax.tree.map(lambda x: x, state)  # structure snapshot

    # -- recovery ------------------------------------------------------------
    def _restore(self) -> int:
        """Roll back to the latest checkpoint; returns the step to resume at."""
        if self.ckpt is None:
            raise RuntimeError("recovery needs a checkpoint store")
        self.ckpt.wait()
        latest = self.ckpt.latest()
        if latest is None:
            raise RuntimeError("step failed before any checkpoint existed")
        _, self.state = self.ckpt.restore(self._template, step=latest)
        return latest

    # -- main loop -----------------------------------------------------------
    def run(self, start_step: int | None = None) -> TrainReport:
        cfg = self.cfg
        report = TrainReport()

        step = start_step if start_step is not None else 0
        if start_step is None and self.ckpt is not None:
            latest = self.ckpt.latest()
            if latest is not None:
                _, self.state = self.ckpt.restore(self._template, step=latest)
                step = latest

        ewma: float | None = None
        while step < cfg.total_steps:
            # the timer covers batch fetch too: a slow host stalls its input
            # pipeline as often as its compute, and both must trip the watchdog.
            # Fetch errors are NOT node faults, though — a deterministic data
            # bug must surface immediately, not burn max_restarts replays.
            t0 = time.perf_counter()
            batch = self.batch_for_step(step)
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                new_state, metrics = self.step_fn(self.state, batch)
                new_state = jax.block_until_ready(new_state)
            except Exception as e:  # noqa: BLE001 — any failure = node fault
                if self.ckpt is None:
                    raise  # no recovery point: surface the real error
                report.restarts += 1
                if report.restarts > cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={cfg.max_restarts}"
                    ) from e
                step = self._restore()
                report.history.append({"step": step, "event": "restart",
                                       "error": type(e).__name__})
                continue
            self.state = new_state
            dt = time.perf_counter() - t0

            # straggler watchdog
            if ewma is not None and report.steps_run >= cfg.straggler_warmup:
                if dt > cfg.straggler_factor * ewma:
                    report.stragglers.append(step)
                    if self.on_straggler is not None:
                        self.on_straggler(step, dt / ewma)
            ewma = dt if ewma is None else (
                cfg.straggler_alpha * dt + (1 - cfg.straggler_alpha) * ewma
            )

            report.steps_run += 1
            step += 1
            if step % cfg.log_every == 0 or step == cfg.total_steps:
                rec = {"step": step, "time_s": dt}
                if self.scheduled_makespan is not None:
                    rec["graphi_makespan_s"] = self.scheduled_makespan
                for k, v in metrics.items():
                    try:
                        rec[k] = float(v)
                    except (TypeError, ValueError):
                        pass
                report.history.append(rec)
            if self.ckpt is not None and step % cfg.checkpoint_every == 0:
                self.ckpt.save(step, self.state)
        if self.ckpt is not None:
            self.ckpt.wait()
        return report
