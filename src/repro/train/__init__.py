"""Training: step functions (pjit) and the fault-tolerant trainer loop."""
from .step import TrainStepConfig, init_train_state, make_train_step

__all__ = ["TrainStepConfig", "init_train_state", "make_train_step"]
