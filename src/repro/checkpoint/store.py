"""Atomic, keep-N, mesh-agnostic checkpoints.

Layout: ``<dir>/step_<k>/state.npz`` (flattened pytree, '/'-joined keys)
plus ``meta.json``; a checkpoint directory is **atomically** published via
``os.rename`` of a ``.tmp`` staging dir — a crash mid-save never corrupts
the latest restorable step (the fault-injection test kills saves midway).

Mesh-agnostic restore: leaves are stored as full (unsharded) numpy arrays,
so a run restarted on a *different* mesh/devices count just device_puts each
leaf with the new sharding — elastic re-scaling (DESIGN.md §9).  On a real
multi-host pod the same layout is written per-process for the process's
addressable shards; this box has one process, so full arrays are exact.

``CheckpointManager`` adds async save (background thread; ``wait()`` joins)
and keep-N pruning.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "latest_step",
    "restore_state",
    "CheckpointManager",
]

_SEP = "/"


def _flatten(state: Any) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Returns (arrays, extended-dtype map).  numpy's npz cannot serialize
    ml_dtypes extension types (bfloat16, fp8); they are stored as raw-bit
    views with the true dtype recorded in meta.json."""
    flat, exts = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":      # extension dtype (bf16, fp8…)
            exts[key] = arr.dtype.name
            arr = arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,))
        flat[key] = arr
    return flat, exts


def _path_str(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


def save_checkpoint(directory: str, step: int, state: Any, *, keep: int | None = None) -> str:
    """Write ``state`` for ``step``; returns the published path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, exts = _flatten(state)
    np.savez(os.path.join(tmp, "state.npz"), **flat)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(flat), "ext_dtypes": exts}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    if keep is not None:
        prune(directory, keep)
    return final


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "meta.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def prune(directory: str, keep: int) -> None:
    steps = list_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def load_checkpoint(directory: str, step: int | None = None) -> tuple[int, dict[str, np.ndarray]]:
    """Load the flat array dict for ``step`` (default: latest)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    base = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(base, "meta.json")) as f:
        meta = json.load(f)
    exts = meta.get("ext_dtypes", {})
    with np.load(os.path.join(base, "state.npz")) as z:
        flat = {k: z[k] for k in z.files}
    if exts:
        import ml_dtypes

        for k, dtype_name in exts.items():
            dt = np.dtype(getattr(ml_dtypes, dtype_name))
            # stored as uint8 with a trailing itemsize axis (see _flatten)
            flat[k] = flat[k].view(dt)[..., 0]
    return step, flat


def restore_state(template: Any, flat: dict[str, np.ndarray], *, shardings: Any = None) -> Any:
    """Rebuild the pytree of ``template`` from a flat dict.

    ``shardings``: optional matching pytree of NamedSharding — each leaf is
    device_put with its sharding (the elastic re-mesh path: full arrays
    reshard onto whatever mesh is current).
    """
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = [_SEP.join(_path_str(p) for p in path) for path, _ in paths]
    missing = [k for k in keys if k not in flat]
    if missing:
        raise KeyError(f"checkpoint missing {len(missing)} leaves, e.g. {missing[:3]}")
    leaves = [flat[k] for k in keys]
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding)
        )
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, shard_leaves)]
    else:
        tmpl_leaves = [l for _, l in paths]
        leaves = [
            jax.numpy.asarray(l, dtype=getattr(t, "dtype", None))
            for l, t in zip(leaves, tmpl_leaves)
        ]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """keep-N manager with optional async (background-thread) saves."""

    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def save(self, step: int, state: Any) -> None:
        # snapshot to host memory *before* handing to the thread so ongoing
        # donation/updates can't mutate what we write
        flat_state = jax.tree.map(lambda x: np.asarray(x), state)
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=save_checkpoint,
                args=(self.directory, step, flat_state),
                kwargs={"keep": self.keep},
                daemon=True,
            )
            self._thread.start()
        else:
            save_checkpoint(self.directory, step, flat_state, keep=self.keep)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest(self) -> int | None:
        return latest_step(self.directory)

    def restore(self, template: Any, *, step: int | None = None, shardings: Any = None) -> tuple[int, Any]:
        step, flat = load_checkpoint(self.directory, step)
        return step, restore_state(template, flat, shardings=shardings)
