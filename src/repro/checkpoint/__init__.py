from .store import (
    CheckpointManager,
    latest_step,
    load_checkpoint,
    restore_state,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "latest_step",
    "load_checkpoint",
    "restore_state",
    "save_checkpoint",
]
