"""Fleet worker process: one replica's serve loop.

Importable without jax — the default engine is a pure-stdlib deterministic
toy (next token is a pure function of ``prompt + emitted``), so fleet tests
and the bench spawn workers in well under a second.  Real engines
(:class:`~repro.serve.engine.ContinuousEngine` /
:class:`~repro.serve.paged.PagedEngine`) are built lazily inside the child
process when the fleet is configured with ``engine="continuous"|"paged"``.

Protocol (dicts over a duplex ``multiprocessing.Pipe``):

supervisor -> worker
    ``{"type": "submit", "rid", "prompt", "max_new", "emitted"}``
        start (or *resume* — ``emitted`` is the token prefix already
        streamed by a previous replica) decoding a request
    ``{"type": "cancel", "rid"}``          drop an in-flight request
    ``{"type": "stall", "seconds"}``       fault: block the loop (wedge)
    ``{"type": "mute", "seconds"}``        fault: keep working, stop heartbeats
    ``{"type": "die"}``                    fault: exit without cleanup
    ``{"type": "shutdown"}``               orderly exit

worker -> supervisor
    ``{"type": "ready", "pid"}``           engine built, serving
    ``{"type": "hb", "inflight", "done_tokens"}``  liveness beacon
    ``{"type": "tokens", "items": [(rid, token, index, done), ...]}``
        one decode step's worth of tokens (batched: one pickle round per
        step, not per token)

Heartbeats are sent from the *main* serve loop — never a side thread — so a
wedged engine (hung op, deadlocked pool) goes silent and the supervisor's
liveness deadline fires.  Determinism contract: decoding is greedy, so the
token at ``index`` depends only on ``prompt + emitted[:index]``; a resumed
request continues bit-exactly on any replica.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field


# ---------------------------------------------------------------------------
# toy engine: deterministic, service-time bound, zero heavy imports
# ---------------------------------------------------------------------------

def toy_next_token(prompt, emitted, vocab_size: int, *, seed: int = 0) -> int:
    """Pure next-token function: a keyed multiplicative hash of the full
    context.  Deterministic across processes and platforms (no floats, no
    RNG state), so a resumed request reproduces the original stream."""
    h = 0x811C9DC5 ^ (seed & 0xFFFFFFFF)
    for t in prompt:
        h = ((h ^ int(t)) * 0x01000193) & 0xFFFFFFFF
    for t in emitted:
        h = ((h ^ int(t)) * 0x01000193) & 0xFFFFFFFF
    return h % max(2, vocab_size)


@dataclass
class _ToyTask:
    rid: int
    prompt: tuple
    max_new: int
    emitted: list = field(default_factory=list)


class ToyEngine:
    """Deterministic single-token-per-step engine.

    Each step decodes one token for every in-flight request and sleeps
    ``service_time_s`` once (the batch is 'fused'), modelling a replica
    whose step cost is service-time bound — which is also what makes fleet
    throughput scale on a box with fewer cores than replicas."""

    def __init__(self, vocab_size: int = 256, service_time_s: float = 0.0,
                 seed: int = 0):
        self.vocab_size = vocab_size
        self.service_time_s = service_time_s
        self.seed = seed
        self._tasks: dict[int, _ToyTask] = {}

    def submit(self, rid: int, prompt, max_new: int, emitted=()) -> None:
        self._tasks[rid] = _ToyTask(rid, tuple(prompt), max_new, list(emitted))

    def cancel(self, rid: int) -> None:
        self._tasks.pop(rid, None)

    @property
    def has_work(self) -> bool:
        return bool(self._tasks)

    def step(self):
        """One decode step -> [(rid, token, index, done)] for every task."""
        if not self._tasks:
            return []
        if self.service_time_s > 0:
            time.sleep(self.service_time_s)
        out = []
        for task in list(self._tasks.values()):
            tok = toy_next_token(task.prompt, task.emitted, self.vocab_size,
                                 seed=self.seed)
            idx = len(task.emitted)
            task.emitted.append(tok)
            done = len(task.emitted) >= task.max_new
            if done:
                del self._tasks[task.rid]
            out.append((task.rid, tok, idx, done))
        return out


# ---------------------------------------------------------------------------
# real-engine adapter (lazy jax import; only inside the child process)
# ---------------------------------------------------------------------------

class RealEngineAdapter:
    """Wraps ContinuousEngine/PagedEngine behind the incremental
    submit/step interface the worker loop drives.

    Resume: a request with ``emitted`` tokens already streamed is replayed
    as ``prompt' = prompt + emitted`` with budget ``max_new - len(emitted)``
    — greedy decode makes the continuation bit-identical to what the
    original replica would have produced."""

    def __init__(self, engine_kind: str, arch: str, *, smoke: bool = True,
                 max_batch: int = 4, max_len: int = 256,
                 reduced_vocab: int | None = None, seed: int = 0,
                 calibration_store: str | None = None,
                 engine_kwargs: dict | None = None):
        import jax  # noqa: PLC0415 — deliberate lazy import (child only)

        from repro.configs.base import get_config
        from repro.models import transformer
        from repro.serve.engine import Request, ServeConfig

        cfg = get_config(arch, smoke=smoke)
        if reduced_vocab:
            cfg = cfg.reduced(vocab_size=reduced_vocab)
        params = transformer.init_params(cfg, jax.random.key(seed))
        scfg = ServeConfig(max_batch=max_batch, max_len=max_len,
                           temperature=0.0)
        kw = dict(engine_kwargs or {})
        if calibration_store and "runtime" not in kw:
            # all replicas share one JSON calibration store, so the first
            # worker's schedule search warms every later (re)spawn
            from repro.runtime import Runtime
            kw["runtime"] = Runtime(calibration_path=calibration_store)
        if engine_kind == "paged":
            from repro.serve.paged import PagedConfig, PagedEngine
            pcfg = PagedConfig(page_size=kw.pop("page_size", 16),
                               n_pages=kw.pop("n_pages", None),
                               prefill_chunk=kw.pop("prefill_chunk", 64))
            self.engine = PagedEngine(cfg, params, scfg, paged=pcfg, **kw)
        else:
            from repro.serve.engine import ContinuousEngine
            self.engine = ContinuousEngine(cfg, params, scfg, **kw)
        self.vocab_size = cfg.vocab_size
        self._Request = Request
        self._live: dict[int, tuple] = {}   # rid -> (req, base_emitted, n_seen)

    def submit(self, rid: int, prompt, max_new: int, emitted=()) -> None:
        import numpy as np

        emitted = list(emitted)
        full = np.asarray(list(prompt) + emitted, np.int32)
        budget = max_new - len(emitted)
        if budget <= 0:
            return
        req = self._Request(request_id=rid, prompt=full, max_new_tokens=budget)
        self._live[rid] = (req, emitted, 0)
        self.engine.submit(req)

    def cancel(self, rid: int) -> None:
        self._live.pop(rid, None)

    @property
    def has_work(self) -> bool:
        return bool(self._live) and self.engine.has_work

    def step(self):
        if not self.engine.has_work:
            return []
        self.engine.step()
        out = []
        for rid, (req, base, seen) in list(self._live.items()):
            new = req.output[seen:]
            for j, tok in enumerate(new):
                out.append((rid, int(tok), len(base) + seen + j, False))
            seen += len(new)
            if req.done:
                del self._live[rid]
                if out and out[-1][0] == rid:
                    r, t, i, _ = out[-1]
                    out[-1] = (r, t, i, True)
                else:
                    out.append((rid, -1, -1, True))
            else:
                self._live[rid] = (req, base, seen)
        return out


def build_engine(spec: dict):
    """Engine factory from a picklable spec dict (``kind`` selects)."""
    kind = spec.get("kind", "toy")
    if kind == "toy":
        return ToyEngine(vocab_size=spec.get("vocab_size", 256),
                         service_time_s=spec.get("service_time_s", 0.0),
                         seed=spec.get("seed", 0))
    return RealEngineAdapter(
        kind, spec["arch"], smoke=spec.get("smoke", True),
        max_batch=spec.get("max_batch", 4), max_len=spec.get("max_len", 256),
        reduced_vocab=spec.get("reduced_vocab"), seed=spec.get("seed", 0),
        calibration_store=spec.get("calibration_store"),
        engine_kwargs=spec.get("engine_kwargs"))


# ---------------------------------------------------------------------------
# the serve loop (process entrypoint)
# ---------------------------------------------------------------------------

def worker_main(worker_id: int, conn, engine_spec: dict,
                heartbeat_s: float = 0.1) -> None:
    """Entry point of a fleet worker process (spawn target).

    Drives the engine one step at a time, streaming every token as it is
    decoded; idle polls block briefly on the pipe so a quiet worker costs
    ~0 CPU.  Heartbeats ride the main loop by design (see module docs)."""
    engine = build_engine(engine_spec)
    conn.send({"type": "ready", "pid": os.getpid()})
    last_hb = 0.0
    mute_until = 0.0
    done_tokens = 0
    inflight = 0

    muted_buf: list[dict] = []

    def send(msg: dict) -> None:
        # the mute fault silences the worker *entirely* (tokens included)
        # while it keeps decoding: a live-but-unreachable replica.  A mute
        # longer than the liveness deadline gets the worker failed over and
        # its requests replayed elsewhere; a shorter blip flushes the
        # buffered stream in order (pipe = reliable transport), so token
        # indices stay contiguous either way.
        if time.monotonic() < mute_until:
            muted_buf.append(msg)
            return
        while muted_buf:
            conn.send(muted_buf.pop(0))
        conn.send(msg)

    def heartbeat(now: float) -> None:
        nonlocal last_hb
        if now - last_hb >= heartbeat_s:
            send({"type": "hb", "inflight": inflight,
                  "done_tokens": done_tokens})
            last_hb = now

    while True:
        # control plane: drain everything pending; block briefly when idle
        while conn.poll(0.0 if engine.has_work else heartbeat_s / 2):
            msg = conn.recv()
            kind = msg["type"]
            if kind == "submit":
                engine.submit(msg["rid"], msg["prompt"], msg["max_new"],
                              msg.get("emitted", ()))
                inflight += 1
            elif kind == "cancel":
                engine.cancel(msg["rid"])
                inflight = max(0, inflight - 1)
            elif kind == "stall":
                time.sleep(msg["seconds"])      # wedge: heartbeats stop
            elif kind == "mute":
                mute_until = time.monotonic() + msg["seconds"]
            elif kind == "die":
                os._exit(17)                    # crash, no cleanup
            elif kind == "shutdown":
                conn.close()
                return

        now = time.monotonic()
        heartbeat(now)
        if not engine.has_work:
            continue
        events = engine.step()
        if events:
            # one message per step, not per token: on small hosts the
            # pickle round-trip dominates the toy service time otherwise
            send({"type": "tokens", "items": events})
            done_tokens += sum(1 for _, _, idx, _ in events if idx >= 0)
            inflight = max(0, inflight - sum(1 for *_, d in events if d))
        heartbeat(time.monotonic())
