"""Request router: prefix affinity first, load second.

The router is pure bookkeeping — it never touches pipes or processes — so
it is unit-testable without a fleet and deterministic given the same call
sequence.  Affinity uses the same notion of "shareable prefix" as the
paged engine's prefix cache: the first ``affinity_len`` prompt tokens,
hashed.  A replica that has already prefilled that prefix serves a new
request with it faster (shared pages / warm calibration), so the router
prefers it unless the load gap to the least-loaded replica exceeds
``max_load_gap`` in-flight requests — affinity must never create a hotspot.
"""
from __future__ import annotations

import hashlib
from collections import defaultdict

__all__ = ["Router"]


def _prefix_key(prompt, affinity_len: int) -> str:
    head = bytes(int(t) & 0xFF for t in list(prompt)[:affinity_len])
    return hashlib.blake2s(head, digest_size=8).hexdigest()


class Router:
    def __init__(self, *, affinity_len: int = 16, max_load_gap: int = 2):
        self.affinity_len = affinity_len
        self.max_load_gap = max_load_gap
        self._prefixes: dict[int, set[str]] = defaultdict(set)
        self._load: dict[int, int] = defaultdict(int)
        self.n_affinity_hits = 0
        self.n_routed = 0

    # -- lifecycle events fed by the supervisor -----------------------------
    def add_worker(self, wid: int) -> None:
        self._load.setdefault(wid, 0)
        self._prefixes.setdefault(wid, set())

    def remove_worker(self, wid: int) -> None:
        """A replica died: its prefix cache is gone and its in-flight load
        is meaningless — drop both (requeued requests re-route fresh)."""
        self._prefixes.pop(wid, None)
        self._load.pop(wid, None)

    def note_done(self, wid: int) -> None:
        if wid in self._load and self._load[wid] > 0:
            self._load[wid] -= 1

    # -- the decision -------------------------------------------------------
    def pick(self, prompt, *, capacity: dict[int, int]) -> int | None:
        """Choose a worker id for ``prompt``.

        ``capacity`` maps worker id -> remaining admission slots; workers at
        zero are skipped.  Returns None when every replica is full (caller
        keeps the request queued).  Deterministic: ties break on worker id.
        """
        live = sorted(w for w, c in capacity.items() if c > 0 and w in self._load)
        if not live:
            return None
        key = _prefix_key(prompt, self.affinity_len)
        least = min(self._load[w] for w in live)
        chosen = None
        for w in live:
            if key in self._prefixes[w] and (
                    self._load[w] - least <= self.max_load_gap):
                chosen = w
                self.n_affinity_hits += 1
                break
        if chosen is None:
            chosen = min(live, key=lambda w: (self._load[w], w))
        self._load[chosen] += 1
        self._prefixes[chosen].add(key)
        self.n_routed += 1
        return chosen
