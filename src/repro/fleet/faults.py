"""Seeded fault injection for the fleet (and the in-process runtime).

Every injector is deterministic given ``seed``: which worker dies, and
when, is a pure function of the spec — so a failing fault drill replays
exactly under ``pytest -k`` with the same seed.

Fleet-level faults (driven by :meth:`FaultInjector.tick` from the
supervisor loop):

``kill``            SIGKILL the victim process (crash mid-decode)
``die``             victim exits abruptly from inside its loop
``stall``           victim's serve loop blocks for ``duration_s`` (wedge:
                    heartbeats stop, liveness deadline fires)
``mute``            victim keeps decoding but drops heartbeats (tests that
                    a live-but-silent replica is still failed over and its
                    requests replay bit-exactly)

Runtime-level fault:

:func:`corrupt_lease_release` double-releases / cross-releases a lease and
returns the runtime's health counters — the admission layer must absorb the
corruption (idempotent release, no double-free) rather than corrupt its
free list.
"""
from __future__ import annotations

import random
from dataclasses import dataclass

__all__ = ["FaultSpec", "FaultInjector", "corrupt_lease_release"]


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``kind``: kill | die | stall | mute.
    ``at_tokens``: fire once the fleet has streamed this many tokens in
    total — "mid-decode" by construction (0 fires immediately).
    ``worker``: victim id, or None to pick one seeded-uniformly among
    workers that currently hold in-flight requests (falling back to any).
    ``duration_s``: stall/mute length.
    """
    kind: str = "kill"
    at_tokens: int = 1
    worker: int | None = None
    duration_s: float = 1.0


class FaultInjector:
    """Ticks alongside :meth:`Fleet.pump`; fires each spec exactly once."""

    def __init__(self, specs, *, seed: int = 0):
        self.specs = list(specs)
        self._rng = random.Random(seed)
        self._fired = [False] * len(self.specs)
        self.log: list[tuple[str, int, int]] = []  # (kind, worker, at_tokens)
        self._tokens = 0
        self._hooked = False

    def _hook(self, fleet) -> None:
        if self._hooked:
            return
        self._hooked = True
        prev = fleet.on_token

        def count(rid, token, index):
            self._tokens += 1
            if prev is not None:
                prev(rid, token, index)

        fleet.on_token = count

    def _victim(self, fleet, spec: FaultSpec) -> int | None:
        if spec.worker is not None:
            return spec.worker if spec.worker in fleet._workers else None
        busy = sorted(w.wid for w in fleet._workers.values() if w.inflight)
        pool = busy or sorted(fleet._workers)
        return self._rng.choice(pool) if pool else None

    def tick(self, fleet) -> None:
        self._hook(fleet)
        for i, spec in enumerate(self.specs):
            if self._fired[i] or self._tokens < spec.at_tokens:
                continue
            wid = self._victim(fleet, spec)
            if wid is None:
                continue
            self._fired[i] = True
            self.log.append((spec.kind, wid, self._tokens))
            if spec.kind == "kill":
                fleet.kill_worker(wid)
            elif spec.kind == "die":
                fleet.send_fault(wid, {"type": "die"})
            elif spec.kind == "stall":
                fleet.send_fault(wid, {"type": "stall",
                                       "seconds": spec.duration_s})
            elif spec.kind == "mute":
                fleet.send_fault(wid, {"type": "mute",
                                       "seconds": spec.duration_s})
            else:
                raise ValueError(f"unknown fault kind {spec.kind!r}")

    @property
    def all_fired(self) -> bool:
        return all(self._fired)


def corrupt_lease_release(runtime, *, width: int = 1) -> dict:
    """Runtime-level fault: release a lease twice, then release executor
    ids that were never leased.  Returns ``runtime.health()`` after the
    abuse; the admission layer counts the bad releases instead of
    corrupting its free list (asserted by the stress tests)."""
    lease = runtime.lease(width)
    ids = lease.executor_ids
    lease.release()
    lease.release()                      # double release: must be a no-op
    runtime._admission.release(ids)      # stale ids: already free
    health = runtime.health()
    # the pool must still be fully usable afterwards
    probe = runtime.lease(width)
    probe.release()
    return health
