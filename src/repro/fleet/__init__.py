"""Fault-tolerant multi-replica serving fleet.

``Fleet`` supervises N worker processes (each one serve replica), routes
requests by prefix affinity + load, detects crashed *and* wedged replicas
via main-loop heartbeats, and replays in-flight requests on healthy
replicas bit-exactly (greedy decode of ``prompt + emitted``).  See
:mod:`repro.fleet.supervisor` for the failure model and
:mod:`repro.fleet.faults` for the seeded fault-injection harness.
"""
from repro.fleet.faults import FaultInjector, FaultSpec, corrupt_lease_release
from repro.fleet.router import Router
from repro.fleet.supervisor import Fleet, FleetConfig, FleetRequest
from repro.fleet.worker import ToyEngine, build_engine, toy_next_token, worker_main

__all__ = [
    "Fleet", "FleetConfig", "FleetRequest", "Router",
    "FaultInjector", "FaultSpec", "corrupt_lease_release",
    "ToyEngine", "build_engine", "toy_next_token", "worker_main",
]
