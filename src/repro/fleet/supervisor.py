"""Fleet supervisor: N replica processes, one router, zero lost requests.

The supervisor owns every worker process (spawn context — each child is a
fresh interpreter, so a crashed replica cannot corrupt the parent) and a
duplex pipe per worker.  It is **single-threaded**: :meth:`pump` dispatches
queued requests, drains worker pipes, and enforces liveness deadlines, so
fleet behaviour is deterministic under test and there are no locks to get
wrong.  Callers either drive :meth:`pump` themselves or use :meth:`run`.

Failure handling — the tentpole contract:

* a worker whose process exits (crash, SIGKILL fault) is detected on the
  next pump via ``Process.is_alive`` / pipe EOF;
* a worker whose process is alive but **silent** past the liveness
  deadline (wedged op, stalled loop, muted heartbeats) is SIGTERMed, given
  ``term_grace_s``, then SIGKILLed;
* either way, its in-flight requests are requeued at the *front* of the
  pending queue with the tokens they already streamed, and replayed on a
  healthy replica as ``prompt + emitted`` — greedy decoding makes the
  resumed stream bit-identical, which :meth:`_on_token` asserts by index;
* the dead slot respawns with a bumped generation (bounded by
  ``max_restarts``), and the router forgets its prefix affinity.
"""
from __future__ import annotations

import itertools
import logging
import multiprocessing as mp
import os
import signal
import time
from collections import deque
from dataclasses import dataclass, field
from multiprocessing.connection import wait as conn_wait

from repro.fleet.router import Router
from repro.fleet.worker import worker_main

_log = logging.getLogger(__name__)

__all__ = ["Fleet", "FleetConfig", "FleetRequest"]


@dataclass(frozen=True)
class FleetConfig:
    """Knobs for a :class:`Fleet`.  ``engine`` is the picklable spec passed
    to :func:`repro.fleet.worker.build_engine` in each child."""
    n_workers: int = 2
    engine: dict = field(default_factory=lambda: {"kind": "toy",
                                                  "vocab_size": 256})
    heartbeat_s: float = 0.05
    liveness_s: float | None = None        # default: 10 * heartbeat_s
    startup_grace_s: float = 60.0          # real engines compile at boot
    term_grace_s: float = 0.5              # SIGTERM -> SIGKILL escalation
    max_inflight_per_worker: int = 4
    affinity_len: int = 16
    max_load_gap: int = 2
    max_restarts: int = 8                  # total respawns across the fleet
    seed: int = 0

    @property
    def effective_liveness_s(self) -> float:
        return self.liveness_s if self.liveness_s is not None \
            else 10.0 * self.heartbeat_s


@dataclass
class FleetRequest:
    rid: int
    prompt: tuple
    max_new: int
    tokens: list = field(default_factory=list)
    done: bool = False
    worker: int | None = None       # current (or last) replica
    n_requeues: int = 0
    _order: int = 0


class _Worker:
    def __init__(self, wid: int, proc, conn, generation: int):
        self.wid = wid
        self.proc = proc
        self.conn = conn
        self.generation = generation
        self.ready = False
        self.last_msg = time.monotonic()
        self.inflight: dict[int, FleetRequest] = {}


class Fleet:
    """Supervised multi-replica serving tier (see module docs)."""

    def __init__(self, cfg: FleetConfig | None = None, **overrides):
        if cfg is None:
            cfg = FleetConfig(**overrides)
        elif overrides:
            raise TypeError("pass FleetConfig or kwargs, not both")
        self.cfg = cfg
        self.router = Router(affinity_len=cfg.affinity_len,
                             max_load_gap=cfg.max_load_gap)
        self._ctx = mp.get_context("spawn")
        self._rid = itertools.count()
        self._workers: dict[int, _Worker] = {}
        self._pending: deque[FleetRequest] = deque()
        self._requests: dict[int, FleetRequest] = {}
        self.completed: list[FleetRequest] = []
        self.events: list[tuple[float, str, int, str]] = []  # (t, kind, wid, why)
        self.n_failovers = 0
        self.n_requeued = 0
        self.n_restarts = 0
        self.on_token = None          # optional (rid, token, index) hook
        self._t0 = time.monotonic()
        self._closed = False
        for wid in range(cfg.n_workers):
            self._spawn(wid, generation=0)

    # -- lifecycle -----------------------------------------------------------
    def _spawn(self, wid: int, *, generation: int) -> None:
        parent, child = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(
            target=worker_main,
            args=(wid, child, self.cfg.engine, self.cfg.heartbeat_s),
            name=f"fleet-worker-{wid}.g{generation}", daemon=True)
        proc.start()
        child.close()
        self._workers[wid] = _Worker(wid, proc, parent, generation)
        self.router.add_worker(wid)
        self._event("spawn", wid, f"generation {generation}")

    def _event(self, kind: str, wid: int, why: str) -> None:
        self.events.append((time.monotonic() - self._t0, kind, wid, why))

    # -- client surface ------------------------------------------------------
    def submit(self, prompt, max_new: int) -> int:
        rid = next(self._rid)
        req = FleetRequest(rid=rid, prompt=tuple(int(t) for t in prompt),
                           max_new=max_new, _order=rid)
        self._requests[rid] = req
        self._pending.append(req)
        return rid

    @property
    def has_work(self) -> bool:
        return bool(self._pending) or any(
            w.inflight for w in self._workers.values())

    def wait_ready(self, timeout_s: float | None = None) -> None:
        """Block until every replica has sent ``ready`` (benches call this
        so spawn/compile time stays out of the measured window)."""
        deadline = time.monotonic() + (
            timeout_s if timeout_s is not None else self.cfg.startup_grace_s)
        while not all(w.ready for w in self._workers.values()):
            if time.monotonic() > deadline:
                slow = [w.wid for w in self._workers.values() if not w.ready]
                raise TimeoutError(f"workers {slow} not ready in time")
            self.pump(timeout=0.05)

    def pump(self, timeout: float = 0.02) -> None:
        """One supervisor iteration: dispatch, drain pipes, enforce
        liveness.  ``timeout`` bounds the pipe wait when nothing is ready."""
        self._dispatch()
        self._poll(timeout)
        self._check_liveness()

    def run(self, requests=None, *, injector=None,
            timeout_s: float = 300.0) -> list[FleetRequest]:
        """Drain all submitted (plus ``requests``) and return them in
        submit order.  ``injector`` is ticked every pump (see faults)."""
        for prompt, max_new in requests or []:
            self.submit(prompt, max_new)
        deadline = time.monotonic() + timeout_s
        while self.has_work:
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"fleet did not drain within {timeout_s}s "
                    f"(pending={len(self._pending)}, "
                    f"inflight={sum(len(w.inflight) for w in self._workers.values())})")
            self.pump()
            if injector is not None:
                injector.tick(self)
        done = sorted(self.completed, key=lambda r: r._order)
        self.completed = []
        return done

    def stats(self) -> dict:
        return {
            "n_workers": len(self._workers),
            "generations": {w.wid: w.generation
                            for w in self._workers.values()},
            "n_failovers": self.n_failovers,
            "n_requeued": self.n_requeued,
            "n_restarts": self.n_restarts,
            "router_affinity_hits": self.router.n_affinity_hits,
            "router_routed": self.router.n_routed,
        }

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for w in self._workers.values():
            try:
                w.conn.send({"type": "shutdown"})
            except (BrokenPipeError, OSError):
                pass
        for w in self._workers.values():
            w.proc.join(timeout=self.cfg.term_grace_s)
            if w.proc.is_alive():
                w.proc.kill()
                w.proc.join(timeout=1.0)
            w.conn.close()
        self._workers.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- fault-injection surface (used by repro.fleet.faults) ---------------
    def send_fault(self, wid: int, msg: dict) -> None:
        w = self._workers.get(wid)
        if w is not None:
            try:
                w.conn.send(msg)
            except (BrokenPipeError, OSError):
                pass

    def kill_worker(self, wid: int) -> None:
        """SIGKILL a replica outright (crash fault)."""
        w = self._workers.get(wid)
        if w is not None and w.proc.is_alive():
            os.kill(w.proc.pid, signal.SIGKILL)

    def worker_inflight(self, wid: int) -> list[FleetRequest]:
        w = self._workers.get(wid)
        return list(w.inflight.values()) if w else []

    # -- internals -----------------------------------------------------------
    def _dispatch(self) -> None:
        while self._pending:
            capacity = {
                w.wid: self.cfg.max_inflight_per_worker - len(w.inflight)
                for w in self._workers.values()
                if w.ready and w.proc.is_alive()
            }
            req = self._pending[0]
            wid = self.router.pick(req.prompt, capacity=capacity)
            if wid is None:
                return
            self._pending.popleft()
            req.worker = wid
            w = self._workers[wid]
            w.inflight[req.rid] = req
            try:
                w.conn.send({"type": "submit", "rid": req.rid,
                             "prompt": list(req.prompt),
                             "max_new": req.max_new,
                             "emitted": list(req.tokens)})
            except (BrokenPipeError, OSError):
                # worker died between liveness checks; fail it now — the
                # request (still in its inflight map) gets requeued
                self._fail(wid, "pipe closed on dispatch")
                return

    def _poll(self, timeout: float) -> None:
        conns = {w.conn: w for w in self._workers.values()}
        if not conns:
            return
        for conn in conn_wait(list(conns), timeout=timeout):
            w = conns[conn]
            try:
                while conn.poll(0):
                    self._handle(w, conn.recv())
            except (EOFError, BrokenPipeError, OSError):
                self._fail(w.wid, "pipe EOF")

    def _handle(self, w: _Worker, msg: dict) -> None:
        w.last_msg = time.monotonic()
        kind = msg["type"]
        if kind == "ready":
            w.ready = True
            self._event("ready", w.wid, f"pid {msg['pid']}")
        elif kind == "hb":
            pass
        elif kind == "tokens":
            for rid, token, index, done in msg["items"]:
                if index >= 0:
                    self._on_token(w, rid, token, index)
                if done:
                    req = w.inflight.pop(rid, None)
                    if req is not None:
                        req.done = True
                        self.completed.append(req)
                        self.router.note_done(w.wid)
        else:
            _log.warning("fleet: unknown message %r from worker %d",
                         kind, w.wid)

    def _on_token(self, w: _Worker, rid: int, token: int, idx: int) -> None:
        req = w.inflight.get(rid)
        if req is None:          # token for a request already requeued away
            return
        if idx != len(req.tokens):
            raise AssertionError(
                f"request {req.rid}: worker {w.wid} emitted token index "
                f"{idx}, expected {len(req.tokens)} — replay is not "
                "contiguous (determinism contract broken)")
        req.tokens.append(token)
        if self.on_token is not None:
            self.on_token(req.rid, token, idx)

    def _check_liveness(self) -> None:
        now = time.monotonic()
        for w in list(self._workers.values()):
            if w.proc.exitcode is not None:
                self._fail(w.wid, f"process exited ({w.proc.exitcode})")
                continue
            deadline = (self.cfg.effective_liveness_s if w.ready
                        else self.cfg.startup_grace_s)
            if now - w.last_msg > deadline:
                self._fail(w.wid, f"silent for {now - w.last_msg:.2f}s "
                                  f"(liveness {deadline:.2f}s)")

    def _fail(self, wid: int, why: str) -> None:
        """Declare a replica dead: reap it, requeue its work, respawn."""
        w = self._workers.pop(wid, None)
        if w is None:
            return
        self.n_failovers += 1
        self._event("fail", wid, why)
        _log.warning("fleet: worker %d failed (%s); requeueing %d request(s)",
                     wid, why, len(w.inflight))
        # best-effort drain: tokens already in the pipe shrink the replay
        try:
            while w.conn.poll(0):
                self._handle(w, w.conn.recv())
        except (EOFError, BrokenPipeError, OSError):
            pass
        if w.proc.is_alive():
            w.proc.terminate()                     # SIGTERM
            w.proc.join(timeout=self.cfg.term_grace_s)
            if w.proc.is_alive():
                w.proc.kill()                      # SIGKILL after grace
                w.proc.join(timeout=1.0)
                self._event("sigkill", wid, "term grace expired")
        try:
            w.conn.close()
        except OSError:
            pass
        self.router.remove_worker(wid)
        # requeue in submit order at the front so failed-over requests do
        # not starve behind the backlog
        victims = sorted(w.inflight.values(), key=lambda r: r._order)
        for req in reversed(victims):
            req.worker = None
            req.n_requeues += 1
            self._pending.appendleft(req)
        self.n_requeued += len(victims)
        if self.n_restarts < self.cfg.max_restarts:
            self.n_restarts += 1
            self._spawn(wid, generation=w.generation + 1)
        elif not self._workers:
            raise RuntimeError(
                f"fleet: every replica is dead and the restart budget "
                f"({self.cfg.max_restarts}) is spent (last failure: {why})")
