"""Sharded AdamW (decoupled weight decay) with global-norm clipping.

Pure functions over pytrees: moments inherit the parameter sharding (the
state specs in dist/sharding.py map them through the same rules), so the
optimizer is ZeRO-0 by default; ZeRO-3-style sharding over the data axis is
a spec change, not a code change (param_pspecs/state_pspecs fsdp=True).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # moments dtype: fp32 master moments regardless of param dtype
    moment_dtype: Any = jnp.float32


def adamw_init(params: Any, cfg: AdamWConfig | None = None) -> dict:
    cfg = cfg or AdamWConfig()
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def _decayable(path) -> bool:
    """Weight decay applies to matrices, not to norms/biases/1-d gains."""
    for e in reversed(path):
        if isinstance(e, jax.tree_util.DictKey):
            name = str(e.key)
            return not (name.startswith("ln") or name in (
                "final_norm", "enc_norm", "conv_b", "dt_bias", "lam", "D", "b"
            ))
    return True


def adamw_update(
    grads: Any,
    params: Any,
    opt_state: dict,
    cfg: AdamWConfig | None = None,
    lr: jax.Array | float | None = None,
) -> tuple[Any, dict, dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, metrics)."""
    cfg = cfg or AdamWConfig()
    step = opt_state["step"] + 1
    lr_t = jnp.asarray(cfg.lr if lr is None else lr, jnp.float32)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, g, p, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * g
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and _decayable(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
        return p_new, m_new.astype(cfg.moment_dtype), v_new.astype(cfg.moment_dtype)

    flat = jax.tree_util.tree_map_with_path(
        lambda path, g, p, m, v: upd(path, g, p, m, v),
        grads, params, opt_state["m"], opt_state["v"],
    )
    # unzip the (p, m, v) leaf tuples
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr_t, "clip_scale": scale}
    return new_params, new_state, metrics
