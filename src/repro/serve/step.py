"""Serving step functions (the ``serve_step`` the decode/long shapes lower)
and the shared next-token sampling used by both engines.

``decode`` shapes lower ONE new token against a KV cache of ``seq_len`` —
the memory-bandwidth-bound regime; caches are sequence-sharded over the
model axis (dist/sharding.cache_pspecs) so MQA archs scale too.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer

__all__ = [
    "make_prefill_step",
    "make_decode_step",
    "make_paged_decode_step",
    "make_prefill_chunk_step",
    "mask_pad_vocab",
    "sample_tokens",
]


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, cache, batch):
        return transformer.prefill(cfg, params, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, tokens):
        logits, cache = transformer.decode_step(cfg, params, tokens, cache)
        return logits, cache

    return decode_step


def make_paged_decode_step(cfg: ModelConfig, page_size: int) -> Callable:
    """Batched decode over the block-paged KV cache: each batch row reads and
    writes physical pages through its page-table row (``cache["table"]``);
    rows whose tail page is unmapped scatter out of bounds and are dropped."""

    def paged_decode_step(params, cache, tokens):
        return transformer.paged_decode_step(cfg, params, tokens, cache,
                                             page_size=page_size)

    return paged_decode_step


def make_prefill_chunk_step(cfg: ModelConfig, page_size: int) -> Callable:
    """One page-aligned prompt chunk of a single request: reads context K/V
    from the pools (strictly below ``start``), returns the chunk's K/V
    *without writing* — the engine scatters it in afterwards, so this graph
    can run concurrently with the decode step's pool writes."""

    def prefill_chunk_step(params, pages, table_row, batch, start, valid_len):
        return transformer.paged_prefill_chunk(
            cfg, params, batch["tokens"], pages, table_row, start, valid_len,
            page_size=page_size)

    return prefill_chunk_step


def mask_pad_vocab(logits: jax.Array, vocab_size: int) -> jax.Array:
    """-inf the padded-vocab tail of ``logits[..., vocab_size:]``.

    The model's unembedding spans ``cfg.padded_vocab`` columns (Megatron
    sharding padding) and the ``vocab_size..padded_vocab`` region carries
    *random initialized weight* — without this mask both greedy argmax and
    temperature sampling can emit token ids that do not exist.
    """
    if logits.shape[-1] <= vocab_size:
        return logits
    mask = jnp.arange(logits.shape[-1]) >= vocab_size
    return jnp.where(mask, -jnp.inf, logits)


def sample_tokens(
    logits: jax.Array,
    vocab_size: int,
    temperature: float,
    key: jax.Array | None = None,
) -> jax.Array:
    """Next-token ids from last-position logits ``[..., padded_vocab]``.

    Greedy argmax at ``temperature == 0``, else categorical — both over the
    pad-masked vocabulary, so every emitted id is ``< vocab_size``.
    """
    logits = mask_pad_vocab(logits, vocab_size)
    if temperature > 0:
        if key is None:
            raise ValueError("temperature sampling needs a PRNG key")
        return jax.random.categorical(key, logits / temperature, axis=-1)
    return jnp.argmax(logits, axis=-1)
