"""Serving step functions (the ``serve_step`` the decode/long shapes lower).

``decode`` shapes lower ONE new token against a KV cache of ``seq_len`` —
the memory-bandwidth-bound regime; caches are sequence-sharded over the
model axis (dist/sharding.cache_pspecs) so MQA archs scale too.
"""
from __future__ import annotations

from typing import Callable

from repro.configs.base import ModelConfig
from repro.models import transformer

__all__ = ["make_prefill_step", "make_decode_step"]


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, cache, batch):
        return transformer.prefill(cfg, params, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, tokens):
        logits, cache = transformer.decode_step(cfg, params, tokens, cache)
        return logits, cache

    return decode_step
