"""Block-paged KV serving: a global page pool, prefix sharing, chunked prefill.

:class:`PagedEngine` replaces the per-slot fixed-stride KV cache of
:class:`~repro.serve.engine.ContinuousEngine` with a **paged** cache
(``transformer.init_paged_cache``): each layer's K/V lives in a global pool
of ``n_pages`` physical pages of ``page_size`` tokens, and each request slot
owns a host-side *page table* mapping logical page indices to physical
pages.  Three things fall out:

* **Memory proportional to live tokens** — a slot holds
  ``ceil(len/page_size)`` pages instead of a full ``max_len`` stripe, so
  mixed-length workloads pack far more requests into the same bytes
  (``pool.peak_used`` measures it).
* **Prefix sharing** — :class:`PagePool` registers completed pages under a
  hash of the token prefix they encode.  A new request whose prompt starts
  with an already-cached prefix *maps the same physical pages* (refcounted,
  read-only) and prefills only the tail; a prompt diverging mid-page gets a
  **copy-on-write** clone of the best partially-matching page
  (``transformer.paged_copy_page``) and recomputes from the divergence
  point.  Pages whose refcount drops to zero are kept as *cold* prefix
  cache (LRU) and reclaimed on demand.
* **Chunked prefill** — prompts prefill in page-aligned chunks, one chunk
  per engine step, overlapped with the in-flight decode on the same
  :class:`~repro.runtime.ExecutorLease`.  A long prompt therefore never
  monopolizes a step: decode latency for active slots — and
  admission-to-first-token for *other* pending prompts — stays bounded by
  the chunk size, not by the longest prompt in flight.  The chunk graph is
  read-only over the pools (``transformer.paged_prefill_chunk`` returns the
  chunk's K/V; the engine scatters it in afterwards), so it coexists with
  the decode step's page writes without aliasing.

Under **pool exhaustion** the allocator first reclaims cold (refcount-zero)
registered pages, oldest first; if the pool is still full the engine evicts
the *youngest* in-flight request (lowest priority under FCFS), frees its
pages, and requeues it at the front of the pending queue — its prompt
*plus everything it already emitted* are recomputed via chunked prefill on
re-admission, so its token stream continues exactly where it stopped
(greedy decoding is deterministic).

Decode and chunk-prefill graphs are captured via ``repro.api.compile``
exactly like the per-slot engine's: profiler-chosen executor config, decode
replayed through a compiled static host plan on steady-state steps, dynamic
scheduling on steps with chunks in flight.  The per-slot
:class:`ContinuousEngine` remains the parity reference
(tests/test_serve_paged.py asserts bit-identical token streams).
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict, deque
from contextlib import nullcontext
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import KNL7250, HardwareModel
from repro.core.engine import ExecutorPool
from repro.models import transformer
from repro.runtime import Runtime, default_runtime
from repro.serve.engine import Request, ServeConfig, _SamplerMixin, _validate_submit
from repro.serve.step import (make_paged_decode_step, make_prefill_chunk_step,
                              sample_tokens)

__all__ = ["PagedConfig", "PagePool", "PagedEngine", "PoolExhausted"]


class PoolExhausted(RuntimeError):
    """No free or reclaimable-cold page left in the pool."""


@dataclass(frozen=True)
class PagedConfig:
    page_size: int = 16
    n_pages: int | None = None     # default: max_batch * ceil(max_len/page_size)
    prefill_chunk: int = 64        # tokens per admission chunk (rounded up to
                                   # a page multiple)
    share_prefix: bool = True


class PagePool:
    """Host-side physical page allocator with a token-prefix registry.

    A page is *registered* once the tokens it encodes are known (at prefill
    completion): full pages under ``sha1(prompt[:end])`` for exact
    whole-page matching, and every registered page additionally under its
    *base* hash ``sha1(prompt[:start])`` together with its token list, so a
    later prompt sharing the base but diverging mid-page can find the best
    partial match for copy-on-write.

    Refcounts track how many request slots map a page.  ``release`` of a
    registered page keeps it as **cold** prefix cache (LRU-ordered) rather
    than freeing it; ``alloc`` reclaims the coldest such page when the free
    list runs dry, and raises :class:`PoolExhausted` only when nothing is
    reclaimable — the engine then evicts a whole request.
    """

    def __init__(self, n_pages: int, page_size: int):
        self.n_pages = n_pages
        self.page_size = page_size
        self.free: deque[int] = deque(range(n_pages))
        self.ref = np.zeros(n_pages, np.int64)
        self.full_map: dict[bytes, int] = {}     # full-prefix digest -> page
        self.by_base: dict[bytes, dict[int, tuple]] = {}
        self.meta: dict[int, tuple] = {}         # page -> (full_key, base_key)
        self.cold: OrderedDict[int, None] = OrderedDict()
        # peak *hot* pages — mapped by at least one live request; cold
        # refcount-zero prefix cache is reclaimable on demand and therefore
        # not memory pressure
        self.peak_used = 0
        self.n_cold_reclaims = 0

    def used(self) -> int:
        return self.n_pages - len(self.free)

    def hot(self) -> int:
        return self.used() - len(self.cold)

    def _note_usage(self) -> None:
        self.peak_used = max(self.peak_used, self.hot())

    @staticmethod
    def _digest(tokens) -> bytes:
        return hashlib.sha1(np.asarray(tokens, np.int32).tobytes()).digest()

    def alloc(self) -> int:
        """A fresh page with refcount 1; reclaims the LRU cold page when the
        free list is empty."""
        if not self.free and self.cold:
            pid, _ = self.cold.popitem(last=False)
            self._unregister(pid)
            self.free.append(pid)
            self.n_cold_reclaims += 1
        if not self.free:
            raise PoolExhausted(
                f"all {self.n_pages} pages mapped by live requests")
        pid = self.free.popleft()
        self.ref[pid] = 1
        self._note_usage()
        return pid

    def share(self, pid: int) -> None:
        """Map an already-resident page into one more slot (read-only)."""
        if self.ref[pid] == 0:
            self.cold.pop(pid, None)             # cold -> hot again
        self.ref[pid] += 1
        self._note_usage()

    def release(self, pid: int) -> None:
        self.ref[pid] -= 1
        if self.ref[pid] < 0:
            raise RuntimeError(f"page {pid} over-released")
        if self.ref[pid] == 0:
            if pid in self.meta:
                self.cold[pid] = None            # keep as cold prefix cache
            else:
                self.free.append(pid)

    def register(self, pid: int, tokens, start: int, ntok: int) -> None:
        """Publish ``pid`` as encoding ``tokens[start:start+ntok]`` of the
        prefix ``tokens[:start+ntok]`` (no-op if already published, or if an
        identical full page exists)."""
        if pid in self.meta:
            return
        base_key = self._digest(tokens[:start])
        full_key = None
        if ntok == self.page_size:
            full_key = self._digest(tokens[:start + ntok])
            if full_key in self.full_map:
                return                           # duplicate content
            self.full_map[full_key] = pid
        page_toks = tuple(int(t) for t in tokens[start:start + ntok])
        self.by_base.setdefault(base_key, {})[pid] = page_toks
        self.meta[pid] = (full_key, base_key)

    def _unregister(self, pid: int) -> None:
        full_key, base_key = self.meta.pop(pid)
        if full_key is not None and self.full_map.get(full_key) == pid:
            del self.full_map[full_key]
        grp = self.by_base.get(base_key)
        if grp is not None:
            grp.pop(pid, None)
            if not grp:
                del self.by_base[base_key]

    def match_prefix(self, tokens, limit: int):
        """Longest registered prefix of ``tokens[:limit]``.

        Returns ``(full_pages, partial)``: physical ids of whole-page
        matches, then the best partially-matching page past them as
        ``(pid, n_common)`` (or None) — the caller shares the former and
        copy-on-writes the latter.  ``limit`` caps how many positions may be
        reused (at least the last prompt token must be *computed* so its
        logits exist)."""
        ps = self.page_size
        full: list[int] = []
        pos = 0
        while pos + ps <= limit:
            pid = self.full_map.get(self._digest(tokens[:pos + ps]))
            if pid is None:
                break
            full.append(pid)
            pos += ps
        best = None
        for pid, ptoks in self.by_base.get(self._digest(tokens[:pos]), {}).items():
            n = 0
            for a, b in zip(ptoks[:limit - pos], tokens[pos:]):
                if int(a) != int(b):
                    break
                n += 1
            if n > 0 and (best is None or n > best[1]):
                best = (pid, n)
        return full, best


class _PrefillTask:
    """A request whose prompt (plus any previously emitted tokens, on
    re-admission after eviction) is being prefilled chunk by chunk."""

    __slots__ = ("req", "tokens", "pos", "total")

    def __init__(self, req: Request, tokens: np.ndarray, pos: int):
        self.req = req
        self.tokens = tokens
        self.pos = pos
        self.total = len(tokens)


class PagedEngine(_SamplerMixin):
    """Continuous batching over a block-paged KV cache (module docstring).

    Protocol per :meth:`step`:

    1. **admit** — pending requests claim free slots; prefix-matching pages
       are shared/CoW'd into their tables and a chunked-prefill task starts;
    2. **allocate** — each in-flight chunk's pages, plus a fresh tail page
       for any decoding slot crossing a page boundary (evicting cold pages,
       then whole younger requests, on exhaustion);
    3. **run** — one decode step over active slots concurrently with one
       prefill chunk per in-flight task, on the step's executor lease;
    4. **install** — chunk K/V scatters into the pools; a finished prefill
       registers its pages for sharing, activates its slot, and samples its
       first token from the chunk logits;
    5. **retire** — EOS/budget releases the slot's pages (refcount-zero
       registered pages stay as cold prefix cache).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        scfg: ServeConfig,
        *,
        paged: PagedConfig | None = None,
        rng_seed: int = 0,
        hw: HardwareModel = KNL7250,
        max_executors: int | None = None,
        pool: ExecutorPool | None = None,
        runtime: Runtime | None = None,
        decode_host_mode: str = "static",
        schedule_search: str = "auto",
        step_deadline_s: float | None = None,
    ):
        if not transformer.paged_supported(cfg):
            raise ValueError(
                "paged serving requires a decoder-only attention-only rope "
                f"arch (got frontend={cfg.frontend!r}, "
                f"kinds={set(cfg.layer_kinds())})")
        from repro import api

        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.pcfg = paged or PagedConfig()
        self.hw = hw
        # see ContinuousEngine: per-step graph-run deadline; None = unbounded.
        self.step_deadline_s = step_deadline_s
        self._step_deadline: float | None = None
        self._key = jax.random.key(rng_seed)
        self.capacity = scfg.max_batch
        ps = self.pcfg.page_size
        self.chunk = -(-max(ps, self.pcfg.prefill_chunk) // ps) * ps
        self.n_pt = -(-scfg.max_len // ps)
        n_pages = self.pcfg.n_pages or self.capacity * self.n_pt
        if n_pages < self.n_pt:
            raise ValueError(
                f"n_pages={n_pages} cannot hold one max_len={scfg.max_len} "
                f"request ({self.n_pt} pages of {ps})")
        cache0 = transformer.init_paged_cache(
            cfg, self.capacity, scfg.max_len, n_pages=n_pages, page_size=ps)
        self._pages = cache0["pages"]
        self._table = cache0["table"]            # np [B, n_pt], host-managed
        self._len = cache0["len"]                # np [B]
        self.page_pool = PagePool(n_pages, ps)
        hd = cfg.resolved_head_dim
        self.page_bytes = (2 * cfg.n_layers * ps * cfg.n_kv_heads * hd
                           * jnp.dtype(cfg.dtype).itemsize)

        self.pool = pool
        self.runtime = runtime if runtime is not None else (
            None if pool is not None else default_runtime())

        # -- decode graph: fixed shape, calibrated, static host plan --------
        cache_spec = {"len": jnp.zeros((self.capacity,), jnp.int32),
                      "table": jnp.full((self.capacity, self.n_pt), -1, jnp.int32),
                      "pages": self._pages}
        tok_spec = jax.ShapeDtypeStruct((self.capacity, 1), jnp.int32)
        # schedule_search="auto": a calibrated decode graph freezes the
        # simulator-searched winner (persisted per graph signature), not
        # necessarily bare CPF — token streams are unchanged (same ops, same
        # numerics; only placements move)
        self._decode_exe = api.compile(
            make_paged_decode_step(cfg, ps), params, cache_spec, tok_spec,
            hw=hw, backend="host", jit_nodes=True, host_mode=decode_host_mode,
            pool=pool, runtime=self.runtime, schedule_search=schedule_search,
            name=f"serve_paged_decode[{cfg.name}]",
        )
        self.schedule_search = schedule_search
        self.decode_host_mode = self._decode_exe.host_mode
        if self._decode_exe.calibrated:
            kw = ({"max_executors": max_executors}
                  if max_executors is not None else {})
            self.profile = self._decode_exe.profile_with(**kw)
        else:
            self.profile = self._decode_exe.calibrate(
                params, jax.tree.map(jnp.zeros_like, cache_spec),
                jnp.full((self.capacity, 1), scfg.pad_id, jnp.int32),
                max_executors=max_executors)
        n_exec = self._decode_exe.planned_executors
        if max_executors is not None:
            n_exec = max(1, min(n_exec, max_executors))
        if pool is not None:
            n_exec = min(n_exec, pool.n_executors)
        elif self.runtime is not None:
            n_exec = min(n_exec, self.runtime.n_workers)
        self.n_executors = n_exec
        self._step_lease_ids: tuple[int, ...] = ()
        if self._decode_exe.host_mode == "static":
            self._decode_exe.host_plan(n_exec)
        self._team_size = self.profile.best_team_size

        # -- chunk-prefill graph: ONE shape for every prompt length ---------
        self._chunk_exe = api.compile(
            make_prefill_chunk_step(cfg, ps), params, self._pages,
            jnp.full((self.n_pt,), -1, jnp.int32),
            {"tokens": jax.ShapeDtypeStruct((1, self.chunk), jnp.int32)},
            jnp.int32(0), jnp.int32(self.chunk),
            hw=hw, backend="host", jit_nodes=True,
            pool=pool, runtime=self.runtime, schedule_search=schedule_search,
            n_executors=self.n_executors, team_size=self._team_size,
            name=f"serve_paged_chunk[{cfg.name},T={self.chunk}]",
        )

        # host-side page maintenance, jitted once with traced indices
        self._insert_chunk = jax.jit(
            lambda pages, row, start, valid, kc, vc:
            transformer.paged_insert_chunk(cfg, pages, row, start, valid,
                                           kc, vc, page_size=ps))
        self._copy_page = jax.jit(
            lambda pages, src, dst:
            transformer.paged_copy_page(cfg, pages, src, dst))

        self.slots: list[Request | None] = [None] * self.capacity
        self.prefills: dict[int, _PrefillTask] = {}
        self.pending: deque[Request] = deque()
        self.completed: list[Request] = []
        self._tokens = np.full((self.capacity, 1), scfg.pad_id, np.int32)
        self._n_submitted = 0
        # loop counters (benchmarks read these)
        self.n_steps = 0
        self.n_decode_steps = 0
        self.n_chunks = 0
        self.n_overlapped_chunks = 0
        self.n_shared_pages = 0
        self.n_cow_copies = 0
        self.n_evictions = 0

        # warm every per-step code path against throwaway state
        warm_pages = jax.tree.map(jnp.zeros_like, self._pages)
        warm_cache = {"len": jnp.zeros((self.capacity,), jnp.int32),
                      "table": jnp.full((self.capacity, self.n_pt), -1, jnp.int32),
                      "pages": warm_pages}
        toks0 = jnp.asarray(self._tokens)
        with self._step_pool() as wpool:
            logits, _ = self._run_exe(
                self._decode_exe, (params, warm_cache, toks0), pool=wpool)
            if self._decode_exe.host_mode == "static":
                self._run_exe(self._decode_exe, (params, warm_cache, toks0),
                              pool=wpool, host_mode="dynamic")
            _, kc, vc = self._run_exe(
                self._chunk_exe,
                (params, warm_pages, jnp.full((self.n_pt,), -1, jnp.int32),
                 {"tokens": jnp.zeros((1, self.chunk), jnp.int32)},
                 jnp.int32(0), jnp.int32(self.chunk)),
                pool=wpool)
        sample_tokens(logits, cfg.vocab_size, scfg.temperature,
                      jax.random.key(0) if scfg.temperature > 0 else None)
        warm_pages = self._insert_chunk(
            warm_pages, jnp.full((self.n_pt,), -1, jnp.int32),
            jnp.int32(0), jnp.int32(self.chunk), kc, vc)
        warm_pages = self._copy_page(warm_pages, jnp.int32(0), jnp.int32(0))
        jax.block_until_ready(jax.tree.leaves(warm_pages)[0])

    # -- lifecycle -------------------------------------------------------------
    def close(self) -> None:
        """Nothing to release: executors are leased per step (an explicit
        ``pool`` is the caller's to close)."""

    def __enter__(self) -> "PagedEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ------------------------------------------------------------
    def submit(self, req: Request) -> None:
        _validate_submit(req, self.scfg)
        req._order = self._n_submitted
        self._n_submitted += 1
        self.pending.append(req)

    @property
    def has_work(self) -> bool:
        return (bool(self.pending) or bool(self.prefills)
                or any(s is not None for s in self.slots))

    def stats(self) -> dict:
        return {
            "n_steps": self.n_steps,
            "n_decode_steps": self.n_decode_steps,
            "n_chunks": self.n_chunks,
            "n_overlapped_chunks": self.n_overlapped_chunks,
            "n_shared_pages": self.n_shared_pages,
            "n_cow_copies": self.n_cow_copies,
            "n_evictions": self.n_evictions,
            "n_cold_reclaims": self.page_pool.n_cold_reclaims,
            "peak_pages": self.page_pool.peak_used,
            "peak_kv_bytes": int(self.page_pool.peak_used * self.page_bytes),
        }

    # -- executor plumbing (same shape as ContinuousEngine) --------------------
    def _step_pool(self):
        if self.pool is not None:
            return nullcontext(self.pool)
        lease = self.runtime.lease(self.n_executors,
                                   prefer=self._step_lease_ids)
        self._step_lease_ids = lease.executor_ids
        return lease

    def _run_exe(self, exe, args: tuple, *, pool, host_mode: str | None = None):
        res = exe.execute_host(
            exe.captured.bind(args), n_executors=self.n_executors,
            pool=pool, host_mode=host_mode, deadline=self._step_deadline,
        )
        return exe.captured.unflatten(res.outputs)

    # -- page accounting -------------------------------------------------------
    def _alloc_page(self, protect: frozenset | set) -> int:
        """A fresh physical page, evicting whole requests (youngest first,
        never one in ``protect``) when even cold reclaim cannot satisfy it."""
        while True:
            try:
                return self.page_pool.alloc()
            except PoolExhausted:
                if not self._evict_one(protect):
                    raise RuntimeError(
                        f"page pool exhausted ({self.page_pool.n_pages} pages)"
                        " with nothing evictable — pool misconfigured"
                    ) from None

    def _evict_one(self, protect) -> bool:
        cands = [(r._order, i) for i, r in enumerate(self.slots)
                 if r is not None and i not in protect]
        cands += [(t.req._order, i) for i, t in self.prefills.items()
                  if i not in protect]
        if not cands:
            return False
        _, victim = max(cands)                   # youngest request loses
        self._requeue(victim)
        self.n_evictions += 1
        return True

    def _requeue(self, slot: int) -> None:
        """Evict ``slot``'s request under memory pressure: free its pages and
        put it back at the *front* of the pending queue.  Its prompt plus
        already-emitted tokens are recomputed by chunked prefill on
        re-admission, so the output stream continues unchanged."""
        req = (self.slots[slot] if self.slots[slot] is not None
               else self.prefills[slot].req)
        self._release_slot(slot)
        self.slots[slot] = None
        self.prefills.pop(slot, None)
        self._tokens[slot, 0] = self.scfg.pad_id
        self.pending.appendleft(req)

    def _release_slot(self, slot: int) -> None:
        for pid in self._table[slot]:
            if pid >= 0:
                self.page_pool.release(int(pid))
        self._table[slot] = -1
        self._len[slot] = 0

    # -- admission -------------------------------------------------------------
    def _begin_prefill(self, req: Request, slot: int) -> None:
        tokens = np.asarray(req.prompt, np.int32)
        if req.output:                           # re-admission after eviction
            tokens = np.concatenate(
                [tokens, np.asarray(req.output, np.int32)])
        task = _PrefillTask(req, tokens, 0)
        # at least the final token must be computed (its logits seed
        # sampling), so reuse is capped one position short of the end
        limit = task.total - 1
        ps = self.pcfg.page_size
        if self.pcfg.share_prefix and limit > 0:
            full, partial = self.page_pool.match_prefix(tokens, limit)
            for j, pid in enumerate(full):
                self.page_pool.share(pid)
                self._table[slot, j] = pid
            task.pos = len(full) * ps
            self.n_shared_pages += len(full)
            if partial is not None:
                src, n_common = partial
                dst = self._alloc_page(protect={slot})
                self._pages = self._copy_page(
                    self._pages, jnp.int32(src), jnp.int32(dst))
                self._table[slot, len(full)] = dst
                task.pos += n_common
                self.n_cow_copies += 1
        self.prefills[slot] = task

    def _alloc_chunk_pages(self, slot: int, task: _PrefillTask) -> None:
        ps = self.pcfg.page_size
        T = min(self.chunk, task.total - task.pos)
        for j in range(task.pos // ps, (task.pos + T - 1) // ps + 1):
            if self._table[slot, j] < 0:
                self._table[slot, j] = self._alloc_page(protect={slot})

    def _finish_prefill(self, slot: int, task: _PrefillTask, logits) -> None:
        del self.prefills[slot]
        self._len[slot] = task.total
        ps = self.pcfg.page_size
        if self.pcfg.share_prefix:
            for j in range(-(-task.total // ps)):
                pid = int(self._table[slot, j])
                if pid >= 0:
                    self.page_pool.register(
                        pid, task.tokens, j * ps,
                        min(ps, task.total - j * ps))
        self.slots[slot] = task.req
        self._emit(slot, int(self._sample(logits)[0]))

    # -- decode / emit ---------------------------------------------------------
    def _emit(self, slot: int, token: int) -> None:
        req = self.slots[slot]
        req.output.append(token)
        hit_eos = req.eos_id is not None and token == req.eos_id
        if hit_eos or len(req.output) >= req.max_new_tokens:
            req.done = True
            self.completed.append(req)
            self.slots[slot] = None
            self._release_slot(slot)
            self._tokens[slot, 0] = self.scfg.pad_id
        else:
            self._tokens[slot, 0] = token

    def _decode_once(self, pool, *, overlapping: bool = False) -> None:
        # idle rows (free, or mid-prefill) decode against an empty table:
        # their pool writes redirect out of bounds and drop, their logits
        # are discarded
        tbl = self._table.copy()
        ln = self._len.copy()
        for i in range(self.capacity):
            if self.slots[i] is None:
                tbl[i] = -1
                ln[i] = 0
        cache = {"len": jnp.asarray(ln), "table": jnp.asarray(tbl),
                 "pages": self._pages}
        host_mode = None
        if overlapping and self._decode_exe.host_mode == "static":
            # same reasoning as ContinuousEngine: a static plan's segments
            # would serialize the concurrent chunk prefills behind the
            # decode, so overlapped steps fall back to the dynamic scheduler
            host_mode = "dynamic"
        logits, out = self._run_exe(
            self._decode_exe, (self.params, cache, jnp.asarray(self._tokens)),
            pool=pool, host_mode=host_mode)
        self._pages = out["pages"]
        self.n_decode_steps += 1
        nxt = self._sample(logits)
        for i in range(self.capacity):
            if self.slots[i] is not None:
                self._len[i] += 1
                self._emit(i, int(nxt[i]))

    def _run_chunk(self, pages_in, slot: int, start: int, valid: int,
                   toks: np.ndarray, pool):
        return self._run_exe(
            self._chunk_exe,
            (self.params, pages_in, jnp.asarray(self._table[slot]),
             {"tokens": jnp.asarray(toks)},
             jnp.int32(start), jnp.int32(valid)),
            pool=pool)

    # -- the loop --------------------------------------------------------------
    def step(self) -> bool:
        """One engine iteration: admit, allocate pages, run one decode step
        concurrently with one prefill chunk per in-flight prompt, install
        chunk K/V, retire finished requests.  Returns whether work remains."""
        self.n_steps += 1
        if self.step_deadline_s is not None:
            self._step_deadline = time.monotonic() + self.step_deadline_s
        ps = self.pcfg.page_size

        # 1. admit pending requests into free slots (prefix share / CoW)
        free = [i for i in range(self.capacity)
                if self.slots[i] is None and i not in self.prefills]
        while self.pending and free:
            self._begin_prefill(self.pending.popleft(), free.pop(0))

        # 2. allocate this step's pages: chunk spans, then decode boundary
        # pages.  Allocation may evict requests (youngest first), so re-check
        # liveness at each use.
        for slot, task in list(self.prefills.items()):
            if slot in self.prefills:
                self._alloc_chunk_pages(slot, task)
        for i in range(self.capacity):
            if (self.slots[i] is not None and self._len[i] % ps == 0
                    and self._table[i, self._len[i] // ps] < 0):
                self._table[i, self._len[i] // ps] = self._alloc_page(
                    protect={i})

        # 3. run: one chunk per surviving prefill, overlapped with decode
        jobs = []
        for slot, task in self.prefills.items():
            T = min(self.chunk, task.total - task.pos)
            toks = np.full((1, self.chunk), self.scfg.pad_id, np.int32)
            toks[0, :T] = task.tokens[task.pos:task.pos + T]
            jobs.append((slot, task, task.pos, T, toks))
        decoding = any(s is not None for s in self.slots)
        # chunks read the pre-decode page snapshot: their context mask stops
        # strictly below `start`, so the decode step's concurrent tail writes
        # can never alias what a chunk reads
        pages_in = self._pages
        results = None
        with self._step_pool() as pool:
            if jobs and decoding:
                box: dict = {}

                def chunk_worker() -> None:
                    try:
                        box["res"] = [
                            self._run_chunk(pages_in, s, p, t, tk, pool)
                            for s, _, p, t, tk in jobs]
                    except BaseException as e:  # noqa: BLE001 — re-raised below
                        box["err"] = e

                th = threading.Thread(target=chunk_worker,
                                      name="serve-paged-prefill")
                th.start()
                self._decode_once(pool, overlapping=True)
                th.join()
                if "err" in box:
                    raise box["err"]
                self.n_overlapped_chunks += len(jobs)
                results = box["res"]
            elif jobs:
                results = [self._run_chunk(pages_in, s, p, t, tk, pool)
                           for s, _, p, t, tk in jobs]
            elif decoding:
                self._decode_once(pool)

        # 4. install chunk K/V (disjoint from the decode step's writes) and
        # activate finished prefills
        if results:
            for (slot, task, start, T, _), (logits, kc, vc) in zip(jobs, results):
                self._pages = self._insert_chunk(
                    self._pages, jnp.asarray(self._table[slot]),
                    jnp.int32(start), jnp.int32(T), kc, vc)
                self.n_chunks += 1
                task.pos = start + T
                if task.pos >= task.total:
                    self._finish_prefill(slot, task, logits)
        self._step_deadline = None
        return self.has_work

    def run(self) -> list[Request]:
        """Drain pending + active requests; returns them in submit order."""
        while self.has_work:
            self.step()
        done = sorted(self.completed, key=lambda r: r._order)
        self.completed = []
        return done
