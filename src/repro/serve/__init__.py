"""Serving: prefill/decode step functions, pad-masked sampling, and the
continuous-batching + wave engines."""
from .engine import ContinuousEngine, Request, ServeConfig, ServeEngine
from .step import make_decode_step, make_prefill_step, mask_pad_vocab, sample_tokens

__all__ = [
    "ContinuousEngine",
    "Request",
    "ServeConfig",
    "ServeEngine",
    "make_decode_step",
    "make_prefill_step",
    "mask_pad_vocab",
    "sample_tokens",
]
