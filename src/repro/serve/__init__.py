"""Serving: prefill/decode step functions, pad-masked sampling, the
continuous-batching + wave engines, and the paged-KV engine."""
from .engine import ContinuousEngine, Request, ServeConfig, ServeEngine
from .paged import PagedConfig, PagedEngine, PagePool
from .step import (make_decode_step, make_paged_decode_step, make_prefill_chunk_step,
                   make_prefill_step, mask_pad_vocab, sample_tokens)

__all__ = [
    "ContinuousEngine",
    "PagedConfig",
    "PagedEngine",
    "PagePool",
    "Request",
    "ServeConfig",
    "ServeEngine",
    "make_decode_step",
    "make_paged_decode_step",
    "make_prefill_chunk_step",
    "make_prefill_step",
    "mask_pad_vocab",
    "sample_tokens",
]
