"""Serving: prefill/decode step functions and the batched engine."""
from .step import make_decode_step, make_prefill_step

__all__ = ["make_decode_step", "make_prefill_step"]
