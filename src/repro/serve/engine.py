"""Batched serving engine (length-bucketed wave batching).

Requests queue up; the engine groups them into waves of up to ``max_batch``
requests of *equal prompt length* (the KV cache's slot-position table is
shared across the batch, so mixed-length padding would let pad tokens leak
into attention — the bucketing keeps batched decode bit-identical to
unbatched, which tests/test_serve_engine.py asserts).  Each wave: one
batched prefill, then a batched greedy/temperature decode loop until every
sequence hits EOS or its token budget.  This is the throughput-oriented
regime the ``decode_*`` dry-run shapes model; latency-oriented continuous
batching would interleave prefills into the decode stream — noted as
future work in DESIGN.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer

__all__ = ["Request", "ServeConfig", "ServeEngine"]


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    # filled by the engine:
    output: list[int] = field(default_factory=list)
    done: bool = False


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    temperature: float = 0.0        # 0 => greedy
    pad_id: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig, *, rng_seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.queue: list[Request] = []
        self._key = jax.random.key(rng_seed)
        self._prefill = jax.jit(lambda p, c, b: transformer.prefill(cfg, p, b, c))
        self._decode = jax.jit(lambda p, c, t: transformer.decode_step(cfg, p, t, c))

    def submit(self, req: Request) -> None:
        assert len(req.prompt) + req.max_new_tokens <= self.scfg.max_len, "budget"
        self.queue.append(req)

    # -- one wave -------------------------------------------------------------
    def _run_wave(self, wave: Sequence[Request]) -> None:
        cfg, scfg = self.cfg, self.scfg
        B = len(wave)
        Ls = {len(r.prompt) for r in wave}
        assert len(Ls) == 1, "waves are length-bucketed"
        S = Ls.pop()
        toks = np.stack([r.prompt for r in wave]).astype(np.int32)
        cache = transformer.init_cache(cfg, B, scfg.max_len)
        logits, cache = self._prefill(self.params, cache, {"tokens": jnp.asarray(toks)})

        active = np.ones(B, bool)
        budget = np.array([r.max_new_tokens for r in wave])
        n_emitted = np.zeros(B, int)
        while active.any():
            if scfg.temperature > 0:
                self._key, sub = jax.random.split(self._key)
                nxt = jax.random.categorical(sub, logits / scfg.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt_np = np.asarray(nxt, np.int32)
            for i, r in enumerate(wave):
                if not active[i]:
                    continue
                t = int(nxt_np[i])
                r.output.append(t)
                n_emitted[i] += 1
                if (r.eos_id is not None and t == r.eos_id) or n_emitted[i] >= budget[i]:
                    active[i] = False
                    r.done = True
            if not active.any():
                break
            logits, cache = self._decode(self.params, cache, nxt_np[:, None])

    # -- public ----------------------------------------------------------------
    def run(self) -> list[Request]:
        """Drain the queue; returns completed requests in submit order."""
        buckets: dict[int, list[Request]] = {}
        for r in self.queue:
            buckets.setdefault(len(r.prompt), []).append(r)
        self.queue = []
        done: list[Request] = []
        for _, reqs in sorted(buckets.items()):
            for lo in range(0, len(reqs), self.scfg.max_batch):
                wave = reqs[lo : lo + self.scfg.max_batch]
                self._run_wave(wave)
                done.extend(wave)
        done.sort(key=lambda r: r.request_id)
        return done
