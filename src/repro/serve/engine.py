"""Serving engines: continuous batching on the graphi runtime + the wave batcher.

:class:`ContinuousEngine` — the latency-oriented engine (the regime
DESIGN.md §6 describes): a persistent decode loop over a fixed-capacity
per-slot KV cache (``transformer.init_cache(per_slot=True)``).  Each batch
row is a request *slot* at its own decode position; new requests' prefills
are admitted into free slots **between decode steps** — overlapped with the
in-flight decode on the same executors — and a finished request frees
its slot immediately on EOS/budget, so no request ever stalls on a
stranger's long prompt.  Prefill and decode are captured via
``repro.api.compile(backend="host")``; the profiler's configuration search
picks the executor count at engine construction.

The engine owns **no executor threads**: each :meth:`step` leases its
calibrated executor width from a :class:`~repro.runtime.Runtime` (the
process default unless one is passed) and runs decode + admission prefills
inside that lease, so a serve engine and a trainer — or two engines —
share one machine-sized pool with bounded interference.  An explicit
``pool=`` reproduces the old shared-pool wiring and bypasses admission.

:class:`ServeEngine` — the throughput-oriented wave batcher kept as the
baseline: requests are grouped into waves of equal prompt length, one
batched prefill, then batched decode until every member finishes.

Both engines sample over the pad-masked vocabulary
(:func:`repro.serve.step.sample_tokens`), so emitted ids are always
``< cfg.vocab_size`` even though the unembedding spans ``padded_vocab``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import KNL7250, HardwareModel
from repro.core.engine import ExecutorPool
from repro.models import transformer
from repro.runtime import Runtime, default_runtime
from repro.serve.step import make_decode_step, make_prefill_step, sample_tokens

__all__ = ["Request", "ServeConfig", "ServeEngine", "ContinuousEngine"]


def _validate_submit(req: "Request", scfg: "ServeConfig") -> None:
    """Shared submit-time validation (both engines, and the paged engine)."""
    if len(req.prompt) == 0:
        raise ValueError(f"request {req.request_id}: empty prompt")
    if req.max_new_tokens <= 0:
        raise ValueError(
            f"request {req.request_id}: max_new_tokens must be positive "
            f"(got {req.max_new_tokens})"
        )
    if len(req.prompt) + req.max_new_tokens > scfg.max_len:
        raise ValueError(
            f"request {req.request_id}: prompt ({len(req.prompt)}) + "
            f"max_new_tokens ({req.max_new_tokens}) exceeds max_len "
            f"({scfg.max_len})"
        )


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    # filled by the engine:
    output: list[int] = field(default_factory=list)
    done: bool = False
    _order: int = field(default=-1, repr=False, compare=False)


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8              # wave width / continuous slot capacity
    max_len: int = 512
    temperature: float = 0.0        # 0 => greedy
    pad_id: int = 0


class _SamplerMixin:
    """Shared pad-masked sampling (greedy or temperature) with key threading."""

    cfg: ModelConfig
    scfg: ServeConfig
    _key: jax.Array

    def _sample(self, logits) -> np.ndarray:
        key = None
        if self.scfg.temperature > 0:
            self._key, key = jax.random.split(self._key)
        toks = sample_tokens(logits, self.cfg.vocab_size, self.scfg.temperature, key)
        return np.asarray(toks, np.int32)


class ServeEngine(_SamplerMixin):
    """Length-bucketed wave batcher (the throughput baseline).

    The KV cache's slot-position table is shared across a wave, so waves are
    bucketed to *equal prompt length* — batched decode stays bit-identical
    to unbatched (tests/test_serve_engine.py).  A wave stalls on its slowest
    member; for latency under staggered arrivals use
    :class:`ContinuousEngine`.
    """

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig, *, rng_seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.queue: list[Request] = []
        self._n_submitted = 0
        self._key = jax.random.key(rng_seed)
        self._prefill = jax.jit(lambda p, c, b: transformer.prefill(cfg, p, b, c))
        self._decode = jax.jit(lambda p, c, t: transformer.decode_step(cfg, p, t, c))

    def submit(self, req: Request) -> None:
        _validate_submit(req, self.scfg)
        req._order = self._n_submitted
        self._n_submitted += 1
        self.queue.append(req)

    # -- one wave -------------------------------------------------------------
    def _run_wave(self, wave: Sequence[Request]) -> None:
        cfg, scfg = self.cfg, self.scfg
        B = len(wave)
        Ls = {len(r.prompt) for r in wave}
        if len(Ls) != 1:
            raise RuntimeError(
                f"wave mixes prompt lengths {sorted(Ls)} — waves are "
                "length-bucketed")
        toks = np.stack([r.prompt for r in wave]).astype(np.int32)
        cache = transformer.init_cache(cfg, B, scfg.max_len)
        logits, cache = self._prefill(self.params, cache, {"tokens": jnp.asarray(toks)})

        active = np.ones(B, bool)
        budget = np.array([r.max_new_tokens for r in wave])
        n_emitted = np.zeros(B, int)
        while active.any():
            nxt_np = self._sample(logits)
            for i, r in enumerate(wave):
                if not active[i]:
                    continue
                t = int(nxt_np[i])
                r.output.append(t)
                n_emitted[i] += 1
                if (r.eos_id is not None and t == r.eos_id) or n_emitted[i] >= budget[i]:
                    active[i] = False
                    r.done = True
            if not active.any():
                break
            logits, cache = self._decode(self.params, cache, nxt_np[:, None])

    # -- public ----------------------------------------------------------------
    def run(self) -> list[Request]:
        """Drain the queue; returns completed requests in submit order."""
        buckets: dict[int, list[Request]] = {}
        for r in self.queue:
            buckets.setdefault(len(r.prompt), []).append(r)
        self.queue = []
        done: list[Request] = []
        for _, reqs in sorted(buckets.items()):
            for lo in range(0, len(reqs), self.scfg.max_batch):
                wave = reqs[lo : lo + self.scfg.max_batch]
                self._run_wave(wave)
                done.extend(wave)
        done.sort(key=lambda r: r._order)
        return done


class ContinuousEngine(_SamplerMixin):
    """Continuous-batching engine driven by graphi Executables.

    Construction captures the batched decode step and *calibrates* it:
    ``Executable.calibrate`` times every node fn on the decode shapes (the
    paper's first-iterations profiling) and the §4.2 configuration search
    picks ``n_executors × team_size`` from those measured costs, optionally
    bounded by ``max_executors``.  Prefill graphs are compiled per prompt
    length on demand, pinned to the same config, and share the decode
    graph's persistent executor pool — so an admission prefill runs
    *concurrently* with the in-flight decode step.

    The decode graph is fixed — one batch shape, replayed once per token —
    so steady-state steps execute it through a compiled
    :class:`~repro.core.static_host.StaticHostPlan`
    (``decode_host_mode="static"``): frozen CPF placements, lock-free
    dependency counters, no per-op scheduler round-trip.  Everything that
    coexists with admissions stays dynamic: prefill graphs (shapes vary
    per prompt length), and the decode step itself on the steps where
    prefills are in flight — a plan's segments would hold every executor
    for the whole step, while the dynamic scheduler interleaves per-op
    with the concurrent prefills.  ``decode_host_mode="dynamic"`` restores
    the paper-faithful per-op scheduler everywhere for A/B measurement.

    Protocol per :meth:`step`:

    1. **admit** — pending requests claim free slots; their prefills run on
       the pool while the decode step for currently-active slots executes;
    2. **install** — each prefilled request's K/V lands in its slot
       (:func:`transformer.cache_insert_slot`), its first token is sampled
       from the prefill logits;
    3. **retire** — EOS/budget frees the slot immediately
       (:func:`transformer.cache_evict_slot`); the next step's admission
       fills it.

    Idle slots decode a pad token against an all-masked position table;
    their output is discarded and their cache rows are overwritten wholesale
    at the next insert, so active rows stay bit-identical to unbatched
    greedy decode (dense archs; MoE capacity routing couples batch rows and
    is only *approximately* parity-preserving, exactly as in wave batching).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        scfg: ServeConfig,
        *,
        rng_seed: int = 0,
        hw: HardwareModel = KNL7250,
        max_executors: int | None = None,
        pool: ExecutorPool | None = None,
        runtime: Runtime | None = None,
        decode_host_mode: str = "static",
        schedule_search: str = "auto",
        step_deadline_s: float | None = None,
    ):
        if cfg.frontend:
            raise ValueError("continuous batching supports decoder-only archs "
                             f"(got frontend={cfg.frontend!r})")
        from repro import api

        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.hw = hw
        # per-step deadline: every graph run inside one step() carries
        # deadline = step start + step_deadline_s, so a hung op raises
        # DeadlineExceeded (quarantining its executor) instead of wedging
        # the engine loop — the in-process analogue of the fleet's
        # SIGKILL-after-silence.  None = wait forever (the default).
        self.step_deadline_s = step_deadline_s
        self._step_deadline: float | None = None
        self._key = jax.random.key(rng_seed)
        self.capacity = scfg.max_batch
        self.cache = transformer.init_cache(cfg, self.capacity, scfg.max_len, per_slot=True)
        self._zero_sub_cache = transformer.init_cache(cfg, 1, scfg.max_len, per_slot=True)

        # executors come from the process Runtime (leased per step) unless
        # the caller hands an explicit shared pool, which bypasses admission
        self.pool = pool
        self.runtime = runtime if runtime is not None else (
            None if pool is not None else default_runtime())

        # the decode graph is *fixed* (one shape, replayed once per token):
        # the compiled static host plan takes the scheduler off its hot path
        # entirely.  Prefill graphs stay dynamic — their shapes vary per
        # prompt length and they share the step's executors with the
        # in-flight decode.
        tok_spec = jax.ShapeDtypeStruct((self.capacity, 1), jnp.int32)
        # schedule_search="auto" (default): once the decode graph is
        # calibrated below, the frozen decode plan is the simulator-searched
        # min-makespan winner (persisted per graph signature), not bare CPF
        self._decode_exe = api.compile(
            make_decode_step(cfg), params, self.cache, tok_spec,
            hw=hw, backend="host", jit_nodes=True, host_mode=decode_host_mode,
            pool=pool, runtime=self.runtime, schedule_search=schedule_search,
            name=f"serve_decode[{cfg.name}]",
        )
        self.schedule_search = schedule_search
        self.decode_host_mode = self._decode_exe.host_mode
        # profile-guided executor config for the serving graph: the §4.2
        # search over *measured* per-op costs (Executable.calibrate runs the
        # paper's first-iterations profiling, jit-compiling every node fn as
        # a side effect).  Analytic flops misrank tiny jitted decode ops —
        # their cost is dispatch, not arithmetic — and the static plan
        # freezes the resulting placement, so it must come from real
        # timings.  A runtime calibration-store hit (same decode graph, a
        # prior engine or process) skips the measurement entirely.
        # Optionally bounded: serving should not claim the whole machine.
        if self._decode_exe.calibrated:
            kw = ({"max_executors": max_executors}
                  if max_executors is not None else {})
            self.profile = self._decode_exe.profile_with(**kw)
        else:
            self.profile = self._decode_exe.calibrate(
                params, jax.tree.map(jnp.zeros_like, self.cache),
                jnp.full((self.capacity, 1), scfg.pad_id, jnp.int32),
                max_executors=max_executors)
        n_exec = self._decode_exe.planned_executors
        if max_executors is not None:
            n_exec = max(1, min(n_exec, max_executors))
        if pool is not None:
            n_exec = min(n_exec, pool.n_executors)
        elif self.runtime is not None:
            n_exec = min(n_exec, self.runtime.n_workers)
        self.n_executors = n_exec
        self._step_lease_ids: tuple[int, ...] = ()
        if self._decode_exe.host_mode == "static":
            # freeze the plan now (not on the first request) at the planned
            # width — a pool or runtime wider than the calibrated config
            # must not widen the placement
            self._decode_exe.host_plan(n_exec)
        self._team_size = self.profile.best_team_size
        # prefill graphs are keyed by *bucket*, not exact prompt length:
        # prompts are right-padded to the next power of two and masked with a
        # valid-length (transformer.prefill's valid_len path), so N distinct
        # lengths compile O(log N) executables instead of N.  Bit-exactness
        # holds for dense attention-only archs — padded tokens never enter a
        # real token's causal window and their cache entries are pos-masked —
        # but MoE capacity routing couples positions, and SSM/RG-LRU carry
        # state through padding, so those archs keep exact-length graphs.
        self._bucket_prefill = (
            not cfg.n_experts and all(k == "attn" for k in cfg.layer_kinds()))
        self._prefill_cap = transformer._attn_cache_len(cfg, scfg.max_len)
        self._prefill_exes: dict[int, api.Executable] = {}

        # slot insert/evict are jitted with a *traced* slot index: one
        # compile covers every slot (XLA scatter compiles are slow, and the
        # admission path runs per request)
        self._insert = jax.jit(
            lambda cache, sub, slot: transformer.cache_insert_slot(cfg, cache, sub, slot))
        self._evict = jax.jit(
            lambda cache, slot: transformer.cache_evict_slot(cfg, cache, slot))

        self.slots: list[Request | None] = [None] * self.capacity
        self.pending: deque[Request] = deque()
        self.completed: list[Request] = []
        self._tokens = np.full((self.capacity, 1), scfg.pad_id, np.int32)
        self._n_submitted = 0
        # loop counters (benchmarks read these)
        self.n_steps = 0
        self.n_decode_steps = 0
        self.n_overlapped_prefills = 0
        # warm every per-step code path against throwaway state (first
        # executions compile per-shape kernels), so the serving loop runs at
        # steady-state cost from the first request on
        warm = jax.tree.map(jnp.zeros_like, self.cache)
        with self._step_pool() as wpool:
            logits, _ = self._run_exe(
                self._decode_exe, (params, warm, jnp.asarray(self._tokens)),
                pool=wpool)
            if self._decode_exe.host_mode == "static":
                # steps with admissions in flight fall back to the dynamic
                # scheduler (_decode_once) — warm that path's state too
                self._run_exe(
                    self._decode_exe, (params, warm, jnp.asarray(self._tokens)),
                    pool=wpool, host_mode="dynamic")
        sample_tokens(logits, cfg.vocab_size, scfg.temperature,
                      jax.random.key(0) if scfg.temperature > 0 else None)
        warm = self._insert(warm, self._zero_sub_cache, jnp.int32(0))
        warm = self._evict(warm, jnp.int32(0))
        jax.block_until_ready(warm["len"])

    # -- lifecycle -------------------------------------------------------------
    def close(self) -> None:
        """Nothing to release: the engine leases executors per step from the
        runtime (an explicit ``pool`` is the caller's to close).  Kept so
        engine call sites stay context-manager shaped."""

    def __enter__(self) -> "ContinuousEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ------------------------------------------------------------
    def submit(self, req: Request) -> None:
        _validate_submit(req, self.scfg)
        req._order = self._n_submitted
        self._n_submitted += 1
        self.pending.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.pending) or any(s is not None for s in self.slots)

    def warmup(self, prompt_lens) -> None:
        """Pre-build + warm the prefill graphs for the given prompt lengths
        (deploy-time shape warming; admission then runs at steady-state)."""
        for s in sorted(set(int(x) for x in prompt_lens)):
            self._prefill_exe(s)

    # -- internals -------------------------------------------------------------
    def _step_pool(self):
        """The executors one engine iteration runs on: the explicit shared
        pool, or a fresh :class:`~repro.runtime.ExecutorLease` of the
        engine's calibrated width — acquired at step start, released at
        step end, so concurrent engines/trainers queue instead of
        oversubscribing.  The previous step's executor ids are passed as
        the affinity hint: the steady-state decode loop keeps its warm
        executor threads."""
        if self.pool is not None:
            return nullcontext(self.pool)
        lease = self.runtime.lease(self.n_executors,
                                   prefer=self._step_lease_ids)
        self._step_lease_ids = lease.executor_ids
        return lease

    def _run_exe(self, exe, args: tuple, *, pool, host_mode: str | None = None):
        """Execute a captured engine graph on the step's executors and
        unflatten to the fn's output pytree."""
        res = exe.execute_host(
            exe.captured.bind(args), n_executors=self.n_executors,
            pool=pool, host_mode=host_mode, deadline=self._step_deadline,
        )
        return exe.captured.unflatten(res.outputs)

    def _prefill_bucket(self, prompt_len: int) -> int:
        """Power-of-two length bucket (capped at the cache length); exact
        length for archs where padding would not be bit-exact, or when the
        cap falls below the prompt (SWA ring: no room to pad)."""
        if not self._bucket_prefill:
            return prompt_len
        b = 1 << max(0, prompt_len - 1).bit_length()
        b = min(b, self._prefill_cap)
        return b if b >= prompt_len else prompt_len

    def _prefill_batch(self, prompt) -> dict:
        S = len(prompt)
        bucket = self._prefill_bucket(S)
        if not self._bucket_prefill:
            return {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
        toks = np.full((1, bucket), self.scfg.pad_id, np.int32)
        toks[0, :S] = prompt
        return {"tokens": jnp.asarray(toks), "valid_len": jnp.int32(S)}

    def _prefill_exe(self, prompt_len: int, pool=None):
        bucket = self._prefill_bucket(prompt_len)
        exe = self._prefill_exes.get(bucket)
        if exe is None:
            from repro import api

            tok_spec = {"tokens": jax.ShapeDtypeStruct((1, bucket), jnp.int32)}
            if self._bucket_prefill:
                tok_spec["valid_len"] = jax.ShapeDtypeStruct((), jnp.int32)
            exe = api.compile(
                make_prefill_step(self.cfg), self.params, self._zero_sub_cache, tok_spec,
                hw=self.hw, backend="host", pool=self.pool, runtime=self.runtime,
                jit_nodes=True, schedule_search=self.schedule_search,
                n_executors=self.n_executors, team_size=self._team_size,
                name=f"serve_prefill[{self.cfg.name},S={bucket}]",
            )
            # first-call warmup, same reasoning as the decode graph
            warm_batch = {"tokens": jnp.zeros((1, bucket), jnp.int32)}
            if self._bucket_prefill:
                warm_batch["valid_len"] = jnp.int32(bucket)
            out = self._run_exe(
                exe, (self.params, self._zero_sub_cache, warm_batch),
                pool=pool)
            sample_tokens(out[0], self.cfg.vocab_size, self.scfg.temperature,
                          jax.random.key(0) if self.scfg.temperature > 0 else None)
            jax.block_until_ready(out[0])
            self._prefill_exes[bucket] = exe
        return exe

    def _admit(self, req: Request, slot: int, pool=None):
        """Run the request's prefill graph on the step's executors."""
        exe = self._prefill_exe(len(req.prompt), pool=pool)
        logits, filled = self._run_exe(
            exe, (self.params, self._zero_sub_cache,
                  self._prefill_batch(req.prompt)),
            pool=pool)
        return req, slot, logits, filled

    def _install(self, req: Request, slot: int, logits, filled) -> None:
        """Land a prefilled request in its slot and sample its first token."""
        self.cache = self._insert(self.cache, filled, jnp.int32(slot))
        self.slots[slot] = req
        self._emit(slot, int(self._sample(logits)[0]))

    def _emit(self, slot: int, token: int) -> None:
        req = self.slots[slot]
        req.output.append(token)
        hit_eos = req.eos_id is not None and token == req.eos_id
        if hit_eos or len(req.output) >= req.max_new_tokens:
            req.done = True
            self.completed.append(req)
            self.slots[slot] = None
            self.cache = self._evict(self.cache, jnp.int32(slot))
            self._tokens[slot, 0] = self.scfg.pad_id
        else:
            self._tokens[slot, 0] = token

    def _decode_once(self, pool, *, overlapping_prefills: bool = False) -> None:
        exe = self._decode_exe
        host_mode = None
        if overlapping_prefills and exe.host_mode == "static":
            # a static plan's segments hold every one of the step's
            # executors for the whole decode, which would serialize the
            # concurrent admission prefills behind it; the dynamic scheduler
            # interleaves per-op, so steps with prefills in flight fall back
            # to it.  Steady-state steps (the vast majority) replay the
            # compiled plan.
            host_mode = "dynamic"
        logits, self.cache = self._run_exe(
            exe, (self.params, self.cache, jnp.asarray(self._tokens)),
            pool=pool, host_mode=host_mode)
        self.n_decode_steps += 1
        nxt = self._sample(logits)
        for i in range(self.capacity):
            if self.slots[i] is not None:
                self._emit(i, int(nxt[i]))

    # -- the loop --------------------------------------------------------------
    def step(self) -> bool:
        """One engine iteration: admit into free slots, one decode step.

        The step leases the engine's executors once (:meth:`_step_pool`);
        admission prefills execute concurrently with the decode step on
        those executors and their slots join the batch from the *next*
        step.  Returns whether work remains.
        """
        self.n_steps += 1
        if self.step_deadline_s is not None:
            self._step_deadline = time.monotonic() + self.step_deadline_s
        free = [i for i, s in enumerate(self.slots) if s is None]
        admits: list[tuple[Request, int]] = []
        while self.pending and free:
            admits.append((self.pending.popleft(), free.pop(0)))
        decoding = any(s is not None for s in self.slots)

        with self._step_pool() as pool:
            if admits and decoding:
                box: dict = {}

                def prefill_worker() -> None:
                    try:
                        box["res"] = [self._admit(r, s, pool=pool)
                                      for r, s in admits]
                    except BaseException as e:  # noqa: BLE001 — re-raised below
                        box["err"] = e

                th = threading.Thread(target=prefill_worker, name="serve-prefill")
                th.start()
                self._decode_once(pool, overlapping_prefills=True)
                th.join()
                if "err" in box:
                    raise box["err"]
                self.n_overlapped_prefills += len(admits)
                for item in box["res"]:
                    self._install(*item)
            elif admits:
                for r, s in admits:
                    self._install(*self._admit(r, s, pool=pool))
            elif decoding:
                self._decode_once(pool)
        self._step_deadline = None
        return self.has_work

    def run(self) -> list[Request]:
        """Drain pending + active requests; returns them in submit order."""
        while self.has_work:
            self.step()
        done = sorted(self.completed, key=lambda r: r._order)
        self.completed = []
        return done
