from .hlo_collectives import collective_bytes, collective_summary
from .roofline import TPU_V5E, HardwareSpec, RooflineReport, roofline_report

__all__ = [
    "collective_bytes",
    "collective_summary",
    "HardwareSpec",
    "TPU_V5E",
    "RooflineReport",
    "roofline_report",
]
