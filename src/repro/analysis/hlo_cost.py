"""Trip-count-aware cost walker over optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
so any ``lax.scan`` (layer stacks, microbatch accumulation, KV chunking)
under-reports flops/bytes by the trip count — for a 64-layer scanned model
that's a 64x error in the roofline's compute term.  XLA records the trip
count in ``backend_config={"known_trip_count":{"n":...}}``; this module
parses the module text and walks the computation graph multiplying through.

Accounting conventions (per-device — the post-SPMD module is the per-device
program):

* flops: ``dot`` = 2 x |result| x K (contracting extent); elementwise /
  reduce = |result| / |operand|; data movement (reshape, slice, gte, ...) = 0.
* bytes: per *top-level* instruction = operand bytes + result bytes (fusions
  count at the call site only — their internals stay in registers/VMEM),
  i.e. an HBM-traffic model, matching what the memory roofline term wants.
* collectives: operand bytes per kind, scaled by enclosing trip counts.

Validated against known-flop probes in tests/test_analysis.py (a scanned
matmul reports exactly trip x 2MNK).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["ModuleCost", "module_cost", "parse_computations"]

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
# note: tuple shapes may contain `/*index=5*/` comments — anything but parens
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\("
)
_REF_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")

# opcodes that move/reinterpret data: zero flops, zero HBM-traffic charge
# (their traffic is captured by the producing/consuming compute ops)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "reshape", "after-all", "add-dependency", "iota",
    "partition-id", "replica-id", "rng-bit-generator", "rng",
    "get-dimension-size", "opt-barrier", "domain",
}
# charged for bytes but not flops
_MOVE_OPS = {
    "copy", "transpose", "slice", "dynamic-slice", "dynamic-update-slice",
    "concatenate", "broadcast", "pad", "reverse", "gather", "scatter",
    "select-and-scatter", "convert", "copy-start", "copy-done", "sort",
}


def _shape_dims(shape_text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_text: str) -> int:
    total = 0
    for _, dims in _shape_dims(shape_text):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Instr:
    name: str
    shape_text: str
    opcode: str
    operands: list[str]
    line: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    root: str = ""


def parse_computations(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for line in text.splitlines():
        hm = _COMP_HEADER_RE.match(line)
        if hm:
            cur = Computation(hm.group(2))
            comps[cur.name] = cur
            if hm.group(1):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, shape_text, opcode = im.groups()
        # operands: balanced-paren span right after the opcode's '('
        start = im.end() - 1
        depth, end = 0, start
        for i in range(start, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _REF_RE.findall(line[start : end + 1])
        is_root = bool(re.match(r"^\s*ROOT\s", line))
        cur.instrs.append(Instr(name, shape_text, opcode, operands, line, is_root))
        if is_root:
            cur.root = name
    return comps, entry


@dataclass
class ModuleCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: dict[str, tuple[int, float]] = field(default_factory=dict)
    unknown_trip_whiles: int = 0

    @property
    def collective_bytes(self) -> float:
        return sum(b for _, b in self.collectives.values())

    def add(self, other: "ModuleCost", scale: float = 1.0, *, bytes_too: bool = True) -> None:
        self.flops += other.flops * scale
        self.transcendentals += other.transcendentals * scale
        if bytes_too:
            self.bytes += other.bytes * scale
        for k, (c, b) in other.collectives.items():
            c0, b0 = self.collectives.get(k, (0, 0.0))
            self.collectives[k] = (c0 + int(c * scale), b0 + b * scale)
        self.unknown_trip_whiles += other.unknown_trip_whiles


_TRANSCENDENTAL = {"exponential", "exp", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "expm1", "log1p", "erf", "atan2",
                   "cbrt", "exponential-minus-one"}

# ops that touch only a window of their first operand: charge the accessed
# region (~ result size), not the whole buffer — a dynamic-slice of a stacked
# [L, ...] parameter inside a scan body reads one layer, not L
_WINDOW_READ_OPS = {"slice", "dynamic-slice", "gather"}
# in-place window writes: traffic = the update operand (read+write region),
# NOT the full aliased buffer the result shape names
_WINDOW_WRITE_OPS = {"dynamic-update-slice", "scatter"}

# bf16-native correction (XLA:CPU promotes every bf16 dot to f32, inserting
# convert chains that would not exist on TPU): values whose producer chain is
# pure data movement from a bf16 source are charged at 2 B/elt even when the
# CPU module types them f32.  Chain-transparent opcodes:
_CHAIN_OPS = {"convert", "copy", "bitcast", "bitcast-convert", "reshape",
              "transpose", "all-gather", "broadcast", "get-tuple-element"}


class _Bf16Resolver:
    """Tracks which values are f32-typed-but-bf16-born (CPU upcast chains)."""

    def __init__(self) -> None:
        self.producers: dict[str, Instr] = {}
        self.comp_of: dict[str, str] = {}
        self.comps: dict[str, Computation] = {}
        self._memo: dict[str, bool] = {}

    def build(self, comps: dict[str, Computation]) -> None:
        self.comps = comps
        for cname, comp in comps.items():
            for ins in comp.instrs:
                self.producers[ins.name] = ins
                self.comp_of[ins.name] = cname

    def born_bf16(self, name: str, depth: int = 0) -> bool:
        if depth > 12:
            return False
        if name in self._memo:
            return self._memo[name]
        ins = self.producers.get(name)
        if ins is None:
            return False
        out = False
        if ins.shape_text.startswith("bf16"):
            out = True
        elif ins.opcode in _CHAIN_OPS and ins.operands:
            out = self.born_bf16(ins.operands[0], depth + 1)
        elif ins.opcode in ("fusion", "call"):
            comp = self.comps.get(_called_comp(ins) or "")
            if comp is not None and all(
                i.opcode in _CHAIN_OPS or i.opcode == "parameter" for i in comp.instrs
            ):
                out = any(self.born_bf16(o, depth + 1) for o in ins.operands)
        self._memo[name] = out
        return out

    def eff_bytes(self, name: str, sizes: dict[str, str]) -> float:
        """Effective (TPU-native) bytes of a value."""
        shape = sizes.get(name, "")
        raw = _shape_bytes(shape)
        if shape.startswith("f32") and self.born_bf16(name):
            return raw / 2.0
        return float(raw)


def _called_comp(ins: Instr) -> str | None:
    """Callee name of a fusion/call site (``calls=`` or ``to_apply=``)."""
    m = _CALLS_RE.search(ins.line) or _TO_APPLY_RE.search(ins.line)
    return m.group(1) if m else None


def _is_pure_convert(ins: Instr, comps: dict[str, Computation]) -> bool:
    """bf16<->f32 convert chains are XLA:CPU dot-promotion artifacts — on the
    TPU target they are fused away or absent; charge them zero traffic.
    XLA:CPU emits them bare, as fusions, or as ``call``s of a
    ``%parallel_convert`` computation (outer-dimension-partitioned)."""
    if ins.opcode == "convert":
        return True
    if ins.opcode in ("fusion", "call"):
        comp = comps.get(_called_comp(ins) or "")
        if comp is not None and comp.instrs and all(
            i.opcode in ("parameter", "convert", "bitcast", "copy", "reshape", "transpose")
            for i in comp.instrs
        ) and any(i.opcode == "convert" for i in comp.instrs):
            return True
    return False


def _instr_bytes(ins: Instr, sizes: dict[str, str], rs: "_Bf16Resolver | None" = None) -> float:
    """HBM traffic estimate for one top-level instruction."""
    if ins.opcode in _WINDOW_READ_OPS:
        return 2.0 * _shape_bytes(ins.shape_text)
    if ins.opcode in _WINDOW_WRITE_OPS:
        upd = sizes.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
        return 2.0 * _shape_bytes(upd)
    total = float(_shape_bytes(ins.shape_text))
    if rs is not None and ins.shape_text.startswith("f32") and rs.born_bf16(ins.name):
        total /= 2.0
    for o in ins.operands:
        total += rs.eff_bytes(o, sizes) if rs is not None else _shape_bytes(sizes.get(o, ""))
    return total


def _fusion_io_bytes(
    ins: Instr,
    comps: dict[str, Computation],
    called: str,
    sizes: dict[str, str],
    rs: "_Bf16Resolver | None" = None,
) -> float:
    """Traffic of a fusion call site: each parameter is charged by how the
    fusion body *accesses* it (windowed reads charge the window), the output
    by what the root *writes* (a DUS root writes the update, aliasing the
    buffer)."""
    comp = comps.get(called)
    if comp is None:
        return _instr_bytes(ins, sizes, rs)
    # map parameter index -> instruction name
    params: dict[int, str] = {}
    consumers: dict[str, list[Instr]] = {}
    root_ins: Instr | None = None
    for inner in comp.instrs:
        if inner.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", inner.line)
            if m:
                params[int(m.group(1))] = inner.name
        for o in inner.operands:
            consumers.setdefault(o, []).append(inner)
        if inner.is_root:
            root_ins = inner
    def _windowed_reads(pname: str) -> list[Instr] | None:
        """Window-read instrs this parameter reaches through pure chain ops
        (bitcast/reshape/...); None if any path escapes to real compute —
        without chain-following a `bitcast -> dynamic-slice` of a stacked
        [L, ...] weight charges the WHOLE stack per scan iteration."""
        found: list[Instr] = []
        stack, seen = [pname], set()
        while stack:
            nm = stack.pop()
            for cons_i in consumers.get(nm, []):
                if cons_i.opcode in _WINDOW_READ_OPS:
                    found.append(cons_i)
                elif cons_i.opcode in ("bitcast", "reshape", "copy", "transpose", "convert"):
                    if cons_i.name not in seen:
                        seen.add(cons_i.name)
                        stack.append(cons_i.name)
                else:
                    return None
        return found or None

    total = 0.0
    for idx, op_name in enumerate(ins.operands):
        full = rs.eff_bytes(op_name, sizes) if rs is not None else _shape_bytes(sizes.get(op_name, ""))
        pname = params.get(idx)
        wins = _windowed_reads(pname) if pname else None
        if wins is not None:
            total += sum(_shape_bytes(c.shape_text) for c in wins)
        else:
            total += full
    if root_ins is not None and root_ins.opcode in _WINDOW_WRITE_OPS:
        upd = root_ins.operands[1] if len(root_ins.operands) > 1 else ""
        inner_sizes = {i.name: i.shape_text for i in comp.instrs}
        total += _shape_bytes(inner_sizes.get(upd, ""))
    else:
        out_b = float(_shape_bytes(ins.shape_text))
        if rs is not None and ins.shape_text.startswith("f32") and rs.born_bf16(ins.name):
            out_b /= 2.0
        total += out_b
    return total


def _dot_flops(instr: Instr, sizes: dict[str, str]) -> float:
    k = 1
    m = _CONTRACT_RE.search(instr.line)
    if m and instr.operands:
        lhs_shape = sizes.get(instr.operands[0], "")
        dims_list = _shape_dims(lhs_shape)
        if dims_list:
            dims = dims_list[0][1]
            idxs = [int(i) for i in m.group(1).split(",") if i != ""]
            for i in idxs:
                if i < len(dims):
                    k *= dims[i]
    return 2.0 * _shape_elems(instr.shape_text) * k


def _conv_flops(instr: Instr, sizes: dict[str, str]) -> float:
    # flops = 2 * |result| * (kernel elems / Cout); Cout from dim_labels 'o'
    if len(instr.operands) < 2:
        return 2.0 * _shape_elems(instr.shape_text)
    kshape = _shape_dims(sizes.get(instr.operands[1], ""))
    if not kshape:
        return 2.0 * _shape_elems(instr.shape_text)
    kdims = kshape[0][1]
    kelems = 1
    for d in kdims:
        kelems *= d
    m = re.search(r"dim_labels=[^_]*_([\dio]+)", instr.line)
    cout = 1
    if m and kdims:
        labels = m.group(1)
        o_idx = labels.find("o")
        if 0 <= o_idx < len(kdims):
            cout = kdims[o_idx]
    return 2.0 * _shape_elems(instr.shape_text) * max(1, kelems // max(cout, 1))


def _comp_cost(
    name: str,
    comps: dict[str, Computation],
    sizes: dict[str, str],
    memo: dict[str, ModuleCost],
    stack: set[str],
    rs: "_Bf16Resolver | None" = None,
) -> ModuleCost:
    if name in memo:
        return memo[name]
    if name in stack or name not in comps:
        return ModuleCost()
    stack = stack | {name}
    total = ModuleCost()
    for ins in comps[name].instrs:
        op = ins.opcode
        if op == "while":
            m = _COND_BODY_RE.search(ins.line)
            tm = _TRIP_RE.search(ins.line)
            trip = int(tm.group(1)) if tm else 1
            if tm is None:
                total.unknown_trip_whiles += 1
            if m:
                body = _comp_cost(m.group(2), comps, sizes, memo, stack, rs)
                cond = _comp_cost(m.group(1), comps, sizes, memo, stack, rs)
                total.add(body, trip)
                total.add(cond, trip)
            continue
        if op in ("fusion", "call", "async-start", "map"):
            if rs is not None and _is_pure_convert(ins, comps):
                continue
            callee = _called_comp(ins)
            if callee:
                inner = _comp_cost(callee, comps, sizes, memo, stack, rs)
                total.add(inner, 1.0, bytes_too=False)  # flops only; VMEM-internal
                total.bytes += _fusion_io_bytes(ins, comps, callee, sizes, rs)
            else:
                total.bytes += _instr_bytes(ins, sizes, rs)
            continue
        if op == "conditional":
            branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|(?:true|false)_computation=%?([\w.\-]+))", ins.line)
            names = []
            for grp, single in branches:
                if grp:
                    names += _REF_RE.findall(grp)
                if single:
                    names.append(single)
            if names:
                worst = ModuleCost()
                for bn in names:
                    c = _comp_cost(bn, comps, sizes, memo, stack, rs)
                    if c.flops >= worst.flops:
                        worst = c
                total.add(worst, 1.0)
            continue
        kind = next((k for k in COLLECTIVE_KINDS if op.startswith(k)), None)
        if kind is not None:
            if rs is not None:
                ob = sum(rs.eff_bytes(o, sizes) for o in ins.operands)
            else:
                ob = sum(_shape_bytes(sizes.get(o, "")) for o in ins.operands)
            if ob == 0:
                ob = _shape_bytes(ins.shape_text)
            c0, b0 = total.collectives.get(kind, (0, 0.0))
            total.collectives[kind] = (c0 + 1, b0 + ob)
            total.bytes += ob + _shape_bytes(ins.shape_text)
            continue
        if op in _FREE_OPS:
            continue
        if rs is not None and op == "convert":
            continue   # CPU dot-promotion artifact; absent on TPU
        # bytes: access-aware operand + result traffic (HBM model)
        total.bytes += _instr_bytes(ins, sizes, rs)
        if op in _MOVE_OPS:
            continue
        if op == "dot":
            total.flops += _dot_flops(ins, sizes)
        elif op == "convolution":
            total.flops += _conv_flops(ins, sizes)
        elif op in ("reduce", "reduce-window"):
            total.flops += sum(_shape_elems(sizes.get(o, "")) for o in ins.operands[:1])
        else:
            n = _shape_elems(ins.shape_text)
            total.flops += n
            if op in _TRANSCENDENTAL:
                total.transcendentals += n
    memo[name] = total
    return total


def module_cost(hlo_text: str, *, bf16_native: bool = True) -> ModuleCost:
    """Per-device flops / HBM bytes / collective traffic of an optimized HLO
    module, with while bodies multiplied by their known trip counts.

    ``bf16_native``: charge f32 values born from bf16 upcast chains at
    2 B/elt (XLA:CPU promotes every bf16 dot to f32; on the TPU target the
    converts do not exist and the traffic is bf16 — without this the memory
    and collective terms are inflated ~2x for bf16 models).
    """
    comps, entry = parse_computations(hlo_text)
    sizes: dict[str, str] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            sizes[ins.name] = ins.shape_text
    rs = None
    if bf16_native:
        rs = _Bf16Resolver()
        rs.build(comps)
    memo: dict[str, ModuleCost] = {}
    if not entry:
        entry = next(iter(comps), "")
    return _comp_cost(entry, comps, sizes, memo, set(), rs)


def _toplevel_multipliers(comps: dict[str, Computation], entry: str) -> dict[str, float]:
    """Enclosing trip multiplier per *top-level* computation (fusion bodies
    excluded — their work is charged at the call site)."""
    mult: dict[str, float] = {entry: 1.0}
    frontier = [entry]
    while frontier:
        cname = frontier.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instrs:
            if ins.opcode == "while":
                tm = _TRIP_RE.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
                cb = _COND_BODY_RE.search(ins.line)
                if cb:
                    for sub in cb.groups():
                        if sub not in mult:
                            mult[sub] = m * trip
                            frontier.append(sub)
            elif ins.opcode == "call":
                callee = _called_comp(ins)  # calls= or to_apply= form
                if callee and callee not in mult:
                    mult[callee] = m
                    frontier.append(callee)
    return mult


def top_flops(hlo_text: str, k: int = 20) -> list[tuple[float, str, str]]:
    """The k top-FLOPS instructions (x enclosing trips) — localizes wasted
    compute (useful-ratio hunts)."""
    comps, entry = parse_computations(hlo_text)
    sizes = {i.name: i.shape_text for c in comps.values() for i in c.instrs}
    mult = _toplevel_multipliers(comps, entry)
    memo: dict[str, ModuleCost] = {}
    rows: list[tuple[float, str, str]] = []
    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            f = 0.0
            if ins.opcode == "dot":
                f = _dot_flops(ins, sizes)
            elif ins.opcode == "convolution":
                f = _conv_flops(ins, sizes)
            elif ins.opcode in ("fusion", "map"):
                callee = _called_comp(ins)
                if callee:
                    f = _comp_cost(callee, comps, sizes, memo, set()).flops
            elif ins.opcode not in _FREE_OPS and ins.opcode not in _MOVE_OPS \
                    and ins.opcode not in ("while", "call", "conditional"):
                f = _shape_elems(ins.shape_text)
            if f:
                meta = re.search(r'op_name="([^"]*)"', ins.line)
                rows.append((f * m, ins.opcode, meta.group(1) if meta else ins.name))
    rows.sort(key=lambda r: -r[0])
    return rows[:k]


def top_traffic(hlo_text: str, k: int = 20) -> list[tuple[float, str, str]]:
    """The k top HBM-traffic instructions — (bytes x enclosing trips, opcode,
    op_name metadata) — the profile the §Perf hillclimb reads."""
    comps, entry = parse_computations(hlo_text)
    sizes: dict[str, str] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            sizes[ins.name] = ins.shape_text

    mult = _toplevel_multipliers(comps, entry)

    rows: list[tuple[float, str, str]] = []
    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.opcode in _FREE_OPS or ins.opcode in ("while", "call"):
                continue
            if ins.opcode in ("fusion", "map"):  # 'call' skipped above; body in mult
                callee = _called_comp(ins)
                b = _fusion_io_bytes(ins, comps, callee, sizes) if callee else _instr_bytes(ins, sizes)
            else:
                b = _instr_bytes(ins, sizes)
            meta = re.search(r'op_name="([^"]*)"', ins.line)
            rows.append((b * m, ins.opcode, meta.group(1) if meta else ins.name))
    rows.sort(key=lambda r: -r[0])
    return rows[:k]
