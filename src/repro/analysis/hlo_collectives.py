"""Collective traffic summary from optimized HLO text.

Thin facade over :mod:`repro.analysis.hlo_cost` (the trip-count-aware
walker): collectives inside a scanned layer stack execute once *per layer*,
so naive line-grep undercounts by the trip count exactly like flops.

Convention: bytes are the **operand** (pre-collective, per-device) sizes —
the payload each device contributes.  Ring-transfer inflation factors
(2(k-1)/k for all-reduce etc.) are applied by the roofline, not here.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .hlo_cost import COLLECTIVE_KINDS, module_cost

__all__ = ["collective_bytes", "collective_summary", "CollectiveStats", "COLLECTIVE_KINDS"]


@dataclass
class CollectiveStats:
    # kind -> (count, operand bytes)
    per_kind: dict[str, tuple[int, float]] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(b for _, b in self.per_kind.values())

    @property
    def total_count(self) -> int:
        return sum(c for c, _ in self.per_kind.values())

    def table(self) -> str:
        rows = [f"{'kind':20s} {'count':>6s} {'MiB':>10s}"]
        for kind in COLLECTIVE_KINDS:
            if kind in self.per_kind:
                c, b = self.per_kind[kind]
                rows.append(f"{kind:20s} {c:6d} {b / 2**20:10.2f}")
        rows.append(f"{'TOTAL':20s} {self.total_count:6d} {self.total_bytes / 2**20:10.2f}")
        return "\n".join(rows)


def collective_summary(hlo_text: str) -> CollectiveStats:
    mc = module_cost(hlo_text)
    return CollectiveStats(per_kind=dict(mc.collectives))


def collective_bytes(hlo_text: str) -> float:
    return collective_summary(hlo_text).total_bytes
