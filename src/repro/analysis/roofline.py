"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs            / (peak_FLOP/s per chip)
    memory     = HLO_bytes            / (HBM_bw per chip)
    collective = collective_bytes     / (ICI link_bw per chip)

All numerators are **per-device** quantities from the post-SPMD module (the
per-device program), computed by the trip-count-aware walker in hlo_cost.py
— NOT ``compiled.cost_analysis()``, which counts scan bodies once (see that
module's docstring; EXPERIMENTS.md §Roofline records the discrepancy).

The dominant term estimates step time at perfect overlap; usefulness is
judged by MODEL_FLOPS/HLO_FLOPS (how much compiled compute is 6ND-useful)
and by the roofline fraction compute/max(all) (MFU bound at that schedule).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .hlo_cost import ModuleCost, module_cost

__all__ = ["HardwareSpec", "TPU_V5E", "RooflineReport", "roofline_report"]


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float        # per chip, bf16
    hbm_bw: float            # per chip, B/s
    link_bw: float           # per ICI link, B/s
    hbm_bytes: float         # per chip capacity

    def describe(self) -> str:
        return (
            f"{self.name}: {self.peak_flops/1e12:.0f} TF/s bf16, "
            f"{self.hbm_bw/1e9:.0f} GB/s HBM, {self.link_bw/1e9:.0f} GB/s/link ICI"
        )


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    hbm_bytes=16e9,
)


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    # per-device numerators
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collectives: dict = field(default_factory=dict)
    # terms (seconds)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    # usefulness
    model_flops_total: float = 0.0
    useful_ratio: float = 0.0        # MODEL_FLOPS / (HLO_FLOPS * chips)
    roofline_fraction: float = 0.0   # compute_s / max(terms) — MFU upper bound
    # memory fit
    bytes_per_device: float = 0.0    # args + temps from memory_analysis
    fits_hbm: bool = True
    dominant: str = "compute"
    note: str = ""

    def step_time_bound(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def mfu_bound(self, hw: "HardwareSpec | None" = None) -> float:
        """Model-flops utilization at the roofline bound (what a perfect
        runtime would achieve with this compiled schedule)."""
        hw = hw or TPU_V5E
        t = self.step_time_bound()
        if t <= 0 or not self.n_chips:
            return 0.0
        return self.model_flops_total / (t * self.n_chips * hw.peak_flops)

    def row(self) -> str:
        return (
            f"{self.arch:22s} {self.shape:12s} {self.mesh:10s} "
            f"c={self.compute_s*1e3:9.3f}ms m={self.memory_s*1e3:9.3f}ms "
            f"x={self.collective_s*1e3:9.3f}ms dom={self.dominant:10s} "
            f"useful={self.useful_ratio:6.3f} frac={self.roofline_fraction:5.3f}"
        )


def roofline_report(
    *,
    arch: str,
    shape: str,
    mesh_desc: str,
    n_chips: int,
    hlo_text: str,
    model_flops_total: float,
    bytes_per_device: float = 0.0,
    hw: HardwareSpec = TPU_V5E,
    cost: ModuleCost | None = None,
) -> RooflineReport:
    mc = cost if cost is not None else module_cost(hlo_text)
    compute_s = mc.flops / hw.peak_flops
    memory_s = mc.bytes / hw.hbm_bw
    collective_s = mc.collective_bytes / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
    hlo_total = mc.flops * n_chips
    rep = RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        n_chips=n_chips,
        hlo_flops=mc.flops,
        hlo_bytes=mc.bytes,
        collective_bytes=mc.collective_bytes,
        collectives={k: v for k, v in mc.collectives.items()},
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        model_flops_total=model_flops_total,
        useful_ratio=model_flops_total / hlo_total if hlo_total else 0.0,
        roofline_fraction=compute_s / max(max(terms.values()), 1e-30),
        bytes_per_device=bytes_per_device,
        fits_hbm=bytes_per_device <= hw.hbm_bytes if bytes_per_device else True,
        dominant=dominant,
    )
    if mc.unknown_trip_whiles:
        rep.note = f"{mc.unknown_trip_whiles} while loop(s) without known_trip_count"
    return rep
