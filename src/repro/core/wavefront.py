"""Wavefront (anti-diagonal) structure of recurrent computation graphs.

A stacked recurrence (L layers × T timesteps; cell (l,t) depends on (l-1,t)
and (l,t-1)) admits exactly one maximal parallel pattern: all cells on an
anti-diagonal d = l + t are independent.  cuDNN hand-codes this for LSTM; the
paper's headline scheduling result (§7.4) is that critical-path-first
scheduling *recovers it automatically*.  This module provides:

* ``recurrence_graph``   — build the L×T cell DAG (for the scheduler);
* ``diagonals``          — the reference wavefront order;
* ``is_wavefront_order`` — checker used by tests/benchmarks;
* ``stacked_wavefront_lstm`` — the TPU-native *static plan*: cells of a
  diagonal stacked on a leading axis (shard it over executor groups; see
  DESIGN.md §2.1) and swept with ``jax.lax`` control flow.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .graph import Graph, OpNode

__all__ = [
    "cell_name",
    "recurrence_graph",
    "diagonals",
    "is_wavefront_order",
    "lstm_cell",
    "stacked_wavefront_lstm",
    "sequential_lstm",
]


def cell_name(l: int, t: int) -> str:
    return f"cell_L{l}_T{t}"


def recurrence_graph(
    n_layers: int,
    n_steps: int,
    *,
    flops_per_cell: float = 0.0,
    bytes_per_cell: float = 0.0,
    kind: str = "lstm_cell",
) -> Graph:
    """The L×T recurrence DAG with wavefront dependencies."""
    g = Graph(f"recurrence_{n_layers}x{n_steps}")
    for t in range(n_steps):
        for l in range(n_layers):
            deps = []
            if l > 0:
                deps.append(cell_name(l - 1, t))
            if t > 0:
                deps.append(cell_name(l, t - 1))
            g.add(
                OpNode(
                    name=cell_name(l, t),
                    kind=kind,
                    flops=flops_per_cell,
                    bytes_in=bytes_per_cell,
                    bytes_out=bytes_per_cell / 3 if bytes_per_cell else 0.0,
                    deps=tuple(deps),
                    meta={"layer": l, "step": t, "diag": l + t},
                )
            )
    return g


def diagonals(n_layers: int, n_steps: int) -> list[list[tuple[int, int]]]:
    out: list[list[tuple[int, int]]] = []
    for d in range(n_layers + n_steps - 1):
        wave = [(l, d - l) for l in range(n_layers) if 0 <= d - l < n_steps]
        out.append(wave)
    return out


def is_wavefront_order(order: Sequence[str], graph: Graph) -> bool:
    """True iff ops appear in non-decreasing anti-diagonal index."""
    last = -1
    for name in order:
        d = graph[name].meta["diag"]
        if d < last:
            return False
        last = max(last, d)
    return True


# ---------------------------------------------------------------------------
# Real LSTM execution: sequential reference vs stacked-wavefront static plan.
# ---------------------------------------------------------------------------

def lstm_cell(params, x, h, c):
    """Standard LSTM cell. params: dict(Wx [D,4H], Wh [H,4H], b [4H])."""
    gates = x @ params["Wx"] + h @ params["Wh"] + params["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def sequential_lstm(params_per_layer, xs):
    """Reference: layer-by-layer ``lax.scan`` (the one-executor interpreter).

    params_per_layer: pytree list of L cell-param dicts (Wx differs for layer 0).
    xs: [T, B, D] input sequence.  Returns top-layer hidden states [T, B, H].
    """
    h = xs
    for lp in params_per_layer:
        B = h.shape[1]
        H = lp["Wh"].shape[0]
        h0 = jnp.zeros((B, H), h.dtype)
        c0 = jnp.zeros((B, H), h.dtype)

        def step(carry, x, lp=lp):
            hh, cc = carry
            hn, cn = lstm_cell(lp, x, hh, cc)
            return (hn, cn), hn

        (_, _), h = jax.lax.scan(step, (h0, c0), h)
    return h


def stacked_wavefront_lstm(stacked_params, xs, n_layers: int):
    """The CPF-recovered diagonal schedule as a *static plan* (DESIGN §2.1).

    All L cells of an anti-diagonal execute as ONE stacked cell op
    [L, B, ...] — on a pod, the leading L axis is sharded over executor
    groups, giving the paper's "independent ops on disjoint partitions"
    without inter-group communication.

    Requires homogeneous cell shapes (D == H for layer 0 via an input
    projection done by the caller).  stacked_params: dict of arrays with
    leading layer axis: Wx [L,H,4H], Wh [L,H,4H], b [L,4H].
    xs: [T, B, H].  Returns top-layer hiddens [T, B, H].
    """
    T, B, H = xs.shape
    L = n_layers
    n_diag = L + T - 1

    h = jnp.zeros((L, B, H), xs.dtype)       # h[l] = latest hidden of layer l
    c = jnp.zeros((L, B, H), xs.dtype)
    # layer l consumes the *previous* output of layer l-1; keep a shift buffer
    # inbuf[l] = next input for layer l (layer 0 reads the sequence).
    inbuf = jnp.zeros((L, B, H), xs.dtype)
    out = jnp.zeros((T, B, H), xs.dtype)

    cell = jax.vmap(lstm_cell, in_axes=(0, 0, 0, 0))

    def diag_step(carry, d):
        h, c, inbuf, out = carry
        # feed the sequence into layer 0 when 0 <= d < T
        x0 = jnp.where(d < T, xs[jnp.minimum(d, T - 1)], jnp.zeros((B, H), xs.dtype))
        inbuf = inbuf.at[0].set(x0)
        h_new, c_new = cell(stacked_params, inbuf, h, c)
        # active mask: layer l is live on diagonal d iff 0 <= d - l < T
        ls = jnp.arange(L)
        active = ((d - ls) >= 0) & ((d - ls) < T)
        m = active[:, None, None]
        h = jnp.where(m, h_new, h)
        c = jnp.where(m, c_new, c)
        # outputs of layer l feed layer l+1 on the next diagonal
        inbuf = inbuf.at[1:].set(jnp.where(m[:-1], h_new[:-1], 0.0))
        # top layer emits position t = d - (L-1)
        t_top = d - (L - 1)
        emit = (t_top >= 0) & (t_top < T)
        idx = jnp.clip(t_top, 0, T - 1)
        out = jax.lax.cond(
            emit,
            lambda o: o.at[idx].set(h_new[L - 1]),
            lambda o: o,
            out,
        )
        return (h, c, inbuf, out), None

    (h, c, inbuf, out), _ = jax.lax.scan(
        diag_step, (h, c, inbuf, out), jnp.arange(n_diag)
    )
    return out
