"""jaxpr → :class:`Graph` capture (the front half of ``graphi.compile``).

``capture(fn, *specs)`` traces ``fn`` with :func:`jax.make_jaxpr`, inlines
``pjit``/``remat``/``custom_*`` call boundaries, fuses trivial data-movement
and elementwise chains into their consumers, and emits one :class:`OpNode`
per surviving equation group.  Every node carries

* roofline statistics (``flops`` / ``bytes_in`` / ``bytes_out``) derived from
  the equation avals with the same accounting conventions as
  ``analysis/hlo_cost.py`` (dot = 2·|out|·K, elementwise = |out|, data
  movement = 0 flops, ``scan`` bodies × trip count), and
* a runnable ``fn`` (a tiny ``Primitive.bind`` interpreter over the group's
  equations), so the sequential oracle ``Graph.execute`` and the host
  runtime ``HostScheduler`` both execute captured graphs bit-exactly.

This is the Opara-style automatic whole-model capture (arXiv 2312.10351)
replacing the hand-built DAGs: any JAX function — a model forward, an
``lm_loss``, a full train step — becomes a schedulable Graphi graph.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.extend import core as jex

from .graph import Graph

__all__ = ["CapturedGraph", "capture"]


# -- primitive classification ------------------------------------------------

# call-like primitives whose sub-jaxpr is semantically "just run the body":
# inlined so the graph sees the real operator DAG, not opaque call nodes
_INLINE_PRIMS = {
    "pjit", "closed_call", "core_call", "xla_call",
    "remat", "remat2", "checkpoint",
    "custom_jvp_call", "custom_vjp_call",
    "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr",
}
_MAX_INLINE_DEPTH = 32

# pure data movement / layout: zero flops, fused into consumers when possible
_MOVEMENT_PRIMS = {
    "reshape", "broadcast_in_dim", "transpose", "squeeze", "expand_dims",
    "slice", "dynamic_slice", "dynamic_update_slice", "concatenate", "pad",
    "rev", "convert_element_type", "bitcast_convert_type", "copy", "gather",
    "iota", "select_n", "stop_gradient", "sharding_constraint", "device_put",
    "split",
}
_GEMM_PRIMS = {"dot_general"}
_CONV_PRIMS = {"conv_general_dilated"}
_LOOP_PRIMS = {"scan", "while", "fori_loop"}
_REDUCE_PREFIXES = ("reduce_", "cum", "arg")


def _kind_of(prim_name: str) -> str:
    if prim_name in _GEMM_PRIMS:
        return "gemm"
    if prim_name in _CONV_PRIMS:
        return "conv"
    if prim_name in _LOOP_PRIMS:
        return "scan"
    if prim_name == "cond":
        return "control"
    if prim_name in _MOVEMENT_PRIMS:
        return "movement"
    if prim_name.startswith(_REDUCE_PREFIXES) or prim_name == "sort":
        return "reduce"
    return "elementwise"


_FUSABLE_KINDS = ("movement", "elementwise")


# -- aval helpers ------------------------------------------------------------

def _aval_bytes(aval: Any) -> float:
    size = getattr(aval, "size", None)
    dtype = getattr(aval, "dtype", None)
    if size is None or dtype is None:
        return 0.0
    return float(size) * np.dtype(dtype).itemsize


def _aval_size(aval: Any) -> float:
    return float(getattr(aval, "size", 0) or 0)


def _sub_jaxpr(eqn: Any):
    """(open jaxpr, consts) of a call-like eqn's body, or (None, None)."""
    sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
    if sub is None:
        return None, None
    if hasattr(sub, "jaxpr"):          # ClosedJaxpr
        return sub.jaxpr, list(sub.consts)
    return sub, []                      # open Jaxpr (remat)


def _eqn_flops(eqn: Any) -> float:
    """Analytic flop count for one equation (hlo_cost.py conventions)."""
    prim = eqn.primitive.name
    if prim == "dot_general":
        (lhs_c, _), _batch = eqn.params["dimension_numbers"]
        lhs = eqn.invars[0].aval
        k = 1.0
        for d in lhs_c:
            k *= lhs.shape[d]
        return 2.0 * _aval_size(eqn.outvars[0].aval) * k
    if prim == "conv_general_dilated":
        rhs = eqn.invars[1].aval
        dn = eqn.params["dimension_numbers"]
        cout = rhs.shape[dn.rhs_spec[0]] if rhs.shape else 1
        kernel = float(np.prod(rhs.shape)) if rhs.shape else 1.0
        return 2.0 * _aval_size(eqn.outvars[0].aval) * kernel / max(cout, 1)
    if prim in _LOOP_PRIMS or prim == "cond":
        body, _ = _sub_jaxpr(eqn)
        trips = float(eqn.params.get("length", 1)) if prim == "scan" else 1.0
        if body is None and prim == "cond":
            branches = eqn.params.get("branches", ())
            costs = [sum(_eqn_flops(e) for e in b.jaxpr.eqns) for b in branches]
            return max(costs, default=0.0)
        if body is None:
            return 0.0
        return trips * sum(_eqn_flops(e) for e in body.eqns)
    sub, _ = _sub_jaxpr(eqn)
    if sub is not None:
        return sum(_eqn_flops(e) for e in sub.eqns)
    if prim.startswith("scatter"):
        # scatter passes the whole operand through and touches only the
        # updates: price it by the update size, not the output buffer —
        # a paged-KV decode graph writes one token row into a pool whose
        # aval is thousands of times larger than the work done
        upd = eqn.invars[-1].aval if len(eqn.invars) >= 3 else eqn.outvars[0].aval
        return _aval_size(upd)
    kind = _kind_of(prim)
    if kind == "movement":
        return 0.0
    if kind == "reduce":
        return sum(_aval_size(v.aval) for v in eqn.invars[:1]
                   if isinstance(v, jex.Var))
    return sum(_aval_size(v.aval) for v in eqn.outvars)


def _gemm_rows(eqn: Any) -> int | None:
    """M (the paper's MKL panel dimension) of a dot_general, for the
    cost model's tall-skinny scaling cap."""
    if eqn.primitive.name != "dot_general":
        return None
    (lhs_c, _), (lhs_b, _) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    rows = 1
    for d, extent in enumerate(lhs.shape):
        if d not in lhs_c and d not in lhs_b:
            rows *= extent
    return rows


# -- flattening (inline call-like prims) -------------------------------------

def _flatten(eqns, sub_map: dict, constenv: dict, depth: int = 0) -> list:
    """Inline call-like eqns and alpha-rename every binder.

    JAX caches traced sub-jaxprs, so two call sites of the same layer share
    one jaxpr *object* — inlining both without renaming would make one Var
    the output of two eqns.  Every surviving eqn therefore gets fresh
    outvars; ``sub_map`` carries the old→new substitution for its scope.
    """
    out: list = []
    for eqn in eqns:
        invars = [sub_map.get(v, v) if isinstance(v, jex.Var) else v
                  for v in eqn.invars]
        if eqn.primitive.name in _INLINE_PRIMS and depth < _MAX_INLINE_DEPTH:
            sub, consts = _sub_jaxpr(eqn)
            if sub is not None and len(sub.invars) == len(eqn.invars):
                inner: dict = dict(zip(sub.invars, invars))
                for cv, c in zip(sub.constvars, consts):
                    constenv[cv] = c
                out.extend(_flatten(sub.eqns, inner, constenv, depth + 1))
                for outer_ov, sub_ov in zip(eqn.outvars, sub.outvars):
                    sub_map[outer_ov] = (
                        inner.get(sub_ov, sub_ov)
                        if isinstance(sub_ov, jex.Var) else sub_ov
                    )
                continue
        fresh = [jex.Var("", ov.aval) for ov in eqn.outvars]
        for ov, fv in zip(eqn.outvars, fresh):
            sub_map[ov] = fv
        out.append(eqn.replace(invars=invars, outvars=fresh))
    return out


# -- captured graph ----------------------------------------------------------

@dataclass
class CapturedGraph:
    """A :class:`Graph` plus the pytree plumbing to call it like ``fn``.

    ``bind(args)`` maps a concrete argument tuple onto the graph's input
    nodes; ``unflatten(results)`` reassembles ``fn``'s output pytree from a
    per-node result mapping (as produced by ``Graph.execute`` or
    ``HostScheduler.run``); ``run(*args)`` is the sequential oracle.
    """

    graph: Graph
    name: str
    in_tree: Any
    n_in_leaves: int
    input_names: dict[int, str]          # used leaf index -> input node name
    out_tree: Any
    out_spec: list[tuple] = field(repr=False, default_factory=list)
    n_eqns: int = 0                      # flattened eqn count, pre-fusion

    def bind(self, args: Sequence[Any]) -> dict[str, Any]:
        leaves, in_tree = jax.tree_util.tree_flatten(tuple(args))
        if in_tree != self.in_tree or len(leaves) != self.n_in_leaves:
            raise TypeError(
                f"{self.name}: argument structure {in_tree} does not match "
                f"the captured structure {self.in_tree}"
            )
        return {self.input_names[i]: leaves[i] for i in self.input_names}

    def unflatten(self, results: Mapping[str, Any]) -> Any:
        leaves = []
        for spec in self.out_spec:
            if spec[0] == "node":
                _, node, slot, n_slots = spec
                val = results[node]
                leaves.append(val if n_slots == 1 else val[slot])
            elif spec[0] == "input":
                leaves.append(results[self.input_names[spec[1]]])
            else:  # const
                leaves.append(spec[1])
        return jax.tree_util.tree_unflatten(self.out_tree, leaves)

    def run(self, *args: Any) -> Any:
        """Execute via the sequential interpreter (the correctness oracle)."""
        return self.unflatten(self.graph.execute(self.bind(args)))


# -- node fn builder ---------------------------------------------------------

def _bind_eqn(eqn, invals):
    out = eqn.primitive.bind(*invals, **eqn.params)
    return out if eqn.primitive.multiple_results else (out,)


def _make_node_fn(members, imports, const_bindings, exports):
    """Build a node ``fn(*dep_vals) -> value | tuple`` over member eqns.

    ``imports``: per imported var ``(var, dep_index, slot, n_slots)``.
    """

    def run(*dep_vals: Any) -> Any:
        env: dict[Any, Any] = dict(const_bindings)
        for var, dep_idx, slot, n_slots in imports:
            val = dep_vals[dep_idx]
            env[var] = val if n_slots == 1 else val[slot]
        for eqn in members:
            invals = [v.val if isinstance(v, jex.Literal) else env[v]
                      for v in eqn.invars]
            for ov, o in zip(eqn.outvars, _bind_eqn(eqn, invals)):
                env[ov] = o
        vals = tuple(env[v] for v in exports)
        return vals[0] if len(vals) == 1 else vals

    return run


# -- main entry --------------------------------------------------------------

def _leaf_name(i: int, path: Any) -> str:
    raw = jax.tree_util.keystr(path)
    keep = "".join(c for c in raw if c.isalnum() or c in "._")
    keep = keep.strip("._")  # noqa: B005 — char-set strip is the intent
    return f"in.{keep[-48:]}" if keep else f"in.{i}"


def capture(fn, *specs: Any, name: str | None = None, fuse: bool = True) -> CapturedGraph:
    """Trace ``fn(*specs)`` and build the schedulable computation graph.

    ``specs`` may be concrete arrays or :class:`jax.ShapeDtypeStruct`
    pytrees (only shapes/dtypes are read at capture time).  ``fuse=False``
    keeps one node per equation (debugging aid).
    """
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*specs)
    jaxpr = closed.jaxpr
    gname = name or getattr(fn, "__name__", None) or "captured"

    top_map: dict[Any, Any] = {}
    constenv: dict[Any, Any] = dict(zip(jaxpr.constvars, closed.consts))
    eqns = _flatten(jaxpr.eqns, top_map, constenv)

    in_leaves_p = jax.tree_util.tree_flatten_with_path(tuple(specs))[0]
    _, in_tree = jax.tree_util.tree_flatten(tuple(specs))
    invar_leaf = {v: i for i, v in enumerate(jaxpr.invars)}

    producer: dict[Any, int] = {}        # var -> producing eqn index
    for i, e in enumerate(eqns):
        for ov in e.outvars:
            producer[ov] = i

    out_vars = [top_map.get(v, v) if isinstance(v, jex.Var) else v
                for v in jaxpr.outvars]
    out_leaves, out_tree = jax.tree_util.tree_flatten(out_shape)
    del out_leaves

    # consumers of each produced var, by eqn index (graph outputs count too)
    consumers: dict[Any, list[int]] = {}
    for i, e in enumerate(eqns):
        for v in e.invars:
            if isinstance(v, jex.Var) and v in producer:
                consumers.setdefault(v, []).append(i)
    graph_out_vars = {v for v in out_vars if isinstance(v, jex.Var)}

    # fusion: walking consumers-first, a trivial eqn whose outputs all feed
    # exactly one surviving group folds into it.  Producers always precede
    # consumers in a jaxpr, so every group's anchor is its max-index eqn and
    # cross-group edges originate only at anchors — no cycle can form.
    group = list(range(len(eqns)))

    def find(i: int) -> int:
        while group[i] != i:
            group[i] = group[group[i]]
            i = group[i]
        return i

    if fuse:
        for i in range(len(eqns) - 1, -1, -1):
            if _kind_of(eqns[i].primitive.name) not in _FUSABLE_KINDS:
                continue
            if any(ov in graph_out_vars for ov in eqns[i].outvars):
                continue
            targets = {find(c) for ov in eqns[i].outvars
                       for c in consumers.get(ov, [])}
            if len(targets) == 1:
                group[i] = targets.pop()

    members: dict[int, list[int]] = {}
    for i in range(len(eqns)):
        members.setdefault(find(i), []).append(i)

    g = Graph(gname)

    # input source nodes (used leaves only)
    used_leaves: set[int] = set()
    for e in eqns:
        for v in e.invars:
            if isinstance(v, jex.Var) and v in invar_leaf:
                used_leaves.add(invar_leaf[v])
    for v in graph_out_vars:
        if v in invar_leaf:
            used_leaves.add(invar_leaf[v])
    input_names: dict[int, str] = {}
    taken: set[str] = set()
    for i in sorted(used_leaves):
        nm = _leaf_name(i, in_leaves_p[i][0])
        if nm in taken:
            nm = f"{nm}.{i}"
        taken.add(nm)
        input_names[i] = nm
        g.add_op(nm, kind="input", bytes_out=_aval_bytes(jaxpr.invars[i].aval))

    # where does a var live? -> (node name, slot, n_slots)
    var_home: dict[Any, tuple[str, int, int]] = {}
    for i, nm in input_names.items():
        var_home[jaxpr.invars[i]] = (nm, 0, 1)

    prim_counts: dict[str, int] = {}
    node_exports: dict[int, list[Any]] = {}

    for anchor in sorted(members):
        idxs = members[anchor]
        grp_eqns = [eqns[i] for i in idxs]
        own_vars = {ov for e in grp_eqns for ov in e.outvars}

        exports: list[Any] = []
        for e in grp_eqns:
            for ov in e.outvars:
                external = any(find(c) != anchor for c in consumers.get(ov, []))
                if (external or ov in graph_out_vars) and ov not in exports:
                    exports.append(ov)
        if not exports:                   # dead group head: export anchor outs
            exports = [ov for ov in eqns[anchor].outvars]
        node_exports[anchor] = exports

        imports: list[Any] = []
        const_bindings: dict[Any, Any] = {}
        for e in grp_eqns:
            for v in e.invars:
                if not isinstance(v, jex.Var) or v in own_vars:
                    continue
                if v in var_home:
                    if v not in imports:
                        imports.append(v)
                elif v in constenv:
                    const_bindings[v] = constenv[v]
                elif v not in imports:
                    imports.append(v)     # will fail loudly below if unplaced

        dep_names: list[str] = []
        import_spec: list[tuple] = []
        for v in imports:
            home = var_home.get(v)
            if home is None:
                raise ValueError(
                    f"capture({gname}): unplaced variable {v} in group "
                    f"{eqns[anchor].primitive.name}"
                )
            nm, slot, n_slots = home
            if nm not in dep_names:
                dep_names.append(nm)
            import_spec.append((v, dep_names.index(nm), slot, n_slots))

        anchor_eqn = eqns[anchor]
        prim = anchor_eqn.primitive.name
        ordinal = prim_counts.get(prim, 0)
        prim_counts[prim] = ordinal + 1
        node_name = f"{prim}.{ordinal}"

        flops = sum(_eqn_flops(e) for e in grp_eqns)
        bytes_in = sum(_aval_bytes(v.aval) for v in imports)
        bytes_in += sum(float(getattr(c, "nbytes", 0) or 0)
                        for c in const_bindings.values())
        bytes_out = sum(_aval_bytes(v.aval) for v in exports)

        meta: dict[str, Any] = {"n_eqns": len(grp_eqns),
                                "prims": tuple(e.primitive.name for e in grp_eqns),
                                # effect-inference hooks (repro.checks.effects):
                                # the group's jaxpr eqns, its import spec
                                # (var, dep_index, slot, n_slots) and export
                                # vars in slot order — lets the checker trace
                                # which *input buffers* a node reads, writes
                                # (scatter / dynamic_update_slice, incl.
                                # inside scan/while bodies), or passes through
                                "_eqns": tuple(grp_eqns),
                                "_imports": tuple(import_spec),
                                "_exports": tuple(exports)}
        rows = _gemm_rows(anchor_eqn)
        if rows is not None:
            meta["rows"] = rows

        kind = _kind_of(prim)
        g.add_op(
            node_name,
            kind="elementwise" if kind == "movement" else kind,
            flops=flops,
            bytes_in=bytes_in,
            bytes_out=bytes_out,
            deps=tuple(dep_names),
            meta=meta,
            fn=_make_node_fn(grp_eqns, import_spec, const_bindings, exports),
        )
        for slot, v in enumerate(exports):
            var_home[v] = (node_name, slot, len(exports))

    out_spec: list[tuple] = []
    for v in out_vars:
        if isinstance(v, jex.Literal):
            out_spec.append(("const", v.val))
        elif isinstance(v, jex.Var) and v in var_home:
            nm, slot, n_slots = var_home[v]
            if v in invar_leaf:
                out_spec.append(("input", invar_leaf[v]))
            else:
                out_spec.append(("node", nm, slot, n_slots))
        elif v in constenv:
            out_spec.append(("const", constenv[v]))
        else:
            raise ValueError(f"capture({gname}): unplaced output {v}")

    g.validate()
    return CapturedGraph(
        graph=g,
        name=gname,
        in_tree=in_tree,
        n_in_leaves=len(in_leaves_p),
        input_names=input_names,
        out_tree=out_tree,
        out_spec=out_spec,
        n_eqns=len(eqns),
    )
