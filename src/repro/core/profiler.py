"""The Graphi profiler (paper §4.2).

Two jobs:
  1. **Configuration search** — enumerate symmetric executor configurations
     (N executors × K workers each, N·K = available workers) and pick the one
     with minimal makespan.
  2. **Per-op cost table** — modelled via the hardware cost model, or
     *measured* by timing real node ``fn`` executions (usable on this box for
     CPU ops; on a pod, per-group timing feeds the same interface).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

from .cost_model import HardwareModel, graph_costs
from .graph import Graph
from .simulate import SimConfig, simulate

__all__ = ["ProfileResult", "enumerate_symmetric_configs", "profile", "measure_op_costs"]


@dataclass
class ProfileResult:
    best_n_executors: int
    best_team_size: int
    best_makespan: float
    # (n_executors, team_size) -> makespan
    config_makespans: dict[tuple[int, int], float]
    op_costs: dict[str, float] = field(repr=False, default_factory=dict)

    @property
    def best_config(self) -> tuple[int, int]:
        return self.best_n_executors, self.best_team_size


def enumerate_symmetric_configs(n_workers: int, max_executors: int | None = None) -> list[tuple[int, int]]:
    """Symmetric (n_executors, team_size) configs with n_executors a power of
    two and team_size = floor(n_workers / n_executors) (paper §4.2 / §7.3:
    64 usable KNL cores -> 1x64, 2x32, ..., 32x2; leftover cores stay idle)."""
    out: list[tuple[int, int]] = []
    n = 1
    while n <= n_workers and (max_executors is None or n <= max_executors):
        team = n_workers // n
        if team >= 1:
            out.append((n, team))
        n *= 2
    return out


def profile(
    graph: Graph,
    hw: HardwareModel,
    *,
    n_workers: int,
    policy: str = "cpf",
    max_executors: int | None = None,
    extra_configs: list[tuple[int, int]] | None = None,
    measured_costs: Callable[[int], Mapping[str, float]] | None = None,
    seed: int = 0,
) -> ProfileResult:
    """Search symmetric configs; ``measured_costs(team_size)`` optionally
    overrides the analytic cost table (the paper's first-iterations timing).

    ``max_executors`` bounds the sweep (serving wants a cap so one request
    stream cannot claim the whole machine); ``extra_configs`` are explicit
    additions and are *not* re-filtered by the bound.
    """
    configs = enumerate_symmetric_configs(n_workers, max_executors=max_executors)
    if extra_configs:
        configs = sorted(set(configs) | set(extra_configs))
    results: dict[tuple[int, int], float] = {}
    best: tuple[float, int, int] | None = None
    best_costs: dict[str, float] = {}
    for n_exec, team in configs:
        if measured_costs is not None:
            costs = dict(measured_costs(team))
        else:
            costs = graph_costs(hw, graph, team)
        cfg = SimConfig(n_executors=n_exec, team_size=team, policy=policy)
        res = simulate(graph, hw, cfg, costs=costs, seed=seed)
        results[(n_exec, team)] = res.makespan
        if best is None or res.makespan < best[0]:
            best = (res.makespan, n_exec, team)
            best_costs = costs
    if best is None:
        raise RuntimeError("profile enumerated no executor configurations")
    return ProfileResult(
        best_n_executors=best[1],
        best_team_size=best[2],
        best_makespan=best[0],
        config_makespans=results,
        op_costs=best_costs,
    )


def measure_op_costs(
    graph: Graph,
    inputs: Mapping[str, Any] | None = None,
    *,
    warmup: int = 1,
    iters: int = 3,
    block: Callable[[Any], Any] | None = None,
) -> dict[str, float]:
    """Measured per-op durations by executing node ``fn``s (paper's profiler
    records start/end over the first few iterations and averages).

    ``block``: result-synchronizer (e.g. ``lambda x: jax.block_until_ready(x)``)
    so async dispatch does not distort timings.
    """
    sync = block or (lambda x: x)
    outs = graph.execute(inputs)  # warm caches / compile
    costs: dict[str, float] = {}
    for n in graph.topo_order():
        node = graph[n]
        if node.fn is None:
            costs[n] = 0.0
            continue
        args = [outs[d] for d in node.deps]
        for _ in range(warmup):
            sync(node.fn(*args))
        t0 = time.perf_counter()
        for _ in range(iters):
            sync(node.fn(*args))
        costs[n] = (time.perf_counter() - t0) / iters
    return costs
