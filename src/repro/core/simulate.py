"""Discrete-event simulator of the Graphi execution engine.

Replays the paper's runtime (centralized scheduler + N symmetric executors,
per-executor buffers vs a naive shared global queue) under a
:class:`~repro.core.cost_model.HardwareModel`.  This is the *measurement
instrument* for every paper-table reproduction on this CPU-only box: the
scheduling semantics are exact (online greedy list scheduling, dependency
triggering, dispatch serialization); the op durations come from the cost
model (optionally jittered to model run-time variation, paper §4.3).

Policies
--------
``SimConfig.policy`` is either a *registry* policy — a name (or instance)
resolved through :mod:`repro.core.policies`: ``cpf``, ``level-pack``,
``lpt``, ``cpf-perturb``, plus anything user-registered — or one of the two
naive shared-queue baselines the paper compares against:

* registry policies run the Graphi dispatch path: centralized scheduler
  orders ready ops by the policy's priority (stable node-id tiebreak) and
  pushes to per-executor buffers; dispatch costs ``cpf_push_cost``
  (serialized at the scheduler core, cheap — bitmap scan + ring-buffer
  push).  A policy's optional executor-assignment hook steers ops among
  the executors free earliest.
* ``fifo``   — naive shared queue in trigger order (TensorFlow/MXNet style).
  Each dequeue serializes on the queue lock and costs
  ``queue_base_cost + queue_contention_cost × (#free executors polling)``.
* ``random`` — naive shared queue, arbitrary ready op (MXNet-style "any
  executor grabs any ready op").

Determinism: ready ops with equal priority pop in stable **node-id order**
(graph insertion index), never in dict/hash order, so two simulations of
one graph produce identical traces — the schedule-search winner is
reproducible run to run (tests/test_policies_search.py).
"""
from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field

from .cost_model import HardwareModel, graph_costs
from .graph import Graph
from .policies import NAIVE_POLICIES, PolicyContext, SchedulePolicy, get_policy

__all__ = ["SimConfig", "SimResult", "TraceEvent", "simulate"]


@dataclass(frozen=True)
class TraceEvent:
    op: str
    executor: int
    start: float
    end: float


@dataclass(frozen=True)
class SimConfig:
    n_executors: int
    team_size: int
    # a repro.core.policies registry name/instance, or "fifo"/"random"
    policy: "str | SchedulePolicy" = "cpf"
    # dispatch-path costs (seconds).  The shared-queue costs are calibrated
    # to KNL lock handoff under contention (cache-line ping-pong across the
    # 2D mesh at 1.4 GHz is ~us-scale per waiter; the paper's Table-2
    # 8-19% gap is the macro observable this reproduces).
    cpf_push_cost: float = 0.3e-6
    queue_base_cost: float = 1.0e-6
    queue_contention_cost: float = 1.5e-6
    # interference (paper Fig 3 / §3.1).  ``duration_multiplier`` is the
    # legacy scalar guess: multiplies every op duration uniformly.
    # ``contention`` is the measured replacement — an object with
    # ``multiplier_for(node, co_resident_nodes) -> float``
    # (repro.hwperf.model.ContentionModel): each op's duration is scaled by
    # the worst measured pairwise slowdown against the ops co-resident at
    # its dispatch.  Both compose (scalar first) for A/B comparisons.
    duration_multiplier: float = 1.0
    contention: object | None = None
    # run-time variation (paper §4.3, "unpredictable variations")
    jitter: float = 0.0
    # TP collective term applies when an op is sharded over a linked fabric
    tp_collective: bool = True
    # paper §6 "data cache locality": prefer the executor that produced an
    # op's input; matched elementwise ops run faster (L2-resident input),
    # GEMMs don't (MKL blocking defeats affinity — the paper's finding)
    cache_affinity: bool = False
    affinity_speedup: dict | None = None   # kind -> fractional speedup


@dataclass
class SimResult:
    makespan: float
    trace: list[TraceEvent]
    config: SimConfig
    op_costs: dict[str, float] = field(repr=False, default_factory=dict)

    @property
    def busy_time(self) -> float:
        return sum(e.end - e.start for e in self.trace)

    @property
    def utilization(self) -> float:
        denom = self.makespan * self.config.n_executors
        return self.busy_time / denom if denom else 0.0

    def executor_timeline(self) -> dict[int, list[TraceEvent]]:
        out: dict[int, list[TraceEvent]] = {e: [] for e in range(self.config.n_executors)}
        for ev in self.trace:
            out[ev.executor].append(ev)
        for evs in out.values():
            evs.sort(key=lambda e: e.start)
        return out

    def start_order(self) -> list[str]:
        return [e.op for e in sorted(self.trace, key=lambda e: (e.start, e.op))]


def simulate(
    graph: Graph,
    hw: HardwareModel,
    cfg: SimConfig,
    *,
    costs: dict[str, float] | None = None,
    seed: int = 0,
) -> SimResult:
    """Run the event-driven engine simulation and return the makespan+trace."""
    naive = isinstance(cfg.policy, str) and cfg.policy in NAIVE_POLICIES
    policy: SchedulePolicy | None = None if naive else get_policy(cfg.policy)
    rng = random.Random(seed)

    if costs is None:
        costs = graph_costs(hw, graph, cfg.team_size, tp_collective=cfg.tp_collective)
    levels = graph.levels(costs)

    indeg = {n: graph.in_degree(n) for n in graph.names}
    ready_time: dict[str, float] = {}
    # stable node-id order (graph insertion index): THE tiebreak for
    # equal-priority ready ops, and the only ordering policies' priority
    # dicts are ever combined with — never dict/hash order.  This is what
    # makes search scores (and the chosen winner) reproducible run-to-run.
    seq = {n: i for i, n in enumerate(graph.names)}

    if policy is not None:
        ctx = PolicyContext(
            graph=graph, costs=costs, levels=levels,
            depths=graph.depth_levels(), n_executors=cfg.n_executors,
            seed=seed,
        )
        prio = policy.priorities(ctx)

    # ready-op container: priority heap for registry policies, trigger-order
    # list for the naive shared-queue baselines
    ready_heap: list[tuple[float, int, str]] = []     # (-priority, node_id, name)
    fifo_list: list[str] = []

    def push_ready(n: str, t: float) -> None:
        ready_time[n] = t
        if policy is not None:
            heapq.heappush(ready_heap, (-prio[n], seq[n], n))
        else:
            fifo_list.append(n)

    def pop_ready() -> str:
        if policy is not None:
            return heapq.heappop(ready_heap)[-1]
        if cfg.policy == "fifo":
            return fifo_list.pop(0)
        i = rng.randrange(len(fifo_list))
        return fifo_list.pop(i)

    def have_ready() -> bool:
        return bool(ready_heap) if policy is not None else bool(fifo_list)

    for n in graph.names:
        if indeg[n] == 0:
            push_ready(n, 0.0)

    exec_free: list[tuple[float, int]] = [(0.0, e) for e in range(cfg.n_executors)]
    heapq.heapify(exec_free)
    completions: list[tuple[float, int, str, int]] = []  # (end, seq, op, executor)
    dispatch_free = 0.0  # serialization point (queue lock / scheduler core)
    trace: list[TraceEvent] = []
    n_done = 0
    total = len(graph)
    producer_exec: dict[str, int] = {}   # op -> executor that ran it (§6)
    affinity = cfg.affinity_speedup or {"elementwise": 0.08}

    def process_completion() -> None:
        nonlocal n_done
        end, _, op, e = heapq.heappop(completions)
        n_done += 1
        producer_exec[op] = e
        heapq.heappush(exec_free, (end, e))
        for s in graph.successors(op):
            indeg[s] -= 1
            if indeg[s] == 0:
                push_ready(s, end)

    while n_done < total:
        if have_ready() and exec_free:
            ft, e = exec_free[0]
            if completions and completions[0][0] < ft:
                # an earlier completion may ready a higher-priority op
                process_completion()
                continue
            heapq.heappop(exec_free)
            op = pop_ready()
            want: int | None = None
            if policy is not None:
                # the policy's assignment hook picks among executors free no
                # later than the earliest one — a placement choice only,
                # never a delay
                free_now = tuple(sorted(
                    [e] + [e2 for ft2, e2 in exec_free if ft2 <= ft]))
                want = policy.assign_executor(ctx, op, free_now)
            if want is None and cfg.cache_affinity:
                # prefer the producer of op's (first) input when it is also
                # free at the same time (the paper's "preferred executor")
                prefs = {producer_exec.get(d) for d in graph.predecessors(op)}
                if e not in prefs:
                    want = next((e2 for ft2, e2 in exec_free
                                 if ft2 <= ft and e2 in prefs), None)
            if want is not None and want != e:
                for i, (ft2, e2) in enumerate(exec_free):
                    if e2 == want and ft2 <= ft:
                        exec_free[i] = (ft, e)
                        heapq.heapify(exec_free)
                        e = want
                        break
            t0 = max(ft, ready_time[op])
            # dispatch serialization.  Naive shared queue: every executor
            # polls the one lock continuously (paper §3.1 "heavy concurrent
            # use"), so each dequeue pays handoff x #executors — not just
            # the currently-idle ones.
            if policy is not None:
                deq = cfg.cpf_push_cost
            else:
                deq = cfg.queue_base_cost + cfg.queue_contention_cost * cfg.n_executors
            start = max(t0, dispatch_free) + deq
            dispatch_free = start
            dur = costs[op] * cfg.duration_multiplier
            if cfg.contention is not None:
                # measured interference: ops still in flight at this op's
                # start are its co-residents; scale by the worst pairwise
                # class slowdown the co-location harness measured
                co = [graph[o] for (c_end, _, o, _) in completions
                      if c_end > start]
                dur *= cfg.contention.multiplier_for(graph[op], co)
            if cfg.cache_affinity and any(
                producer_exec.get(d) == e for d in graph.predecessors(op)
            ):
                dur *= 1.0 - affinity.get(graph[op].kind, 0.0)
            if cfg.jitter:
                dur *= max(0.05, 1.0 + cfg.jitter * rng.gauss(0.0, 1.0))
            end = start + dur
            heapq.heappush(completions, (end, seq[op], op, e))
            trace.append(TraceEvent(op, e, start, end))
        else:
            process_completion()

    makespan = max((e.end for e in trace), default=0.0)
    return SimResult(makespan=makespan, trace=trace, config=cfg, op_costs=dict(costs))
