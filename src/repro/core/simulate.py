"""Discrete-event simulator of the Graphi execution engine.

Replays the paper's runtime (centralized scheduler + N symmetric executors,
per-executor buffers vs a naive shared global queue) under a
:class:`~repro.core.cost_model.HardwareModel`.  This is the *measurement
instrument* for every paper-table reproduction on this CPU-only box: the
scheduling semantics are exact (online greedy list scheduling, dependency
triggering, dispatch serialization); the op durations come from the cost
model (optionally jittered to model run-time variation, paper §4.3).

Policies
--------
* ``cpf``    — critical-path-first: ready ops ordered by *level* (longest
  accumulated cost to the sink), scheduler pushes to per-executor buffers.
  Dispatch costs ``cpf_push_cost`` (serialized at the scheduler core, cheap —
  bitmap scan + ring-buffer push).
* ``fifo``   — naive shared queue in trigger order (TensorFlow/MXNet style).
  Each dequeue serializes on the queue lock and costs
  ``queue_base_cost + queue_contention_cost × (#free executors polling)``.
* ``random`` — naive shared queue, arbitrary ready op (MXNet-style "any
  executor grabs any ready op").
"""
from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field

from .cost_model import HardwareModel, graph_costs
from .graph import Graph

__all__ = ["SimConfig", "SimResult", "TraceEvent", "simulate"]


@dataclass(frozen=True)
class TraceEvent:
    op: str
    executor: int
    start: float
    end: float


@dataclass(frozen=True)
class SimConfig:
    n_executors: int
    team_size: int
    policy: str = "cpf"              # cpf | fifo | random
    # dispatch-path costs (seconds).  The shared-queue costs are calibrated
    # to KNL lock handoff under contention (cache-line ping-pong across the
    # 2D mesh at 1.4 GHz is ~us-scale per waiter; the paper's Table-2
    # 8-19% gap is the macro observable this reproduces).
    cpf_push_cost: float = 0.3e-6
    queue_base_cost: float = 1.0e-6
    queue_contention_cost: float = 1.5e-6
    # interference (paper Fig 3 / §3.1): multiplies every op duration
    duration_multiplier: float = 1.0
    # run-time variation (paper §4.3, "unpredictable variations")
    jitter: float = 0.0
    # TP collective term applies when an op is sharded over a linked fabric
    tp_collective: bool = True
    # paper §6 "data cache locality": prefer the executor that produced an
    # op's input; matched elementwise ops run faster (L2-resident input),
    # GEMMs don't (MKL blocking defeats affinity — the paper's finding)
    cache_affinity: bool = False
    affinity_speedup: dict | None = None   # kind -> fractional speedup


@dataclass
class SimResult:
    makespan: float
    trace: list[TraceEvent]
    config: SimConfig
    op_costs: dict[str, float] = field(repr=False, default_factory=dict)

    @property
    def busy_time(self) -> float:
        return sum(e.end - e.start for e in self.trace)

    @property
    def utilization(self) -> float:
        denom = self.makespan * self.config.n_executors
        return self.busy_time / denom if denom else 0.0

    def executor_timeline(self) -> dict[int, list[TraceEvent]]:
        out: dict[int, list[TraceEvent]] = {e: [] for e in range(self.config.n_executors)}
        for ev in self.trace:
            out[ev.executor].append(ev)
        for evs in out.values():
            evs.sort(key=lambda e: e.start)
        return out

    def start_order(self) -> list[str]:
        return [e.op for e in sorted(self.trace, key=lambda e: (e.start, e.op))]


def simulate(
    graph: Graph,
    hw: HardwareModel,
    cfg: SimConfig,
    *,
    costs: dict[str, float] | None = None,
    seed: int = 0,
) -> SimResult:
    """Run the event-driven engine simulation and return the makespan+trace."""
    if cfg.policy not in ("cpf", "fifo", "random"):
        raise ValueError(f"unknown policy {cfg.policy!r}")
    rng = random.Random(seed)

    if costs is None:
        costs = graph_costs(hw, graph, cfg.team_size, tp_collective=cfg.tp_collective)
    levels = graph.levels(costs)

    indeg = {n: graph.in_degree(n) for n in graph.names}
    ready_time: dict[str, float] = {}

    # ready-op container per policy
    cpf_heap: list[tuple[float, str]] = []            # (-level, name)
    fifo_list: list[str] = []
    seq = {n: i for i, n in enumerate(graph.names)}   # deterministic tiebreak

    def push_ready(n: str, t: float) -> None:
        ready_time[n] = t
        if cfg.policy == "cpf":
            heapq.heappush(cpf_heap, (-levels[n], seq[n], n))  # type: ignore[arg-type]
        else:
            fifo_list.append(n)

    def pop_ready() -> str:
        if cfg.policy == "cpf":
            return heapq.heappop(cpf_heap)[-1]
        if cfg.policy == "fifo":
            return fifo_list.pop(0)
        i = rng.randrange(len(fifo_list))
        return fifo_list.pop(i)

    def have_ready() -> bool:
        return bool(cpf_heap) if cfg.policy == "cpf" else bool(fifo_list)

    for n in graph.names:
        if indeg[n] == 0:
            push_ready(n, 0.0)

    exec_free: list[tuple[float, int]] = [(0.0, e) for e in range(cfg.n_executors)]
    heapq.heapify(exec_free)
    completions: list[tuple[float, int, str, int]] = []  # (end, seq, op, executor)
    dispatch_free = 0.0  # serialization point (queue lock / scheduler core)
    trace: list[TraceEvent] = []
    n_done = 0
    total = len(graph)
    producer_exec: dict[str, int] = {}   # op -> executor that ran it (§6)
    affinity = cfg.affinity_speedup or {"elementwise": 0.08}

    def process_completion() -> None:
        nonlocal n_done
        end, _, op, e = heapq.heappop(completions)
        n_done += 1
        producer_exec[op] = e
        heapq.heappush(exec_free, (end, e))
        for s in graph.successors(op):
            indeg[s] -= 1
            if indeg[s] == 0:
                push_ready(s, end)

    while n_done < total:
        if have_ready() and exec_free:
            ft, e = exec_free[0]
            if completions and completions[0][0] < ft:
                # an earlier completion may ready a higher-priority op
                process_completion()
                continue
            heapq.heappop(exec_free)
            op = pop_ready()
            if cfg.cache_affinity:
                # prefer the producer of op's (first) input when it is also
                # free at the same time (the paper's "preferred executor")
                prefs = {producer_exec.get(d) for d in graph.predecessors(op)}
                if e not in prefs:
                    for i, (ft2, e2) in enumerate(exec_free):
                        if ft2 <= ft and e2 in prefs:
                            exec_free[i] = (ft, e)
                            heapq.heapify(exec_free)
                            e = e2
                            break
            t0 = max(ft, ready_time[op])
            # dispatch serialization.  Naive shared queue: every executor
            # polls the one lock continuously (paper §3.1 "heavy concurrent
            # use"), so each dequeue pays handoff x #executors — not just
            # the currently-idle ones.
            if cfg.policy == "cpf":
                deq = cfg.cpf_push_cost
            else:
                deq = cfg.queue_base_cost + cfg.queue_contention_cost * cfg.n_executors
            start = max(t0, dispatch_free) + deq
            dispatch_free = start
            dur = costs[op] * cfg.duration_multiplier
            if cfg.cache_affinity and any(
                producer_exec.get(d) == e for d in graph.predecessors(op)
            ):
                dur *= 1.0 - affinity.get(graph[op].kind, 0.0)
            if cfg.jitter:
                dur *= max(0.05, 1.0 + cfg.jitter * rng.gauss(0.0, 1.0))
            end = start + dur
            heapq.heappush(completions, (end, seq[op], op, e))
            trace.append(TraceEvent(op, e, start, end))
        else:
            process_completion()

    makespan = max((e.end for e in trace), default=0.0)
    return SimResult(makespan=makespan, trace=trace, config=cfg, op_costs=dict(costs))
