"""Graphi core: computation-graph scheduling engine (the paper's contribution).

Public API re-exports.
"""
from .capture import CapturedGraph, capture
from .cost_model import (
    KNL7250,
    TPUV5E,
    HardwareModel,
    graph_costs,
    interference_multiplier,
    op_saturation_point,
    op_time,
    sequential_makespan,
)
from .engine import HostRunResult, HostScheduler
from .graph import Graph, GraphValidationError, OpNode
from .policies import (
    NAIVE_POLICIES,
    PolicyContext,
    SchedulePolicy,
    get_policy,
    list_policies,
    register_policy,
    unregister_policy,
)
from .profiler import ProfileResult, enumerate_symmetric_configs, measure_op_costs, profile
from .scheduler import Schedule, make_schedule, slot_assignment
from .search import SearchResult, search_schedule
from .simulate import SimConfig, SimResult, TraceEvent, simulate
from .static_host import StaticHostPlan, compile_host_plan
from .trace import ascii_timeline, trace_csv
from .wavefront import (
    diagonals,
    is_wavefront_order,
    lstm_cell,
    recurrence_graph,
    sequential_lstm,
    stacked_wavefront_lstm,
)

__all__ = [
    "KNL7250",
    "TPUV5E",
    "CapturedGraph",
    "HardwareModel",
    "Graph",
    "GraphValidationError",
    "OpNode",
    "capture",
    "HostRunResult",
    "HostScheduler",
    "NAIVE_POLICIES",
    "PolicyContext",
    "ProfileResult",
    "Schedule",
    "SchedulePolicy",
    "SearchResult",
    "SimConfig",
    "SimResult",
    "StaticHostPlan",
    "TraceEvent",
    "ascii_timeline",
    "compile_host_plan",
    "trace_csv",
    "diagonals",
    "enumerate_symmetric_configs",
    "get_policy",
    "graph_costs",
    "interference_multiplier",
    "is_wavefront_order",
    "list_policies",
    "lstm_cell",
    "make_schedule",
    "measure_op_costs",
    "op_saturation_point",
    "op_time",
    "profile",
    "recurrence_graph",
    "register_policy",
    "search_schedule",
    "sequential_lstm",
    "sequential_makespan",
    "simulate",
    "slot_assignment",
    "stacked_wavefront_lstm",
    "unregister_policy",
]
