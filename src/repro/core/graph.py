"""Computation-graph IR for the Graphi scheduling engine.

A :class:`Graph` is a DAG of :class:`OpNode`. Nodes carry the roofline-relevant
statistics (flops / bytes in / bytes out) that the cost model consumes, plus an
optional ``fn`` so the host engine can actually *execute* the graph (fn takes
the dep outputs in ``deps`` order and returns this node's output).

This mirrors the paper's abstraction (Section 2): nodes are operations
(GEMM / conv / elementwise / ...), edges are data dependencies.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Iterable, Mapping, Sequence

__all__ = ["OpNode", "Graph", "GraphValidationError"]


class GraphValidationError(ValueError):
    pass


@dataclass(frozen=True)
class OpNode:
    """One operation in the computation graph."""

    name: str
    kind: str = "generic"  # gemm | elementwise | conv | attention | scan | ...
    flops: float = 0.0
    bytes_in: float = 0.0
    bytes_out: float = 0.0
    deps: tuple[str, ...] = ()
    meta: Mapping[str, Any] = field(default_factory=dict)
    fn: Callable[..., Any] | None = None

    @property
    def bytes_total(self) -> float:
        return self.bytes_in + self.bytes_out

    def with_deps(self, deps: Sequence[str]) -> "OpNode":
        return replace(self, deps=tuple(deps))


class Graph:
    """Directed acyclic computation graph (insertion-ordered)."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self._nodes: dict[str, OpNode] = {}
        self._succs: dict[str, list[str]] = {}
        self._succ_tuples: dict[str, tuple[str, ...]] = {}
        self._version = 0
        self._succ_version = 0

    # -- construction ------------------------------------------------------
    def add(self, node: OpNode) -> OpNode:
        if node.name in self._nodes:
            raise GraphValidationError(f"duplicate node {node.name!r}")
        for d in node.deps:
            if d not in self._nodes:
                raise GraphValidationError(
                    f"node {node.name!r} depends on unknown node {d!r}"
                )
        self._nodes[node.name] = node
        self._succs[node.name] = []
        for d in node.deps:
            self._succs[d].append(node.name)
        self._version += 1
        return node

    @property
    def version(self) -> int:
        """Mutation counter, bumped on every :meth:`add`.

        The single staleness guard for everything derived from the graph's
        structure: cached successor tuples, :class:`HostScheduler` hoisted
        immutables, compiled :class:`StaticHostPlan`\\ s, and
        ``repro.checks`` analyses all record the version they were built
        against and refuse (or rebuild) when the graph has grown since.
        """
        return self._version

    def add_op(self, name: str, **kw: Any) -> OpNode:
        deps = tuple(kw.pop("deps", ()))
        return self.add(OpNode(name=name, deps=deps, **kw))

    # -- queries -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._nodes)

    def __contains__(self, name: str) -> bool:
        return name in self._nodes

    def __getitem__(self, name: str) -> OpNode:
        return self._nodes[name]

    @property
    def nodes(self) -> list[OpNode]:
        return list(self._nodes.values())

    @property
    def names(self) -> list[str]:
        return list(self._nodes)

    def successors(self, name: str) -> tuple[str, ...]:
        """Consumers of ``name`` as a cached immutable tuple.

        Hit once per op per run by every runtime (dynamic scheduler,
        simulator, plan compiler) — a fresh list copy per call was pure
        per-op overhead.  The cache invalidates via :attr:`version`.
        """
        if self._succ_version != self._version:
            self._succ_tuples.clear()
            self._succ_version = self._version
        t = self._succ_tuples.get(name)
        if t is None:
            t = self._succ_tuples[name] = tuple(self._succs[name])
        return t

    def predecessors(self, name: str) -> tuple[str, ...]:
        """Dependencies of ``name`` — the node's own (immutable) dep tuple."""
        return self._nodes[name].deps

    def in_degree(self, name: str) -> int:
        return len(self._nodes[name].deps)

    def sources(self) -> list[str]:
        return [n for n in self._nodes if not self._nodes[n].deps]

    def sinks(self) -> list[str]:
        return [n for n in self._nodes if not self._succs[n]]

    def total_flops(self) -> float:
        return sum(n.flops for n in self._nodes.values())

    def total_bytes(self) -> float:
        return sum(n.bytes_total for n in self._nodes.values())

    # -- orderings & structure ----------------------------------------------
    def topo_order(self) -> list[str]:
        """Kahn topological order (deterministic: insertion-order tiebreak)."""
        indeg = {n: self.in_degree(n) for n in self._nodes}
        order_index = {n: i for i, n in enumerate(self._nodes)}
        ready: list[tuple[int, str]] = [
            (order_index[n], n) for n, d in indeg.items() if d == 0
        ]
        heapq.heapify(ready)
        out: list[str] = []
        while ready:
            _, n = heapq.heappop(ready)
            out.append(n)
            for s in self._succs[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    heapq.heappush(ready, (order_index[s], s))
        if len(out) != len(self._nodes):
            raise GraphValidationError(f"graph {self.name!r} has a cycle")
        return out

    def validate(self) -> None:
        self.topo_order()  # raises on cycles

    def depth_levels(self) -> dict[str, int]:
        """Unit-cost longest path *from sources* (the ASAP wave index)."""
        lev: dict[str, int] = {}
        for n in self.topo_order():
            node = self._nodes[n]
            lev[n] = 0 if not node.deps else 1 + max(lev[d] for d in node.deps)
        return lev

    def width(self) -> int:
        """Parallelism width: max #ops sharing an ASAP wave (antichain lower
        bound — matches the paper's 'number of parallelizable operations')."""
        lev = self.depth_levels()
        counts: dict[int, int] = {}
        for v in lev.values():
            counts[v] = counts.get(v, 0) + 1
        return max(counts.values()) if counts else 0

    def levels(self, costs: Mapping[str, float]) -> dict[str, float]:
        """Paper §4.3 *level* value: longest accumulated cost from the op to
        the sink, **inclusive** of the op itself."""
        lev: dict[str, float] = {}
        for n in reversed(self.topo_order()):
            succ = self._succs[n]
            tail = max((lev[s] for s in succ), default=0.0)
            lev[n] = costs[n] + tail
        return lev

    def critical_path(self, costs: Mapping[str, float]) -> tuple[float, list[str]]:
        """(length, node list) of the longest-cost path source→sink.

        The maximum level is always attained at a source (levels are
        non-increasing along edges), and the path follows max-level
        successors all the way to a sink — zero-cost tail ops (a free
        concat/loss node) are still on the path.
        """
        lev = self.levels(costs)
        if not self._nodes:
            return 0.0, []
        cur = max(self.sources(), key=lambda n: lev[n])
        path = [cur]
        while self._succs[cur]:
            cur = max(self._succs[cur], key=lambda s: lev[s])
            path.append(cur)
        return lev[path[0]], path

    # -- execution ----------------------------------------------------------
    def execute(self, inputs: Mapping[str, Any] | None = None) -> dict[str, Any]:
        """Reference sequential interpreter (topological order).

        Source nodes take their value from ``inputs[name]`` if given, else
        ``fn()`` with no args. Used as the correctness oracle for every
        parallel execution path.
        """
        inputs = dict(inputs or {})
        out: dict[str, Any] = {}
        for n in self.topo_order():
            node = self._nodes[n]
            if not node.deps and n in inputs:
                out[n] = inputs[n]
            elif node.fn is None:
                raise GraphValidationError(f"node {n!r} has no fn and no input")
            else:
                out[n] = node.fn(*[out[d] for d in node.deps])
        return out

    # -- misc ----------------------------------------------------------------
    def subgraph(self, names: Iterable[str]) -> "Graph":
        keep = set(names)
        g = Graph(f"{self.name}.sub")
        for n in self.topo_order():
            if n in keep:
                node = self._nodes[n]
                g.add(node.with_deps([d for d in node.deps if d in keep]))
        return g

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Graph({self.name!r}, n={len(self)}, width={self.width()}, "
            f"flops={self.total_flops():.3g})"
        )
