"""Analytic per-op cost model (the profiler's backend on a box without the
target hardware).

The paper measures per-op durations on KNL during the first iterations
(Section 4.2).  On this container we cannot time KNL or TPU ops, so the
profiler consumes a *roofline-based hardware model* instead:

    T(op, k) = alpha(k) + max( compute_term(op, k),
                               memory_term(op, k) )  + collective_term(op, k)

with a **granularity cap** `k_eff = clip(parallel_grains(op), 1, k)` modelling
the paper's Fig-2 observation that a small op stops scaling beyond the number
of efficiently-parallelizable work grains (GEMM [64,512]x[512,512] saturates
at ~8 KNL cores; a 32k elementwise at ~16).

Two calibrated models ship:

* ``KNL7250``  — Intel Xeon Phi 7250 (the paper's hardware), used by the
  paper-table reproduction benchmarks.
* ``TPUV5E``   — one TPU v5e chip as the "worker" of a pod-scale executor
  group (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI), used by the
  scheduling analysis for the assigned architectures.

All times are **seconds**.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .graph import Graph, OpNode

__all__ = [
    "HardwareModel",
    "KNL7250",
    "TPUV5E",
    "op_time",
    "op_saturation_point",
    "graph_costs",
]


@dataclass(frozen=True)
class HardwareModel:
    name: str
    n_workers: int                # cores (KNL) / chips (pod)
    peak_flops: float             # per worker, achievable units/s at frac=1
    achievable_frac: float        # library efficiency ceiling (MKL / MXU)
    mem_bw_total: float           # shared memory bandwidth (B/s)
    mem_bw_per_worker: float      # max single-worker draw (B/s)
    dispatch_alpha: float         # fixed per-op launch / fork cost (s)
    team_beta: float              # extra barrier cost per log2(team size) (s)
    link_bw: float                # inter-worker interconnect (B/s); 0 = shared-mem
    grain_flops: float            # compute per efficiently-parallel grain
    grain_bytes: float            # bytes per efficiently-parallel grain
    workers_per_tile: int = 1     # workers sharing a cache tile (KNL: 2/L2)

    @property
    def peak_total(self) -> float:
        return self.n_workers * self.peak_flops * self.achievable_frac


# The paper's machine: 68 cores @1.4 GHz, AVX-512 (2 VPU): 32 fp32 FMA/cycle
# -> ~90 GF/s/core single precision peak; MKL large-GEMM efficiency ~55%.
# MCDRAM ~400+ GB/s total, ~12 GB/s single-core draw. OpenMP fork ~5 us.
# grain_flops calibrated so GEMM [64,512,512] (33.6 MF) saturates ~8 cores,
# grain_bytes so a 32k-element eltwise (~0.4 MB traffic) saturates ~16.
KNL7250 = HardwareModel(
    name="knl7250",
    n_workers=68,
    peak_flops=89.6e9,
    achievable_frac=0.55,
    mem_bw_total=420e9,
    mem_bw_per_worker=12e9,
    dispatch_alpha=5e-6,
    team_beta=2e-6,
    link_bw=0.0,
    grain_flops=4.2e6,
    grain_bytes=24e3,
    workers_per_tile=2,
)

# TPU v5e chip as a pod worker. grain_flops = one 128x128x512 MXU macro-tile;
# grain_bytes = one 128x512 bf16 block stream. dispatch_alpha models the
# per-op XLA launch + ICI barrier entry (~2 us).
TPUV5E = HardwareModel(
    name="tpuv5e",
    n_workers=256,
    peak_flops=197e12,
    achievable_frac=0.62,
    mem_bw_total=256 * 819e9,
    mem_bw_per_worker=819e9,
    dispatch_alpha=2e-6,
    team_beta=1e-6,
    link_bw=50e9,
    grain_flops=2 * 128 * 128 * 512,
    grain_bytes=128 * 512 * 2,
    workers_per_tile=1,
)


def parallel_grains(hw: HardwareModel, op: OpNode) -> tuple[float, float]:
    """(compute grains, memory grains): how many workers each roofline term
    of this op can keep efficiently busy (the Fig-2 knee). The caps apply
    *per term* — extra memory parallelism cannot stretch a compute-bound op.

    GEMM shape cap: MKL parallelizes panels of the row dimension, so a
    tall-skinny [M=64, ...] GEMM stops scaling near M/8 threads no matter
    how many total flops it has — this is what makes the paper's Fig-2a
    [64,512]x[512,512] knee sit at 8 cores while a 16x-flops LSTM-large
    GEMM *still* saturates early (the whole premise of multi-executor
    scheduling).  Nodes advertise ``meta["rows"]``.
    """
    g_c = max(1.0, op.flops / hw.grain_flops) if op.flops else 1.0
    rows = op.meta.get("rows") if op.meta else None
    if rows is not None and op.kind in ("gemm", "conv"):
        g_c = min(g_c, max(1.0, rows / 8.0))
    g_m = max(1.0, op.bytes_total / hw.grain_bytes) if op.bytes_total else 1.0
    return g_c, g_m


def op_saturation_point(hw: HardwareModel, op: OpNode) -> int:
    """Smallest power-of-two team size at/beyond which adding workers stops
    reducing ``op_time`` (the knee of the paper's Fig 2)."""
    best_k, best_t = 1, op_time(hw, op, 1)
    k = 2
    while k <= hw.n_workers:
        t = op_time(hw, op, k)
        if t < best_t * (1.0 - 1e-3):
            best_k, best_t = k, t
        k *= 2
    return best_k


def op_time(hw: HardwareModel, op: OpNode, k: int, *, tp_collective: bool = True) -> float:
    """Modelled duration of ``op`` on a team of ``k`` workers.

    ``tp_collective``: when the op is *sharded* k ways on a linked fabric
    (TPU tensor-parallelism), its partial results must be combined — a ring
    all-reduce of the output, 2(k-1)/k * bytes_out per worker over ICI.
    Shared-memory CPUs (link_bw == 0) pay nothing (the paper's executors
    share MCDRAM).
    """
    if k < 1:
        raise ValueError(f"team size must be >= 1, got {k}")
    k = min(k, hw.n_workers)
    g_c, g_m = parallel_grains(hw, op)
    k_c = min(float(k), g_c)
    k_m = min(float(k), g_m)

    alpha = hw.dispatch_alpha + hw.team_beta * math.log2(k) if k > 1 else hw.dispatch_alpha

    compute = op.flops / (k_c * hw.peak_flops * hw.achievable_frac) if op.flops else 0.0

    bw = min(k_m * hw.mem_bw_per_worker, hw.mem_bw_total)
    memory = op.bytes_total / bw if op.bytes_total else 0.0

    comm = 0.0
    if tp_collective and k > 1 and hw.link_bw > 0 and op.bytes_out:
        comm = 2.0 * (k - 1) / k * op.bytes_out / hw.link_bw

    return alpha + max(compute, memory) + comm


def graph_costs(
    hw: HardwareModel, graph: Graph, team_size: int, *, tp_collective: bool = True
) -> dict[str, float]:
    """Per-op modelled cost table for a symmetric executor configuration."""
    return {
        n.name: op_time(hw, n, team_size, tp_collective=tp_collective)
        for n in graph.nodes
    }


def sequential_makespan(hw: HardwareModel, graph: Graph, team_size: int | None = None) -> float:
    """Makespan of the conventional one-executor interpreter (paper §2)."""
    k = team_size if team_size is not None else hw.n_workers
    return sum(op_time(hw, n, k) for n in graph.nodes)


def interference_multiplier(
    hw: HardwareModel,
    *,
    software_threads: int,
    pinned: bool,
) -> float:
    """Oversubscription / migration penalty for the TF-like baseline (Fig 3).

    The paper measures up to ~45% throughput loss with OS-managed threads and
    severe loss when #software threads > #cores (Eigen + OpenMP double pools).
    Modelled as a multiplicative slowdown on every op duration.
    """
    over = max(1.0, software_threads / hw.n_workers)
    migration = 1.0 if pinned else 1.45
    return over * migration
