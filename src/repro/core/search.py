"""Offline simulator-guided schedule search (ROADMAP: "beyond CPF").

The stack already owns everything a schedule *search* needs: a noise-free
discrete-event simulator (:mod:`repro.core.simulate`), measured per-op
costs (``Executable.calibrate`` / the runtime's ``CalibrationStore``), and
static host plans that replay one frozen schedule per decode token.  So
instead of settling for critical-path-first, :func:`search_schedule` scores
**every registered policy** (:mod:`repro.core.policies`) — randomized
policies over ``n_restarts`` seeds — in the simulator with the caller's
cost table (calibrated when available, analytic otherwise) and returns the
min-makespan winner.

The winner is verified against the ``repro.checks`` schedule rules
(S-COVER/S-DEP/S-EXEC/S-OVERLAP) before it is returned: the static verifier
is the safety net that makes aggressive search cheap to trust — a policy
bug surfaces here as a typed error, never as a wedged host plan.

Candidate order is deterministic (CPF first, then registration order, then
seed), and the simulator breaks priority ties in stable node-id order, so
a (policy, seed) pair *names* a schedule: the persisted winner record
``{policy, seed, makespan_sim, runner_up_gap}`` replays bit-identically in
any later process (the format-2 ``CalibrationStore`` schedule sections).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

from .cost_model import HardwareModel
from .graph import Graph
from .policies import SchedulePolicy, get_policy, list_policies
from .scheduler import Schedule, make_schedule

__all__ = ["CandidateScore", "SearchResult", "search_schedule", "DEFAULT_RESTARTS"]

# seeded restarts per randomized policy: enough draws to escape CPF's
# tie-break plateaus on small graphs while the whole search stays a few
# dozen noise-free simulations
DEFAULT_RESTARTS = 8


@dataclass(frozen=True)
class CandidateScore:
    policy: str
    seed: int
    makespan: float


@dataclass
class SearchResult:
    """The searched winner plus the full scoreboard."""

    policy: str                       # winning policy name
    seed: int                         # winning seed (0 for deterministic)
    makespan_sim: float               # winner's simulated makespan
    runner_up_gap: float              # (2nd best - best) / best, >= 0
    cpf_makespan: float               # the reference heuristic's score
    candidates: list[CandidateScore]  # every scored (policy, seed)
    schedule: Schedule                # the winning schedule itself

    @property
    def gain_over_cpf(self) -> float:
        """Fractional makespan reduction vs plain CPF (>= 0 by
        construction — CPF is always a candidate)."""
        if self.cpf_makespan <= 0.0:
            return 0.0
        return 1.0 - self.makespan_sim / self.cpf_makespan

    def record(self) -> dict:
        """The JSON-able winner record persisted per graph signature."""
        return {
            "policy": self.policy,
            "seed": self.seed,
            "makespan_sim": self.makespan_sim,
            "runner_up_gap": self.runner_up_gap,
        }

    def by_policy(self) -> dict[str, float]:
        """Best makespan per policy (benchmark reporting)."""
        out: dict[str, float] = {}
        for c in self.candidates:
            if c.policy not in out or c.makespan < out[c.policy]:
                out[c.policy] = c.makespan
        return out


def search_schedule(
    graph: Graph,
    hw: HardwareModel,
    *,
    n_executors: int,
    team_size: int,
    costs: Mapping[str, float] | None = None,
    policies: "Sequence[str | SchedulePolicy] | None" = None,
    n_restarts: int = DEFAULT_RESTARTS,
    base_seed: int = 0,
    verify: bool = True,
) -> SearchResult:
    """Score every candidate policy in the simulator; return the winner.

    ``costs`` is the per-op cost table the candidates are scored under —
    pass the calibrated (measured) table when one exists; ``None`` falls
    back to the analytic cost model at ``team_size``.  ``policies``
    restricts the candidate set (default: every registered policy, CPF
    first).  Randomized policies score ``n_restarts`` seeds starting at
    ``base_seed``.  Ties keep the earliest candidate, so CPF wins exact
    ties — search never trades the known-good heuristic for noise.

    ``verify=True`` runs the ``repro.checks`` schedule invariants over the
    winner and raises on any error finding before the result escapes.
    """
    if n_restarts < 1:
        raise ValueError(f"need n_restarts >= 1, got {n_restarts}")
    pols = [get_policy(p) for p in (policies if policies is not None
                                    else list_policies())]
    if not pols:
        raise ValueError("search_schedule needs at least one policy")

    candidates: list[CandidateScore] = []
    best: Schedule | None = None
    cpf_makespan: float | None = None
    for pol in pols:
        seeds = (range(base_seed, base_seed + n_restarts)
                 if pol.randomized else (base_seed,))
        for seed in seeds:
            sched = make_schedule(
                graph, hw, n_executors=n_executors, team_size=team_size,
                policy=pol, costs=dict(costs) if costs is not None else None,
                seed=seed,
            )
            candidates.append(CandidateScore(pol.name, seed, sched.makespan))
            if pol.name == "cpf" and cpf_makespan is None:
                cpf_makespan = sched.makespan
            if best is None or sched.makespan < best.makespan:
                best = sched
    if best is None:  # unreachable: pols non-empty, n_restarts >= 1
        raise RuntimeError("schedule search scored no candidates")

    others = sorted(c.makespan for c in candidates)
    runner_up = others[1] if len(others) > 1 else best.makespan
    gap = ((runner_up - best.makespan) / best.makespan
           if best.makespan > 0 else 0.0)

    if verify:
        # the PR 7 verifier: a winner that violates coverage/dependency/
        # exclusivity invariants must never be persisted or frozen
        from repro.checks import check_schedule

        check_schedule(best, graph).raise_if_errors()

    return SearchResult(
        policy=best.policy,
        seed=best.seed,
        makespan_sim=best.makespan,
        runner_up_gap=gap,
        cpf_makespan=(cpf_makespan if cpf_makespan is not None
                      else best.makespan),
        candidates=candidates,
        schedule=best,
    )
