"""Pluggable scheduling policies: the priority layer of the Graphi engine.

The paper fixes one heuristic — critical-path-first (§4.3) — but no single
list-scheduling priority dominates across graph shapes (Mayer et al., "It's
the Critical Path!", PAPERS.md).  This module makes the policy a first-class
registry entry so the simulator, the scheduler, and the offline schedule
search (:mod:`repro.core.search`) all resolve policies by *name* through one
table, and adding a policy is a one-file change.

A policy is two things:

* a **priority function** — a static per-node score; among *ready* ops the
  highest-priority one is dispatched first (ties break in stable node-id
  order, i.e. graph insertion index, so every policy's schedule is
  bit-reproducible run to run);
* an optional **executor-assignment hook** — given the executors that are
  free earliest, steer the op onto a specific one (Opara-style stream
  packing: align an op with its wave position so producer→consumer chains
  stay on one executor).  Returning ``None`` keeps the engine's default
  earliest-free placement.

Registered policies (all run on the CPF dispatch path — centralized
scheduler, per-executor buffers; the *naive shared-queue* baselines
``"fifo"``/``"random"`` model a different scheduler architecture and live in
:mod:`repro.core.simulate`):

* ``cpf``          — the paper's critical-path-first: priority = *level*
  (longest accumulated cost from the op to the sink, §4.3).
* ``level-pack``   — pack ASAP waves in order (earlier wavefront first),
  with the stream-packing assignment hook.
* ``lpt``          — longest-processing-time: biggest ready op first (the
  classic makespan bound for independent tasks; wins when the DAG is wide
  and costs are skewed).
* ``cpf-perturb``  — CPF with seeded multiplicative priority noise; the
  search runs N restarts and keeps the best draw (randomized restarts
  escape CPF's tie-breaking plateaus).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Mapping, Protocol, runtime_checkable

from .graph import Graph

__all__ = [
    "PolicyContext",
    "SchedulePolicy",
    "CriticalPathFirst",
    "LevelPack",
    "LongestProcessingTime",
    "PerturbedCPF",
    "register_policy",
    "unregister_policy",
    "get_policy",
    "list_policies",
    "NAIVE_POLICIES",
]

# shared-queue baseline schedulers handled natively by the simulator — kept
# out of the registry because they are not priority policies (dispatch
# architecture differs, not the op order heuristic)
NAIVE_POLICIES = ("fifo", "random")


@dataclass(frozen=True)
class PolicyContext:
    """Everything a policy may consult, computed once per simulation.

    ``scratch`` is per-simulation policy scratch space (policies are
    stateless singletons shared across concurrent simulations; anything
    derived from the context is memoized here, not on the policy).
    """

    graph: Graph
    costs: Mapping[str, float]         # per-op seconds (measured or analytic)
    levels: Mapping[str, float]        # §4.3 level: cost-to-sink incl. self
    depths: Mapping[str, int]          # ASAP wave index (unit-cost from sources)
    n_executors: int
    seed: int = 0
    scratch: dict = field(default_factory=dict)


@runtime_checkable
class SchedulePolicy(Protocol):
    """The policy protocol: a name, a priority function, and an optional
    executor-assignment hook.  Duck-typed — any object with these members
    registers; ``randomized`` tells the search to try several seeds."""

    name: str
    randomized: bool

    def priorities(self, ctx: PolicyContext) -> Mapping[str, float]:
        """Static per-node priority (higher pops first among ready ops)."""
        ...  # pragma: no cover - protocol

    def assign_executor(
        self, ctx: PolicyContext, op: str, free: tuple[int, ...]
    ) -> int | None:
        """Pick an executor among ``free`` (the ids free earliest, sorted)
        or ``None`` for the engine's default placement."""
        ...  # pragma: no cover - protocol


class CriticalPathFirst:
    """The paper's CPF: schedule the op with the longest remaining
    critical path first."""

    name = "cpf"
    randomized = False

    def priorities(self, ctx: PolicyContext) -> Mapping[str, float]:
        return ctx.levels

    def assign_executor(self, ctx, op, free):
        return None


class LevelPack:
    """Pack ASAP waves in order; steer each op to the executor matching its
    position within the wave, so consecutive waves keep producer→consumer
    chains executor-aligned (Opara-style op-stream packing)."""

    name = "level-pack"
    randomized = False

    def priorities(self, ctx: PolicyContext) -> Mapping[str, float]:
        return {n: -float(d) for n, d in ctx.depths.items()}

    def assign_executor(self, ctx, op, free):
        pos = ctx.scratch.get("level-pack.wavepos")
        if pos is None:
            pos = {}
            counts: dict[int, int] = {}
            for n in ctx.graph.names:          # stable node-id order
                d = ctx.depths[n]
                pos[n] = counts.get(d, 0)
                counts[d] = pos[n] + 1
            ctx.scratch["level-pack.wavepos"] = pos
        want = pos[op] % ctx.n_executors
        return want if want in free else None


class LongestProcessingTime:
    """Biggest ready op first (LPT list scheduling)."""

    name = "lpt"
    randomized = False

    def priorities(self, ctx: PolicyContext) -> Mapping[str, float]:
        return ctx.costs

    def assign_executor(self, ctx, op, free):
        return None


class PerturbedCPF:
    """CPF levels scaled by seeded uniform noise in ``1 ± epsilon``.

    One instance is one *distribution*; a concrete draw is fixed by the
    simulation seed, so a (policy, seed) pair names a schedule exactly —
    the searched winner record replays bit-identically.
    """

    name = "cpf-perturb"
    randomized = True

    def __init__(self, epsilon: float = 0.25):
        if not 0.0 < epsilon < 1.0:
            raise ValueError(f"epsilon must be in (0, 1), got {epsilon}")
        self.epsilon = epsilon

    def priorities(self, ctx: PolicyContext) -> Mapping[str, float]:
        rng = random.Random(ctx.seed)
        eps = self.epsilon
        # iterate in node-id order so a seed draws the same noise sequence
        # regardless of dict history
        return {
            n: ctx.levels[n] * (1.0 + eps * (2.0 * rng.random() - 1.0))
            for n in ctx.graph.names
        }

    def assign_executor(self, ctx, op, free):
        return None


# -- the registry ------------------------------------------------------------
_REGISTRY: dict[str, SchedulePolicy] = {}


def register_policy(policy: SchedulePolicy, *, replace: bool = False) -> SchedulePolicy:
    """Add ``policy`` to the registry under ``policy.name``; returns it so
    the call composes as a decorator-ish one-liner.  Registering an existing
    name raises unless ``replace=True`` (silent shadowing would make
    schedule provenance — the persisted winner records — ambiguous)."""
    if not isinstance(policy, SchedulePolicy):
        raise TypeError(
            f"{policy!r} does not implement SchedulePolicy "
            "(name/randomized/priorities/assign_executor)"
        )
    if policy.name in NAIVE_POLICIES:
        raise ValueError(
            f"{policy.name!r} is reserved for the naive shared-queue "
            "simulator baselines"
        )
    if policy.name in _REGISTRY and not replace:
        raise ValueError(
            f"policy {policy.name!r} is already registered "
            "(pass replace=True to shadow it)"
        )
    _REGISTRY[policy.name] = policy
    return policy


def unregister_policy(name: str) -> None:
    """Remove a registered policy (tests; undoing an experiment)."""
    _REGISTRY.pop(name, None)


def get_policy(policy: "str | SchedulePolicy") -> SchedulePolicy:
    """Resolve a policy name through the registry; instances pass through
    (an unregistered ad-hoc policy is usable without registering)."""
    if isinstance(policy, str):
        try:
            return _REGISTRY[policy]
        except KeyError:
            raise ValueError(
                f"unknown scheduling policy {policy!r}; registered: "
                f"{sorted(_REGISTRY)} (repro.core.policies.register_policy "
                "adds one; 'fifo'/'random' are simulator baselines, not "
                "registry policies)"
            ) from None
    if not isinstance(policy, SchedulePolicy):
        raise TypeError(f"{policy!r} does not implement SchedulePolicy")
    return policy


def list_policies() -> list[str]:
    """Registered policy names, CPF first (the reference heuristic), then
    the competitors in registration order — the search's candidate order,
    so ties resolve toward CPF."""
    names = list(_REGISTRY)
    if "cpf" in names:
        names.remove("cpf")
        names.insert(0, "cpf")
    return names


register_policy(CriticalPathFirst())
register_policy(LevelPack())
register_policy(LongestProcessingTime())
register_policy(PerturbedCPF())
