"""Compiled static host plans: the scheduler off the per-op hot path.

The dynamic :class:`~repro.core.engine.HostScheduler` is paper-faithful
(§5.2): a centralized scheduler thread makes a placement decision per op and
pays a triggered-queue round-trip per completion.  For a graph executed once
that overhead is noise; for a serving decode loop that replays the *same*
small graph once per token it **is** the latency floor — exactly the
contention the paper says kills small-op parallelism.

A :class:`StaticHostPlan` freezes the CPF schedule we already computed
(Mayer et al.: the critical path decided the placement; nothing about it
changes between runs) into per-executor **op programs over integer node
ids**:

* flat result buffers (``results[id]``) instead of name-keyed dicts,
* precomputed argument-index tuples (``arg_ids[id]``),
* precomputed successor id lists (``succ_ids[id]``),
* lock-free dependency counters — one :class:`itertools.count` per fan-in
  node (``count.__next__`` is a single C call, atomic under the GIL): every
  producer bumps its consumers' counters, and exactly one producer observes
  the final value and *directly runs* the op it unblocked (same executor)
  or enqueues it on the owning executor's per-run ready queue.

There is **no central dispatch loop** at run time: no triggered-queue drain,
no ``heapq``, no least-loaded-executor scan.  The client thread resolves
input passthroughs inline, seeds the zero-dependency ops, submits one
*segment* per executor to an :class:`~repro.core.engine.ExecutorPool` (so
static runs interleave with dynamic runs on the same persistent executors),
and waits for the segments to finish — one reply-queue hop per executor per
*run* instead of two hops per *op*.

Failure protocol: the first op exception is recorded on the run state and a
poison id is pushed to every ready queue; segments exit on poison, and the
client raises the same ``RuntimeError("op ... failed on executor ...")`` the
dynamic runtime raises.
"""
from __future__ import annotations

import itertools
import queue
import sys
import time
from dataclasses import dataclass
from functools import partial
from threading import Lock
from typing import Any, Callable, Mapping

from .engine import _ERR, DeadlineExceeded, ExecutorPool, HostRunResult
from .graph import Graph, GraphValidationError
from .scheduler import Schedule
from .simulate import TraceEvent

__all__ = ["StaticHostPlan", "compile_host_plan", "layered_graph"]

_POISON = -1


def layered_graph(L: int = 6, W: int = 3, *, flops: float = 10.0) -> Graph:
    """Decode-shaped reference DAG: ``W`` parallel ~free ops per layer
    feeding a join, ``L`` layers deep, one inline-resolved input.

    The shape the static-plan machinery exists for — a small graph replayed
    many times where scheduling overhead dominates.  Shared by the
    scheduler-overhead bench (`scripts/bench_sched_overhead.py`) and the
    static-plan tests so they exercise the identical structure.
    """
    g = Graph("layered")
    g.add_op("x", kind="input")
    prev = "x"
    for layer in range(L):
        for w in range(W):
            g.add_op(f"l{layer}w{w}", deps=(prev,), flops=flops,
                     fn=lambda v, w=w: v + w)
        g.add_op(f"j{layer}", deps=tuple(f"l{layer}w{w}" for w in range(W)),
                 flops=flops, fn=lambda *xs: sum(xs))
        prev = f"j{layer}"
    g.add_op("out", deps=(prev,), flops=1.0, fn=lambda v: v * 2)
    return g


def compile_host_plan(
    graph: Graph, schedule: Schedule, n_executors: int | None = None
) -> StaticHostPlan:
    """Freeze ``schedule``'s placements into a :class:`StaticHostPlan`.

    ``n_executors`` defaults to the schedule's executor count; a smaller
    count folds placements onto the available executors (``e % n``) — the
    pool a plan runs on may be narrower than the profiled config.  Input
    passthroughs (``fn is None``) are compiled *out* of the programs: the
    client thread resolves them inline at run start.
    """
    n_exec = schedule.n_executors if n_executors is None else n_executors
    if n_exec < 1:
        raise ValueError(f"need >= 1 executor, got {n_exec}")
    names = tuple(graph.names)
    ids = {n: i for i, n in enumerate(names)}
    nodes = [graph[n] for n in names]
    is_input = [nd.fn is None for nd in nodes]
    for nd, inp in zip(nodes, is_input):
        if inp and nd.deps:
            raise GraphValidationError(
                f"node {nd.name!r} has deps but no fn — static plans resolve "
                "fn-less nodes inline from inputs, which requires them to be "
                "sources"
            )
    input_ids = tuple(i for i in range(len(names)) if is_input[i])
    arg_ids = tuple(tuple(ids[d] for d in nd.deps) for nd in nodes)
    # consumers to notify on completion; input nodes notify nobody (their
    # consumers never wait on them — see n_wait) and are never notified
    succ_ids = tuple(
        () if is_input[i] else tuple(ids[s] for s in graph.successors(n))
        for i, n in enumerate(names)
    )
    # counter target: deps that are *executed* (inputs are pre-resolved)
    n_wait = tuple(
        sum(1 for d in nd.deps if not is_input[ids[d]]) for nd in nodes
    )

    owner = [-1] * len(names)
    programs: list[list[int]] = [[] for _ in range(n_exec)]
    for e, ops in enumerate(schedule.by_executor(n_exec)):
        for nm in ops:
            i = ids.get(nm)
            if i is None:
                raise GraphValidationError(
                    f"schedule places unknown op {nm!r} (graph {graph.name!r})"
                )
            if is_input[i]:
                continue
            owner[i] = e
            programs[e].append(i)
    missing = [names[i] for i in range(len(names))
               if not is_input[i] and owner[i] < 0]
    if missing:
        raise GraphValidationError(
            f"schedule does not place ops {missing[:4]!r} of graph {graph.name!r}"
        )
    seeds = tuple(
        tuple(i for i in prog if n_wait[i] == 0) for prog in programs
    )
    return StaticHostPlan(
        graph=graph,
        graph_version=graph.version,
        n_executors=n_exec,
        names=names,
        ids=ids,
        fns=tuple(nd.fn for nd in nodes),
        arg_ids=arg_ids,
        succ_ids=succ_ids,
        n_wait=n_wait,
        owner=tuple(owner),
        programs=tuple(tuple(p) for p in programs),
        input_ids=input_ids,
        seeds=seeds,
        policy=schedule.policy,
        seed=schedule.seed,
    )


@dataclass(frozen=True)
class StaticHostPlan:
    """A graph + frozen CPF placements compiled to integer-id executor
    programs.  Immutable; per-run state lives in :class:`_PlanRun`."""

    graph: Graph
    graph_version: int                        # Graph.version at compile time
    n_executors: int
    names: tuple[str, ...]                    # id -> name (insertion order)
    ids: Mapping[str, int]                    # name -> id
    fns: tuple[Callable[..., Any] | None, ...]
    arg_ids: tuple[tuple[int, ...], ...]      # id -> dep ids (arg order)
    succ_ids: tuple[tuple[int, ...], ...]     # id -> consumer ids
    n_wait: tuple[int, ...]                   # id -> executed-dep count
    owner: tuple[int, ...]                    # id -> executor (-1: input)
    programs: tuple[tuple[int, ...], ...]     # executor -> owned ids
    input_ids: tuple[int, ...]                # resolved inline from inputs
    seeds: tuple[tuple[int, ...], ...]        # executor -> ready-at-start ids
    # provenance: the scheduling policy (+ its seed) whose placements this
    # plan froze — "cpf", or a searched winner such as "cpf-perturb"
    policy: str = "cpf"
    seed: int = 0

    @property
    def n_ops(self) -> int:
        """Executed ops per run (inputs excluded)."""
        return sum(len(p) for p in self.programs)

    def describe(self) -> str:
        widths = ",".join(str(len(p)) for p in self.programs)
        pol = self.policy if self.seed == 0 else f"{self.policy}@{self.seed}"
        return (
            f"StaticHostPlan({self.graph.name!r}, {self.n_executors} executors, "
            f"{self.n_ops} ops [{widths}], {len(self.input_ids)} inputs, "
            f"policy={pol})"
        )

    # -- execution ----------------------------------------------------------
    def run(
        self,
        inputs: Mapping[str, Any] | None = None,
        pool: ExecutorPool | None = None,
        *,
        collect_trace: bool = False,
        deadline: float | None = None,
    ) -> HostRunResult:
        """Execute the plan; returns the same :class:`HostRunResult` shape as
        the dynamic runtime (``trace`` is empty unless ``collect_trace`` —
        per-op timestamps are exactly the overhead this path removes).

        Without a ``pool`` an ephemeral one is spun up for the run; with one,
        segments are queued atomically behind whatever the pool is already
        running (dynamic ops or another plan's segments).

        ``deadline`` (absolute, ``time.monotonic``) bounds the wait for
        segment completion: on expiry every ready queue is poisoned — idle
        segments exit — and :class:`~repro.core.engine.DeadlineExceeded`
        is raised naming whatever ops are still on executor threads, so a
        hung op frees this run's lease instead of wedging it forever.
        """
        inputs = inputs or {}
        if self.graph.version != self.graph_version:
            # same staleness guard as HostScheduler.run: the frozen integer
            # programs would silently skip any node added since compile
            raise GraphValidationError(
                f"graph {self.graph.name!r} mutated (version "
                f"{self.graph_version} -> {self.graph.version}) after this "
                "plan was compiled — recompile the plan"
            )
        if pool is not None and pool.n_executors < self.n_executors:
            raise ValueError(
                f"plan needs {self.n_executors} executors but pool has "
                f"{pool.n_executors} — recompile the plan for the pool size"
            )
        ephemeral = pool is None
        if ephemeral:
            pool = ExecutorPool(self.n_executors)
        state = _PlanRun(self)
        results = state.results
        names = self.names
        for i in self.input_ids:
            nm = names[i]
            if nm not in inputs:
                raise GraphValidationError(f"node {nm!r} has no fn and no input")
            results[i] = inputs[nm]
        for e, seed in enumerate(self.seeds):
            q = state.ready[e]
            for i in seed:
                q.put(i)
        reply: queue.SimpleQueue = queue.SimpleQueue()
        t_origin = time.perf_counter()
        active = [e for e in range(self.n_executors) if self.programs[e]]
        try:
            pool.submit_segments(
                [
                    (
                        e,
                        f"{self.graph.name}#seg{e}",
                        partial(_run_segment, self, state, e, t_origin,
                                collect_trace),
                    )
                    for e in active
                ],
                reply,
                t_origin,
            )
            seg_err: tuple[Any, int] | None = None
            for _ in active:
                if deadline is None:
                    msg = reply.get()
                else:
                    try:
                        msg = reply.get(
                            timeout=max(0.0, deadline - time.monotonic()))
                    except queue.Empty:
                        # poison first: segments blocked on their ready
                        # queue exit immediately and give their executor
                        # back; only the executor actually inside the hung
                        # op stays busy (the caller quarantines it)
                        for q in state.ready:
                            q.put(_POISON)
                        busy = ""
                        if hasattr(pool, "current_tasks"):
                            cur = [c[0] for c in pool.current_tasks() if c]
                            busy = f"; executors busy in {cur!r}" if cur else ""
                        raise DeadlineExceeded(
                            f"plan {self.graph.name!r}: deadline exceeded "
                            f"with segments unfinished{busy}") from None
                if msg[0] is _ERR and seg_err is None:  # pragma: no cover
                    # segment infrastructure died outside the per-op try:
                    # poison the siblings (they may be blocked waiting for
                    # ops the dead segment never ran) and keep draining, so
                    # a shared pool's executors are not wedged forever
                    seg_err = (msg[1], msg[2])
                    for q in state.ready:
                        q.put(_POISON)
        finally:
            if ephemeral:
                pool.close(raise_on_stuck=sys.exc_info()[0] is None)
        if seg_err is not None:  # pragma: no cover — segment infra only
            raise RuntimeError(
                f"plan segment died on executor {seg_err[1]}") from seg_err[0]
        if state.error is not None:
            nm, e = state.error_at
            raise RuntimeError(f"op {nm!r} failed on executor {e}") from state.error
        wall = time.perf_counter() - t_origin
        trace = sorted(state.trace, key=lambda ev: ev.start)
        # untraced runs fall back to per-segment end stamps: last op end,
        # like the dynamic runtime's makespan, not client-observed wall
        makespan = max((ev.end for ev in trace), default=0.0) or \
            max((t for t in state.seg_end if t > 0.0), default=wall)
        return HostRunResult(
            outputs=dict(zip(names, results)),
            trace=trace,
            makespan=makespan,
            peak_inflight=1,
        )


class _PlanRun:
    """Mutable per-run state: flat result buffer, dependency counters, and
    per-executor ready queues.  One instance per ``StaticHostPlan.run``."""

    __slots__ = ("results", "pending", "ready", "trace", "seg_end", "error",
                 "error_at", "_lock")

    def __init__(self, plan: StaticHostPlan):
        self.results: list[Any] = [None] * len(plan.names)
        # a counter only where there is a race to lose: fan-in >= 2
        self.pending = [
            itertools.count() if w >= 2 else None for w in plan.n_wait
        ]
        self.ready = [queue.SimpleQueue() for _ in range(plan.n_executors)]
        self.trace: list[TraceEvent] = []
        self.seg_end: list[float] = [0.0] * plan.n_executors
        self.error: BaseException | None = None
        self.error_at: tuple[str, int] = ("", -1)
        self._lock = Lock()

    def fail(self, exc: BaseException, name: str, executor: int) -> None:
        with self._lock:
            if self.error is None:
                self.error = exc
                self.error_at = (name, executor)
        for q in self.ready:
            q.put(_POISON)


def _run_segment(
    plan: StaticHostPlan,
    state: _PlanRun,
    e: int,
    t_origin: float,
    collect_trace: bool,
) -> int:
    """Executor ``e``'s share of one plan run.

    Runs as a single pool work item: drains a local stack first (ops this
    executor just unblocked for itself — zero queue hops), then blocks on
    its per-run ready queue.  Exits after completing exactly its program
    length, or on a poison id after another segment failed.
    """
    fns = plan.fns
    arg_ids = plan.arg_ids
    succ_ids = plan.succ_ids
    owner = plan.owner
    need = plan.n_wait
    results = state.results
    pending = state.pending
    ready = state.ready
    get = ready[e].get
    local: list[int] = []
    pop = local.pop
    push = local.append
    remaining = len(plan.programs[e])
    t0 = 0.0
    while remaining:
        if local:
            i = pop()
        else:
            i = get()
            if i < 0:
                return remaining
        try:
            if collect_trace:
                t0 = time.perf_counter() - t_origin
            results[i] = fns[i](*[results[d] for d in arg_ids[i]])
        except BaseException as exc:  # noqa: BLE001 — relayed to the client
            state.fail(exc, plan.names[i], e)
            return remaining
        if collect_trace:
            state.trace.append(
                TraceEvent(plan.names[i], e, t0, time.perf_counter() - t_origin)
            )
        remaining -= 1
        for s in succ_ids[i]:
            w = need[s]
            if w == 1 or next(pending[s]) == w - 1:
                o = owner[s]
                if o == e:
                    push(s)
                else:
                    ready[o].put(s)
    state.seg_end[e] = time.perf_counter() - t_origin
    return 0
