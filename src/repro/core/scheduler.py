"""Schedulers for computation graphs (paper §4.3).

``make_schedule`` runs the online engine (noise-free) under a policy —
resolved by name through the :mod:`repro.core.policies` registry, so CPF,
level-packing, LPT, perturbed CPF, and anything user-registered all flow
through the same entry point — and returns a :class:`Schedule`: per-op
(executor, start, end) plus the derived *slot* structure used by the static
plan compiler (slots = barrier-separated groups of mutually independent
ops, at most ``n_executors`` wide — the spatial-multiplexing unit on an
SPMD mesh, see DESIGN.md §2.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .cost_model import HardwareModel
from .graph import Graph, GraphValidationError
from .policies import NAIVE_POLICIES, SchedulePolicy, get_policy
from .simulate import SimConfig, SimResult, simulate

__all__ = ["Schedule", "make_schedule", "slot_assignment"]


@dataclass
class Schedule:
    graph_name: str
    policy: str
    n_executors: int
    team_size: int
    makespan: float
    # name -> (executor, start, end)
    placements: dict[str, tuple[int, float, float]]
    op_costs: dict[str, float] = field(repr=False, default_factory=dict)
    # the simulation seed the schedule was produced under: with the policy
    # name, enough to replay a randomized policy's exact schedule (the
    # searched-winner records in the format-2 calibration store)
    seed: int = 0

    def start_order(self) -> list[str]:
        return sorted(self.placements, key=lambda n: (self.placements[n][1], n))

    def by_executor(self, n_executors: int | None = None) -> list[list[str]]:
        """Per-executor op names in start order — the frozen placement view
        the static host plan compiler consumes.  ``n_executors`` folds the
        schedule onto fewer executors (``e % n``) when the pool a plan will
        run on is narrower than the scheduled config."""
        n = self.n_executors if n_executors is None else n_executors
        if n < 1:
            raise ValueError(f"need >= 1 executor, got {n}")
        out: list[list[str]] = [[] for _ in range(n)]
        for nm in self.start_order():
            out[self.placements[nm][0] % n].append(nm)
        return out

    def validate(self, graph: Graph) -> None:
        """Every dep finishes before its consumer starts; executors never
        overlap. Raises :class:`GraphValidationError` otherwise (a typed
        exception, not ``assert`` — validation must survive ``python -O``).
        ``repro.checks.check_schedule`` is the finding-reporting superset."""
        eps = 1e-12
        for n, (_, start, _) in self.placements.items():
            for d in graph.predecessors(n):
                _, _, dend = self.placements[d]
                if dend > start + eps:
                    raise GraphValidationError(
                        f"{n} starts before dep {d} ends")
        per_exec: dict[int, list[tuple[float, float, str]]] = {}
        for n, (e, s, t) in self.placements.items():
            per_exec.setdefault(e, []).append((s, t, n))
        for e, iv in per_exec.items():
            iv.sort()
            for (_s0, t0, a), (s1, _t1, b) in zip(iv, iv[1:]):
                if t0 > s1 + eps:
                    raise GraphValidationError(
                        f"executor {e}: {a} and {b} overlap")


def make_schedule(
    graph: Graph,
    hw: HardwareModel,
    *,
    n_executors: int,
    team_size: int,
    policy: "str | SchedulePolicy" = "cpf",
    costs: dict[str, float] | None = None,
    seed: int = 0,
) -> Schedule:
    """Schedule ``graph`` under ``policy`` (a registry name or a
    :class:`~repro.core.policies.SchedulePolicy` instance; the naive
    shared-queue baselines ``"fifo"``/``"random"`` pass through for
    comparison runs).  ``seed`` feeds randomized policies — (policy, seed)
    replays the identical schedule."""
    if not (isinstance(policy, str) and policy in NAIVE_POLICIES):
        policy = get_policy(policy)   # fail fast on unknown names
    cfg = SimConfig(
        n_executors=n_executors,
        team_size=team_size,
        policy=policy,
        # noise-free, zero dispatch cost: the pure scheduling decision
        cpf_push_cost=0.0,
        queue_base_cost=0.0,
        queue_contention_cost=0.0,
    )
    res: SimResult = simulate(graph, hw, cfg, costs=costs, seed=seed)
    placements = {e.op: (e.executor, e.start, e.end) for e in res.trace}
    return Schedule(
        graph_name=graph.name,
        policy=policy if isinstance(policy, str) else policy.name,
        n_executors=n_executors,
        team_size=team_size,
        makespan=res.makespan,
        placements=placements,
        op_costs=res.op_costs,
        seed=seed,
    )


def slot_assignment(graph: Graph, schedule: Schedule) -> list[list[str]]:
    """Barrier-slot structure for static (SPMD) execution.

    Ops are taken in schedule start order; each op lands in the earliest slot
    after all its deps' slots that still has a free executor lane. The result
    is a list of slots, each a list of <= n_executors mutually-independent op
    names — directly stackable along an 'executor' mesh axis.
    """
    slot_of: dict[str, int] = {}
    occupancy: list[int] = []
    slots: list[list[str]] = []
    for n in schedule.start_order():
        lo = 0
        for d in graph.predecessors(n):
            lo = max(lo, slot_of[d] + 1)
        s = lo
        while s < len(slots) and occupancy[s] >= schedule.n_executors:
            s += 1
        while s >= len(slots):
            slots.append([])
            occupancy.append(0)
        slots[s].append(n)
        occupancy[s] += 1
        slot_of[n] = s
    return slots
