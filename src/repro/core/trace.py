"""Execution-trace utilities (paper §5.2: "we use the profiling results to
visualize the execution process ... immensely helpful in analysis")."""
from __future__ import annotations

from typing import Sequence

from .simulate import TraceEvent

__all__ = ["ascii_timeline", "trace_csv"]


def ascii_timeline(
    trace: Sequence[TraceEvent], n_executors: int, width: int = 100
) -> str:
    """Render per-executor timelines as ASCII (one row per executor)."""
    if not trace:
        return "(empty trace)"
    t_end = max(e.end for e in trace)
    t_end = t_end or 1.0
    rows = []
    for ex in range(n_executors):
        line = [" "] * width
        for ev in trace:
            if ev.executor != ex:
                continue
            a = int(ev.start / t_end * (width - 1))
            b = max(a + 1, int(ev.end / t_end * (width - 1)))
            ch = ev.op[-1] if ev.op else "#"
            for i in range(a, min(b, width)):
                line[i] = "#" if line[i] != " " else ch
        rows.append(f"E{ex:02d} |" + "".join(line) + "|")
    rows.append(f"     0{' ' * (width - 12)}{t_end * 1e6:9.1f}us")
    return "\n".join(rows)


def trace_csv(trace: Sequence[TraceEvent]) -> str:
    lines = ["op,executor,start_us,end_us,duration_us"]
    for e in sorted(trace, key=lambda e: e.start):
        lines.append(
            f"{e.op},{e.executor},{e.start*1e6:.3f},{e.end*1e6:.3f},{(e.end-e.start)*1e6:.3f}"
        )
    return "\n".join(lines)
