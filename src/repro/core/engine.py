"""GraphiEngine — the paper's execution engine, end to end.

Two runtimes sit behind one facade:

* :class:`HostScheduler` — the **paper-faithful dynamic runtime**: a
  centralized scheduler (runs on the client thread, §5.2) with critical-path-
  first priority, per-executor operation buffers (depth 1), executor worker
  threads, and a triggered-operation return queue. On a multi-device system
  each executor owns a device group; on this box it demonstrates exact
  scheduling semantics and is validated against the sequential interpreter.

* **Static plan** (:func:`Schedule` → :func:`slot_assignment`) — the
  TPU-native path: the CPF schedule is frozen into barrier slots whose ops
  are stacked/sharded over disjoint sub-meshes (see core/wavefront.py and
  DESIGN.md §2.1).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Mapping

from .cost_model import HardwareModel
from .graph import Graph
from .profiler import ProfileResult, profile
from .scheduler import Schedule, make_schedule, slot_assignment
from .simulate import SimConfig, SimResult, TraceEvent, simulate

__all__ = ["GraphiEngine", "HostScheduler", "HostRunResult"]


@dataclass
class HostRunResult:
    outputs: dict[str, Any]
    trace: list[TraceEvent]
    makespan: float


class HostScheduler:
    """Centralized scheduler + N executor threads with per-executor buffers.

    Executors poll *their own* buffer (no shared global queue — the paper's
    contention fix); on completion they push (op, result) onto the triggered
    queue, which the scheduler drains (Algorithm 1/2).
    """

    def __init__(
        self,
        graph: Graph,
        n_executors: int,
        *,
        costs: Mapping[str, float] | None = None,
        buffer_depth: int = 1,
    ):
        self.graph = graph
        self.n_executors = n_executors
        costs = costs or {n: max(g.flops, 1.0) for n, g in zip(graph.names, graph.nodes)}
        self.levels = graph.levels({n: float(costs[n]) for n in graph.names})
        self.buffer_depth = buffer_depth

    def run(self, inputs: Mapping[str, Any] | None = None) -> HostRunResult:
        g = self.graph
        inputs = dict(inputs or {})
        results: dict[str, Any] = {}
        indeg = {n: g.in_degree(n) for n in g.names}
        seq = {n: i for i, n in enumerate(g.names)}

        import heapq

        ready: list[tuple[float, int, str]] = []
        for n in g.names:
            if indeg[n] == 0:
                heapq.heappush(ready, (-self.levels[n], seq[n], n))

        buffers = [queue.Queue(maxsize=self.buffer_depth) for _ in range(self.n_executors)]
        triggered: queue.Queue = queue.Queue()
        idle = [True] * self.n_executors
        trace: list[TraceEvent] = []
        t_origin = time.perf_counter()

        def executor_loop(ex: int) -> None:
            while True:
                item = buffers[ex].get()
                if item is None:
                    return
                name, args = item
                node = g[name]
                t0 = time.perf_counter() - t_origin
                if node.fn is None:
                    out = inputs[name]
                else:
                    out = node.fn(*args)
                t1 = time.perf_counter() - t_origin
                triggered.put((name, out, ex, t0, t1))

        threads = [
            threading.Thread(target=executor_loop, args=(e,), daemon=True)
            for e in range(self.n_executors)
        ]
        for t in threads:
            t.start()

        n_done = 0
        total = len(g)
        try:
            while n_done < total:
                # fire ready ops at idle executors, highest level first (Alg. 1)
                while ready and any(idle):
                    ex = idle.index(True)  # bit-scan analogue
                    _, _, name = heapq.heappop(ready)
                    node = g[name]
                    if not node.deps and name in inputs and node.fn is None:
                        args: tuple = ()
                    else:
                        args = tuple(results[d] for d in node.deps)
                    idle[ex] = False
                    buffers[ex].put((name, args))
                # poll triggered operations (Alg. 1 line 2)
                name, out, ex, t0, t1 = triggered.get()
                results[name] = out
                idle[ex] = True
                trace.append(TraceEvent(name, ex, t0, t1))
                n_done += 1
                for s in g.successors(name):
                    indeg[s] -= 1
                    if indeg[s] == 0:
                        heapq.heappush(ready, (-self.levels[s], seq[s], s))
        finally:
            for b in buffers:
                b.put(None)
            for t in threads:
                t.join(timeout=5)

        makespan = max((e.end for e in trace), default=0.0)
        return HostRunResult(outputs=results, trace=trace, makespan=makespan)


@dataclass
class GraphiEngine:
    """profile -> schedule -> execute (Fig 4)."""

    graph: Graph
    hw: HardwareModel
    n_workers: int | None = None  # defaults to hw.n_workers minus 2 reserved
    reserved_workers: int = 2     # scheduler core + lightweight executor (§5.2)
    _profile: ProfileResult | None = field(default=None, repr=False)

    @property
    def usable_workers(self) -> int:
        n = self.n_workers if self.n_workers is not None else self.hw.n_workers
        return max(1, n - self.reserved_workers)

    def profile(self, **kw: Any) -> ProfileResult:
        self._profile = profile(self.graph, self.hw, n_workers=self.usable_workers, **kw)
        return self._profile

    def schedule(self, policy: str = "cpf") -> Schedule:
        p = self._profile or self.profile()
        return make_schedule(
            self.graph,
            self.hw,
            n_executors=p.best_n_executors,
            team_size=p.best_team_size,
            policy=policy,
        )

    def static_slots(self, policy: str = "cpf") -> list[list[str]]:
        return slot_assignment(self.graph, self.schedule(policy))

    def static_plan(self, mesh: Any, *, policy: str = "cpf", axis: str | None = None):
        """Bind the frozen CPF schedule to device placement: barrier slots
        over disjoint executor sub-meshes (repro.dist.executor_mesh)."""
        from repro.dist.executor_mesh import plan_from_schedule

        return plan_from_schedule(self.graph, self.schedule(policy), mesh, axis=axis)

    def simulate(self, policy: str = "cpf", **kw: Any) -> SimResult:
        p = self._profile or self.profile()
        cfg = SimConfig(
            n_executors=p.best_n_executors, team_size=p.best_team_size, policy=policy, **kw
        )
        return simulate(self.graph, self.hw, cfg, costs=p.op_costs)

    def execute_host(
        self, inputs: Mapping[str, Any] | None = None, n_executors: int | None = None
    ) -> HostRunResult:
        p = self._profile or self.profile()
        n = n_executors or p.best_n_executors
        host = HostScheduler(self.graph, n, costs=p.op_costs)
        return host.run(inputs)
