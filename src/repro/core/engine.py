"""Host runtime + the deprecated ``GraphiEngine`` facade.

* :class:`HostScheduler` — the **paper-faithful dynamic runtime**: a
  centralized scheduler (runs on the client thread, §5.2) with critical-path-
  first priority, per-executor operation buffers (depth ``buffer_depth``),
  executor worker threads, and a triggered-operation return queue.  On a
  multi-device system each executor owns a device group; on this box it
  demonstrates exact scheduling semantics and is validated against the
  sequential interpreter.

* :class:`GraphiEngine` — **deprecated**: the original five-call stateful
  facade (profile / schedule / static_slots / simulate / execute_host), now
  a thin shim over :class:`repro.api.Executable`.  New code should call
  ``repro.api.compile`` (see DESIGN.md §3).
"""
from __future__ import annotations

import heapq
import queue
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Mapping

from .cost_model import HardwareModel
from .graph import Graph
from .profiler import ProfileResult
from .scheduler import Schedule
from .simulate import SimResult, TraceEvent

__all__ = ["GraphiEngine", "HostScheduler", "HostRunResult"]

_ERR = object()   # triggered-queue sentinel: an executor relayed an exception


@dataclass
class HostRunResult:
    outputs: dict[str, Any]
    trace: list[TraceEvent]
    makespan: float
    peak_inflight: int = 1      # max ops queued on one executor (buffer use)


class HostScheduler:
    """Centralized scheduler + N executor threads with per-executor buffers.

    Executors poll *their own* buffer (no shared global queue — the paper's
    contention fix); on completion they push (op, result) onto the triggered
    queue, which the scheduler drains (Algorithm 1/2).  Each executor buffer
    holds up to ``buffer_depth`` dispatched ops, so an executor finishing one
    op can start the next without a scheduler round-trip.
    """

    def __init__(
        self,
        graph: Graph,
        n_executors: int,
        *,
        costs: Mapping[str, float] | None = None,
        buffer_depth: int = 1,
    ):
        if n_executors < 1:
            raise ValueError(f"need >= 1 executor, got {n_executors}")
        if buffer_depth < 1:
            raise ValueError(f"need buffer_depth >= 1, got {buffer_depth}")
        self.graph = graph
        self.n_executors = n_executors
        costs = costs or {n: max(g.flops, 1.0) for n, g in zip(graph.names, graph.nodes)}
        self.levels = graph.levels({n: float(costs[n]) for n in graph.names})
        self.buffer_depth = buffer_depth

    def run(self, inputs: Mapping[str, Any] | None = None) -> HostRunResult:
        g = self.graph
        inputs = dict(inputs or {})
        results: dict[str, Any] = {}
        indeg = {n: g.in_degree(n) for n in g.names}
        seq = {n: i for i, n in enumerate(g.names)}

        ready: list[tuple[float, int, str]] = []
        for n in g.names:
            if indeg[n] == 0:
                heapq.heappush(ready, (-self.levels[n], seq[n], n))

        n_exec = self.n_executors
        # depth is enforced by the inflight counters, so the queues stay
        # unbounded — shutdown puts never block on a full buffer
        buffers = [queue.Queue() for _ in range(n_exec)]
        triggered: queue.Queue = queue.Queue()
        inflight = [0] * n_exec
        peak_inflight = 0
        trace: list[TraceEvent] = []
        t_origin = time.perf_counter()

        def executor_loop(ex: int) -> None:
            while True:
                item = buffers[ex].get()
                if item is None:
                    return
                name, args = item
                node = g[name]
                t0 = time.perf_counter() - t_origin
                try:
                    if node.fn is None:
                        out = inputs[name]
                    else:
                        out = node.fn(*args)
                except BaseException as e:  # noqa: BLE001 — relayed to scheduler
                    triggered.put((_ERR, e, ex, name, 0.0))
                    return
                t1 = time.perf_counter() - t_origin
                triggered.put((name, out, ex, t0, t1))

        threads = [
            threading.Thread(target=executor_loop, args=(e,), daemon=True)
            for e in range(n_exec)
        ]
        for t in threads:
            t.start()

        def dispatch() -> None:
            """Fire ready ops highest-level-first at the least-loaded
            executors until every buffer is full or nothing is ready."""
            nonlocal peak_inflight
            while ready:
                ex = min(range(n_exec), key=lambda e: (inflight[e], e))
                if inflight[ex] >= self.buffer_depth:
                    return
                _, _, name = heapq.heappop(ready)
                node = g[name]
                if not node.deps and name in inputs and node.fn is None:
                    args: tuple = ()
                else:
                    args = tuple(results[d] for d in node.deps)
                inflight[ex] += 1
                peak_inflight = max(peak_inflight, inflight[ex])
                buffers[ex].put((name, args))

        n_done = 0
        total = len(g)
        try:
            dispatch()
            while n_done < total:
                # poll triggered operations (Alg. 1 line 2); drain every
                # completion that has already arrived so one dispatch round
                # can refill all newly-idle executors
                completed = [triggered.get()]
                while True:
                    try:
                        completed.append(triggered.get_nowait())
                    except queue.Empty:
                        break
                for name, out, ex, t0, t1 in completed:
                    if name is _ERR:
                        failing_op = t0
                        raise RuntimeError(
                            f"op {failing_op!r} failed on executor {ex}"
                        ) from out
                    results[name] = out
                    inflight[ex] -= 1
                    trace.append(TraceEvent(name, ex, t0, t1))
                    n_done += 1
                    for s in g.successors(name):
                        indeg[s] -= 1
                        if indeg[s] == 0:
                            heapq.heappush(ready, (-self.levels[s], seq[s], s))
                dispatch()
        finally:
            for b in buffers:
                b.put(None)
            for t in threads:
                t.join(timeout=5)

        makespan = max((e.end for e in trace), default=0.0)
        return HostRunResult(
            outputs=results, trace=trace, makespan=makespan,
            peak_inflight=max(peak_inflight, 1),
        )


@dataclass
class GraphiEngine:
    """Deprecated shim: profile -> schedule -> execute (Fig 4).

    Use ``repro.api.compile(graph_or_fn, ..., hw=...)`` instead — it returns
    an :class:`~repro.api.Executable` owning the same pipeline as lazy
    cached properties.  This class remains so pre-redesign call sites keep
    working; every method delegates to an Executable underneath.
    """

    graph: Graph
    hw: HardwareModel
    n_workers: int | None = None  # defaults to hw.n_workers minus 2 reserved
    reserved_workers: int = 2     # scheduler core + lightweight executor (§5.2)
    _exe: Any = field(default=None, repr=False)

    def __post_init__(self) -> None:
        warnings.warn(
            "GraphiEngine is deprecated; use repro.api.compile(...) instead",
            DeprecationWarning,
            stacklevel=3,
        )

    def _executable(self):
        if self._exe is None:
            from repro.api import Executable

            self._exe = Executable(
                self.graph,
                self.hw,
                backend="sim",
                n_workers=self.n_workers,
                reserved_workers=self.reserved_workers,
            )
        return self._exe

    @property
    def usable_workers(self) -> int:
        return self._executable().usable_workers

    def profile(self, **kw: Any) -> ProfileResult:
        if kw:
            return self._executable().profile_with(**kw)
        return self._executable().profile

    def schedule(self, policy: str = "cpf") -> Schedule:
        return self._executable().schedule_for(policy)

    def static_slots(self, policy: str = "cpf") -> list[list[str]]:
        from .scheduler import slot_assignment

        return slot_assignment(self.graph, self.schedule(policy))

    def static_plan(self, mesh: Any, *, policy: str = "cpf", axis: str | None = None):
        from repro.dist.executor_mesh import plan_from_schedule

        return plan_from_schedule(self.graph, self.schedule(policy), mesh, axis=axis)

    def simulate(self, policy: str = "cpf", **kw: Any) -> SimResult:
        return self._executable().simulate(policy=policy, **kw)

    def execute_host(
        self, inputs: Mapping[str, Any] | None = None, n_executors: int | None = None
    ) -> HostRunResult:
        return self._executable().execute_host(inputs, n_executors=n_executors)
