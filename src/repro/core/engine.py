"""Host runtime: executor pool + the paper-faithful dynamic scheduler.

* :class:`HostScheduler` — the **paper-faithful dynamic runtime**: a
  centralized scheduler (runs on the client thread, §5.2) with critical-path-
  first priority, per-executor operation buffers (depth ``buffer_depth``),
  executor worker threads, and a triggered-operation return queue.  On a
  multi-device system each executor owns a device group; on this box it
  demonstrates exact scheduling semantics and is validated against the
  sequential interpreter.

* :class:`ExecutorPool` — a **persistent** set of executor threads that
  outlives any single run.  Several :class:`HostScheduler` runs — several
  *graphs* — submit to one pool concurrently (each run drains its own
  triggered queue), which is what lets a serve engine overlap a prefill
  graph with the in-flight decode graph on the same executors.  A process
  normally has exactly one, owned by :class:`repro.runtime.Runtime`, which
  leases disjoint executor subsets to concurrent runs.
"""
from __future__ import annotations

import heapq
import itertools
import logging
import queue
import sys
import threading
import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Mapping

from .graph import Graph
from .simulate import TraceEvent

__all__ = ["DeadlineExceeded", "ExecutorPool", "HostScheduler", "HostRunResult"]

_ERR = object()   # triggered-queue sentinel: an executor relayed an exception

_log = logging.getLogger(__name__)


class DeadlineExceeded(TimeoutError):
    """A host run overshot its deadline with ops still in flight.

    The run abandons its results and frees its executor lease; the op(s)
    that wedged keep their executor threads busy until they return (Python
    threads cannot be killed), which is why callers holding a lease
    quarantine the still-busy executors instead of handing them to the next
    run (``repro.runtime._Admission.quarantine``)."""


class ExecutorPool:
    """Persistent executor threads shared across HostScheduler runs.

    Each executor owns its buffer queue (the paper's per-executor operation
    buffer — no shared global queue).  A work item carries the submitting
    run's reply queue, so *multiple graphs* can be in flight on one pool at
    once: a serve engine submits its prefill Executable and its decode
    Executable concurrently and each run drains only its own completions.

    Exceptions raised by an op are relayed to the submitting run's reply
    queue and the executor thread keeps serving — a failed graph must not
    take the pool down for the other graphs using it.
    """

    def __init__(self, n_executors: int):
        if n_executors < 1:
            raise ValueError(f"need >= 1 executor, got {n_executors}")
        self.n_executors = n_executors
        # SimpleQueue: C-level put/get, ~3x cheaper per hop than Queue —
        # the decode loop pays one round-trip per chained node per step
        self._buffers: list[queue.SimpleQueue] = [queue.SimpleQueue() for _ in range(n_executors)]
        self._segment_lock = threading.Lock()
        self._seg_batches = itertools.count()
        # (executor, batch_no, segment_name) per segment enqueue, in buffer
        # order, when enabled: the evidence `repro.checks` replays to verify
        # batches land FIFO-consistently (no cross-plan deadlock) instead of
        # assuming the lock above works
        self.segment_log: list[tuple[int, int, str]] | None = None
        # per-executor (task name, started_at monotonic) while an op runs,
        # None when idle: the liveness signal deadline aborts and the stuck-
        # close diagnostic read to name *which* op wedged *which* executor
        self._current: list[tuple[str, float] | None] = [None] * n_executors
        # executors whose threads outlived close(): a nonempty tuple marks
        # the pool unhealthy — its threads are stuck inside an op
        self.stuck_executors: tuple[tuple[int, str], ...] = ()
        self._closed = False
        self._threads = [
            threading.Thread(target=self._worker, args=(e,), daemon=True,
                             name=f"graphi-executor-{e}")
            for e in range(n_executors)
        ]
        for t in self._threads:
            t.start()

    def submit(
        self,
        ex: int,
        name: str,
        task: Callable[[], Any],
        reply: queue.SimpleQueue,
        t_origin: float,
    ) -> None:
        if self._closed:
            raise RuntimeError("ExecutorPool is closed")
        self._buffers[ex].put((name, task, reply, t_origin))

    def submit_segments(
        self,
        items: list[tuple[int, str, Callable[[], Any]]],
        reply: queue.SimpleQueue,
        t_origin: float,
    ) -> None:
        """Queue one static-plan segment per executor, atomically.

        Segments (``repro.core.static_host``) block-wait for their peers, so
        two plans whose segment batches interleaved in opposite orders on two
        buffers would deadlock — the lock makes every batch land in the same
        relative order on every buffer.  Dynamic ops may interleave freely:
        they never wait inside an executor thread.
        """
        if self._closed:
            raise RuntimeError("ExecutorPool is closed")
        with self._segment_lock:
            batch = next(self._seg_batches)
            for ex, name, task in items:
                if self.segment_log is not None:
                    self.segment_log.append((ex, batch, name))
                self._buffers[ex].put((name, task, reply, t_origin))

    def qsize(self, ex: int) -> int:
        """Approximate queued depth on one executor (cross-run load signal)."""
        return self._buffers[ex].qsize()

    def executor_thread_ids(self) -> list[int | None]:
        """OS-level (native) thread id per executor, ``None`` for a thread
        not yet started or already exited — the handles
        :func:`repro.hwperf.pinning.pin_pool` passes to
        ``os.sched_setaffinity``."""
        return [t.native_id if t.is_alive() else None for t in self._threads]

    def current_tasks(self) -> list[tuple[str, float] | None]:
        """Snapshot of what each executor is running *right now*:
        ``(op name, started_at)`` per executor, ``None`` when idle.  The
        liveness probe behind deadline aborts, executor quarantine, and the
        stuck-close diagnostic."""
        return list(self._current)

    def close(self, timeout: float = 5.0, *, raise_on_stuck: bool = True) -> None:
        """Shut the executor threads down. Idempotent and segment-safe:

        * the shutdown sentinels go in under the segment lock, so they can
          never split an in-flight ``submit_segments`` batch — work queued
          *before* close (including a whole static plan) still completes
          (SimpleQueue is FIFO: every item precedes its buffer's sentinel);
        * a second ``close()`` — or one racing the first from another
          thread — neither re-poisons the buffers nor raises; it just joins
          whatever threads remain;
        * closing from an executor thread itself (an op that tears its own
          pool down) skips the self-join instead of raising.

        A thread that outlives its ``timeout``-second join is **stuck inside
        an op**: the pool records it in :attr:`stuck_executors` (with the
        op's name), logs the diagnostic, and raises ``RuntimeError`` —
        returning silently would let the caller believe every executor
        exited when one is still holding a thread (and whatever memory its
        task closed over).  ``raise_on_stuck=False`` keeps the record and
        the log but suppresses the raise, for close calls already on an
        exception path that must not be masked.
        """
        with self._segment_lock:
            if not self._closed:
                self._closed = True
                for b in self._buffers:
                    b.put(None)
        me = threading.current_thread()
        deadline = time.monotonic() + timeout
        stuck: list[tuple[int, str]] = []
        for e, t in enumerate(self._threads):
            if t is me:
                continue
            t.join(timeout=max(0.0, deadline - time.monotonic()))
            if t.is_alive():
                cur = self._current[e]
                stuck.append((e, cur[0] if cur else "<between ops>"))
        if stuck:
            self.stuck_executors = tuple(stuck)
            detail = ", ".join(f"executor {e} in op {nm!r}" for e, nm in stuck)
            _log.warning(
                "ExecutorPool.close: %d executor thread(s) still running "
                "after %.1fs — %s; pool is unhealthy", len(stuck), timeout,
                detail)
            if raise_on_stuck:
                raise RuntimeError(
                    f"ExecutorPool.close: {len(stuck)} executor thread(s) "
                    f"stuck after {timeout:.1f}s ({detail})")

    def __enter__(self) -> "ExecutorPool":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def _worker(self, ex: int) -> None:
        while True:
            item = self._buffers[ex].get()
            if item is None:
                return
            name, task, reply, t_origin = item
            self._current[ex] = (name, time.monotonic())
            t0 = time.perf_counter() - t_origin
            try:
                out = task()
            except BaseException as e:  # noqa: BLE001 — relayed to the run
                self._current[ex] = None
                reply.put((_ERR, e, ex, name, 0.0))
                del item, task
                continue
            t1 = time.perf_counter() - t_origin
            self._current[ex] = None
            reply.put((name, out, ex, t0, t1))
            # an idle executor must not pin its last task (a static-plan
            # segment closes over the whole plan -> graph) or result arrays
            # until the next item arrives
            del item, task, out


def _input_lookup(inputs: Mapping[str, Any], name: str) -> Any:
    return inputs[name]


@dataclass
class HostRunResult:
    outputs: dict[str, Any]
    trace: list[TraceEvent]
    makespan: float
    peak_inflight: int = 1      # max ops queued on one executor (buffer use)


class HostScheduler:
    """Centralized scheduler + N executor threads with per-executor buffers.

    Executors poll *their own* buffer (no shared global queue — the paper's
    contention fix); on completion they push (op, result) onto the triggered
    queue, which the scheduler drains (Algorithm 1/2).  Each executor buffer
    holds up to ``buffer_depth`` dispatched ops, so an executor finishing one
    op can start the next without a scheduler round-trip.

    ``pool`` binds the run to a shared persistent :class:`ExecutorPool`
    (``n_executors`` then follows the pool's size); without one, each
    ``run()`` spins up an ephemeral pool and tears it down on exit — or
    takes a per-run pool/lease via ``run(pool=...)``, which is how a
    :class:`repro.runtime.Runtime` executes the same scheduler on a fresh
    :class:`~repro.runtime.ExecutorLease` every run without rebuilding the
    hoisted per-graph immutables.
    """

    def __init__(
        self,
        graph: Graph,
        n_executors: int,
        *,
        costs: Mapping[str, float] | None = None,
        buffer_depth: int = 1,
        pool: ExecutorPool | None = None,
    ):
        if n_executors < 1:
            raise ValueError(f"need >= 1 executor, got {n_executors}")
        if buffer_depth < 1:
            raise ValueError(f"need buffer_depth >= 1, got {buffer_depth}")
        self.graph = graph
        self.pool = pool
        self.n_executors = pool.n_executors if pool is not None else n_executors
        costs = costs or {n: max(g.flops, 1.0) for n, g in zip(graph.names, graph.nodes)}
        self.levels = graph.levels({n: float(costs[n]) for n in graph.names})
        self.buffer_depth = buffer_depth
        # per-graph immutables, hoisted: repeated run() calls on one
        # scheduler (the decode loop) must not rebuild these every step
        names = graph.names
        seq = {n: i for i, n in enumerate(names)}
        self._indeg0 = {n: graph.in_degree(n) for n in names}
        self._entry = {n: (-self.levels[n], seq[n], n) for n in names}
        self._ready0 = sorted(self._entry[n] for n in names if self._indeg0[n] == 0)
        self._total = len(graph)
        self._graph_version = graph.version

    def run(
        self,
        inputs: Mapping[str, Any] | None = None,
        *,
        pool: Any = None,
        deadline: float | None = None,
    ) -> HostRunResult:
        g = self.graph
        if g.version != self._graph_version:
            # the per-graph immutables above were hoisted to __init__; a
            # node added since would silently never execute
            raise RuntimeError(
                f"graph {g.name!r} mutated (version {self._graph_version} -> "
                f"{g.version}, {self._total} -> {len(g)} nodes) after "
                "HostScheduler construction — build a new scheduler"
            )
        inputs = dict(inputs or {})
        results: dict[str, Any] = {}
        indeg = dict(self._indeg0)
        entry = self._entry
        successors = g.successors

        ready: list[tuple[float, int, str]] = list(self._ready0)  # sorted => heap

        n_exec = self.n_executors
        pool = pool if pool is not None else self.pool
        ephemeral = pool is None
        if ephemeral:
            pool = ExecutorPool(n_exec)
        elif pool.n_executors < n_exec:
            raise ValueError(
                f"run needs {n_exec} executors but the pool has "
                f"{pool.n_executors}"
            )
        # depth is enforced per-run by the inflight counters, so the pool's
        # queues stay unbounded — shutdown puts never block on a full buffer
        triggered: queue.SimpleQueue = queue.SimpleQueue()
        inflight = [0] * n_exec
        depth = self.buffer_depth
        # idle-executor heap keyed (inflight, qsize-at-push, e): replaces the
        # O(n_exec) min(...) scan per dispatched op.  Entries go stale when
        # inflight changes; stale entries are discarded (and re-keyed) on
        # pop, so total heap traffic stays O(ops log n_exec).
        idle: list[tuple[int, int, int]] = sorted(
            (0, pool.qsize(e), e) for e in range(n_exec)
        )
        peak_inflight = 0
        trace: list[TraceEvent] = []
        t_origin = time.perf_counter()

        n_done = 0
        total = self._total

        def dispatch() -> None:
            """Fire ready ops highest-level-first at the least-loaded
            executors until every buffer is full or nothing is ready.
            Cross-run load on a shared pool shows up via ``pool.qsize``.
            Input passthroughs resolve inline — a serving decode step's
            dozens of input leaves must not each pay an executor
            round-trip."""
            nonlocal peak_inflight, n_done
            while ready:
                name = ready[0][2]
                node = g[name]
                if node.fn is None and name in inputs:
                    heapq.heappop(ready)
                    results[name] = inputs[name]
                    n_done += 1
                    for s in successors(name):
                        indeg[s] -= 1
                        if indeg[s] == 0:
                            heapq.heappush(ready, entry[s])
                    continue
                ex = -1
                while idle:
                    inf, _, e = idle[0]
                    if inf == inflight[e] and inf < depth:
                        ex = e
                        heapq.heappop(idle)
                        break
                    heapq.heappop(idle)  # stale: re-key if still usable
                    if inflight[e] < depth:
                        heapq.heappush(idle, (inflight[e], pool.qsize(e), e))
                if ex < 0:
                    return          # every buffer is full
                heapq.heappop(ready)
                if node.fn is None:
                    # no fn and no input: raises in the executor and is
                    # relayed like any other op failure
                    task: Any = partial(_input_lookup, inputs, name)
                else:
                    task = partial(node.fn, *(results[d] for d in node.deps))
                inflight[ex] += 1
                if inflight[ex] < depth:
                    heapq.heappush(idle, (inflight[ex], pool.qsize(ex), ex))
                peak_inflight = max(peak_inflight, inflight[ex])
                pool.submit(ex, name, task, triggered, t_origin)

        try:
            dispatch()
            while n_done < total:
                # poll triggered operations (Alg. 1 line 2); drain every
                # completion that has already arrived so one dispatch round
                # can refill all newly-idle executors
                if deadline is None:
                    first = triggered.get()
                else:
                    # a per-run deadline bounds each wait: a hung op must
                    # poison this run (freeing its lease) instead of wedging
                    # the scheduler — and the pool behind it — forever
                    try:
                        first = triggered.get(
                            timeout=max(0.0, deadline - time.monotonic()))
                    except queue.Empty:
                        busy = ""
                        if hasattr(pool, "current_tasks"):
                            cur = [c[0] for c in pool.current_tasks() if c]
                            busy = f"; executors busy in {cur!r}" if cur else ""
                        raise DeadlineExceeded(
                            f"graph {g.name!r}: deadline exceeded with "
                            f"{total - n_done} of {total} ops unfinished"
                            f"{busy}") from None
                completed = [first]
                while True:
                    try:
                        completed.append(triggered.get_nowait())
                    except queue.Empty:
                        break
                for name, out, ex, t0, t1 in completed:
                    if name is _ERR:
                        failing_op = t0
                        raise RuntimeError(
                            f"op {failing_op!r} failed on executor {ex}"
                        ) from out
                    results[name] = out
                    inflight[ex] -= 1
                    heapq.heappush(idle, (inflight[ex], pool.qsize(ex), ex))
                    trace.append(TraceEvent(name, ex, t0, t1))
                    n_done += 1
                    for s in successors(name):
                        indeg[s] -= 1
                        if indeg[s] == 0:
                            heapq.heappush(ready, entry[s])
                dispatch()
        finally:
            if ephemeral:
                # on an exception path (op failure, deadline) the close must
                # not mask the in-flight error with a stuck-thread raise —
                # the unhealthy state is still recorded and logged
                pool.close(raise_on_stuck=sys.exc_info()[0] is None)

        makespan = max((e.end for e in trace), default=0.0)
        return HostRunResult(
            outputs=results, trace=trace, makespan=makespan,
            peak_inflight=max(peak_inflight, 1),
        )
