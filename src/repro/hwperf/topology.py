"""CPU topology discovery: which logical CPUs exist, how they group into
physical cores, sockets, and NUMA nodes.

The paper pins each executor's OpenMP team to a contiguous block of KNL
cores (§3.1/Fig 3: pinned threads reach up to ~1.45x the FLOPS of
OS-scheduled ones).  Reproducing that requires knowing the machine's shape:

* two logical CPUs on one physical core (SMT siblings) share execution
  ports — putting two executors there is co-location, not parallelism;
* cores on different sockets share nothing but the interconnect — an
  executor team spanning sockets pays cross-socket cache traffic on every
  barrier.

:func:`detect_topology` reads the truth from ``/sys`` (restricted to the
CPUs this process may use, per ``os.sched_getaffinity``); where ``/sys`` is
absent (non-Linux, containers with a masked sysfs) it degrades to a flat
:func:`synthetic_topology` so every consumer — the pinning planner, the
co-location harness, the tests — works against one interface everywhere.
"""
from __future__ import annotations

import glob
import os
import re
from dataclasses import dataclass

__all__ = [
    "LogicalCpu",
    "CpuTopology",
    "detect_topology",
    "synthetic_topology",
    "disjoint_core_sets",
]


@dataclass(frozen=True)
class LogicalCpu:
    """One OS-schedulable CPU: the unit ``sched_setaffinity`` masks."""

    cpu: int      # logical id (the scheduler's number)
    core: int     # physical core id (SMT siblings share it)
    socket: int   # physical package id
    node: int     # NUMA node


@dataclass(frozen=True)
class CpuTopology:
    """The set of logical CPUs this process may run on, with their physical
    grouping.  ``source`` records provenance: ``"sys"`` (read from sysfs),
    ``"synthetic"`` (constructed), or ``"flat"`` (cpu count only — no
    core/socket structure was discoverable)."""

    cpus: tuple[LogicalCpu, ...]
    source: str = "synthetic"

    @property
    def n_cpus(self) -> int:
        return len(self.cpus)

    @property
    def sockets(self) -> tuple[int, ...]:
        return tuple(sorted({c.socket for c in self.cpus}))

    @property
    def nodes(self) -> tuple[int, ...]:
        return tuple(sorted({c.node for c in self.cpus}))

    @property
    def smt(self) -> bool:
        """Whether any physical core carries more than one logical CPU."""
        return any(len(g) > 1 for g in self.physical_cores())

    def physical_cores(self) -> list[tuple[int, ...]]:
        """Logical CPU ids grouped by (socket, core) — SMT siblings land in
        one group.  Stable order: by socket, then core id, then cpu id, so
        two detections of one machine enumerate identically."""
        groups: dict[tuple[int, int], list[int]] = {}
        for c in self.cpus:
            groups.setdefault((c.socket, c.core), []).append(c.cpu)
        return [tuple(sorted(groups[k])) for k in sorted(groups)]

    def cpus_of_socket(self, socket: int) -> tuple[int, ...]:
        return tuple(sorted(c.cpu for c in self.cpus if c.socket == socket))

    def smt_siblings(self, cpu: int) -> tuple[int, ...]:
        """All logical CPUs (including ``cpu``) on ``cpu``'s physical core."""
        me = next((c for c in self.cpus if c.cpu == cpu), None)
        if me is None:
            raise ValueError(f"cpu {cpu} is not in this topology")
        return tuple(sorted(
            c.cpu for c in self.cpus
            if c.socket == me.socket and c.core == me.core))

    def describe(self) -> str:
        cores = self.physical_cores()
        return (f"CpuTopology({self.n_cpus} cpus, {len(cores)} cores, "
                f"{len(self.sockets)} socket(s), {len(self.nodes)} node(s), "
                f"smt={'on' if self.smt else 'off'}, source={self.source})")


def synthetic_topology(n_cpus: int, *, sockets: int = 1, smt: int = 1,
                       source: str = "synthetic") -> CpuTopology:
    """A constructed topology: ``n_cpus`` logical CPUs over
    ``n_cpus // smt`` physical cores spread evenly across ``sockets``.

    Logical ids follow the Linux enumeration convention — first one CPU per
    core (0..cores-1), then the SMT siblings (cores..2*cores-1) — so tests
    written against synthetic shapes transfer to real machines.
    """
    if n_cpus < 1:
        raise ValueError(f"need >= 1 cpu, got {n_cpus}")
    if sockets < 1 or smt < 1:
        raise ValueError(f"need sockets >= 1 and smt >= 1, got {sockets}/{smt}")
    n_cores = max(1, n_cpus // smt)
    cpus = []
    for i in range(n_cpus):
        core = i % n_cores
        socket = core * sockets // n_cores
        cpus.append(LogicalCpu(cpu=i, core=core, socket=socket, node=socket))
    return CpuTopology(cpus=tuple(cpus), source=source)


def _read_int(path: str) -> int | None:
    try:
        with open(path) as f:
            return int(f.read().strip())
    except (OSError, ValueError):
        return None


def _cpu_node(cpu_dir: str) -> int | None:
    """NUMA node of one cpu: the ``nodeN`` entry linked into its sysfs dir."""
    for p in glob.glob(os.path.join(cpu_dir, "node*")):
        m = re.fullmatch(r"node(\d+)", os.path.basename(p))
        if m:
            return int(m.group(1))
    return None


def _usable_cpus() -> list[int]:
    """The logical CPUs this process may be scheduled on: the affinity mask
    where the OS exposes one (a cgroup cpuset shrinks it below the machine
    count — planning against unusable CPUs would make every pin fail)."""
    if hasattr(os, "sched_getaffinity"):
        try:
            return sorted(os.sched_getaffinity(0))
        except OSError:  # pragma: no cover - exotic kernels
            pass
    return list(range(os.cpu_count() or 1))


def detect_topology(sysfs: str = "/sys") -> CpuTopology:
    """The running machine's topology, restricted to usable CPUs.

    Reads ``{sysfs}/devices/system/cpu/cpuN/topology/`` per CPU; any CPU
    whose files are unreadable (masked sysfs, non-Linux) drops the whole
    detection to a flat :func:`synthetic_topology` over the usable count —
    a *partial* sysfs read must not fabricate an asymmetric machine.
    ``sysfs`` is injectable so tests exercise the parser against a fake
    tree.
    """
    usable = _usable_cpus()
    cpus: list[LogicalCpu] = []
    for cpu in usable:
        topo_dir = os.path.join(sysfs, "devices", "system", "cpu", f"cpu{cpu}")
        core = _read_int(os.path.join(topo_dir, "topology", "core_id"))
        socket = _read_int(
            os.path.join(topo_dir, "topology", "physical_package_id"))
        if core is None or socket is None:
            return synthetic_topology(len(usable), source="flat")
        node = _cpu_node(topo_dir)
        cpus.append(LogicalCpu(
            cpu=cpu, core=core, socket=max(0, socket),
            node=node if node is not None else max(0, socket)))
    if not cpus:
        return synthetic_topology(1, source="flat")
    return CpuTopology(cpus=tuple(cpus), source="sys")


def disjoint_core_sets(
    topology: CpuTopology,
    n_sets: int,
    *,
    cpus_per_set: int | None = None,
) -> list[tuple[int, ...]]:
    """Partition the topology's CPUs into ``n_sets`` core sets for pinned
    executors.

    Placement policy (the paper's §3.1 pinning, socket-aware):

    * whole physical cores go to one set — SMT siblings are never split
      across executors (they would interfere by construction);
    * sets fill socket by socket, so each executor's CPUs stay on one
      socket whenever ``cpus_per_set`` fits (no cross-socket barriers);
    * when there are fewer CPUs than sets the sets are **not** disjoint —
      executors round-robin over single CPUs (two executors time-share a
      CPU rather than crash; the pinning layer reports ``disjoint=False``).

    ``cpus_per_set`` defaults to an even split (``n_cpus // n_sets``,
    floor 1).  Leftover CPUs stay unassigned, mirroring the paper's idle
    leftover cores (§4.2).
    """
    if n_sets < 1:
        raise ValueError(f"need >= 1 set, got {n_sets}")
    # socket-major, whole-core-major CPU order: consuming this list in
    # chunks gives each set contiguous cores on one socket
    ordered: list[int] = []
    for socket in topology.sockets:
        for group in topology.physical_cores():
            if all(c in topology.cpus_of_socket(socket) for c in group):
                ordered.extend(group)
    if not ordered:  # pragma: no cover - empty topology is rejected upstream
        ordered = [c.cpu for c in topology.cpus]
    if n_sets > len(ordered):
        # oversubscribed: round-robin single CPUs (overlapping sets)
        return [(ordered[i % len(ordered)],) for i in range(n_sets)]
    size = cpus_per_set if cpus_per_set is not None else max(1, len(ordered) // n_sets)
    size = max(1, min(size, len(ordered) // n_sets))
    return [tuple(ordered[i * size:(i + 1) * size]) for i in range(n_sets)]
