"""Contention model: per-op-class interference coefficients measured by the
co-location harness, persisted in the calibration store, and fed back into
both the simulator (duration adjustment) and placement (a registered
``SchedulePolicy`` that keeps high-contention classes apart).

This replaces the scalar ``duration_multiplier`` guess in
:mod:`repro.core.simulate` with measured structure: an op's duration is
scaled by the worst pairwise slowdown against the classes co-resident with
it at dispatch time (max, not product — contended resources saturate, they
don't compound multiplicatively across neighbors).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Mapping

from .colocate import InterferenceMatrix

__all__ = [
    "classify",
    "ContentionModel",
    "ContentionAwareCPF",
    "install_contention_policy",
]

# op kind (repro.core.graph.OpNode.kind) -> contended-resource class
# (the axes the co-location harness measures)
_KIND_CLASS = {
    "gemm": "gemm",
    "conv": "gemm",          # compute-bound, FMA-port contention
    "attention": "gemm",
    "elementwise": "elementwise",
    "scan": "elementwise",
    "generic": "elementwise",
    "input": "memory",       # pure data movement
}


def classify(node) -> str:
    """Contention class of an op node (duck-typed: anything with ``.kind``)."""
    return _KIND_CLASS.get(getattr(node, "kind", "generic"), "elementwise")


@dataclass
class ContentionModel:
    """Measured interference coefficients between op classes.

    ``pair_slowdown[(a, b)]`` — how much slower class-*a* work runs beside
    class-*b* work than alone (>= 1.0).  Unknown pairs default to 1.0: an
    unmeasured combination must never *inflate* simulated costs.
    """

    solo: dict[str, float] = field(default_factory=dict)
    pair_slowdown: dict[tuple[str, str], float] = field(default_factory=dict)
    # a class is "hot" if any pairing slows it (or its partner) past this
    hot_threshold: float = 1.25
    pinned: bool = False

    @classmethod
    def from_matrix(cls, m: InterferenceMatrix, *,
                    hot_threshold: float = 1.25) -> "ContentionModel":
        pairs = {
            (a, b): m.slowdown(a, b)
            for a in m.classes() for b in m.classes()
        }
        return cls(solo=dict(m.solo), pair_slowdown=pairs,
                   hot_threshold=hot_threshold, pinned=m.pinned)

    def multiplier(self, op_class: str, co_classes: Iterable[str]) -> float:
        """Duration multiplier for ``op_class`` running beside
        ``co_classes``: the worst single pairwise slowdown."""
        worst = 1.0
        for c in co_classes:
            worst = max(worst, self.pair_slowdown.get((op_class, c), 1.0))
        return worst

    def multiplier_for(self, node, co_nodes: Iterable) -> float:
        """Node-level entry point for the simulator: classify the op and
        its co-residents, return the duration multiplier."""
        return self.multiplier(classify(node), (classify(n) for n in co_nodes))

    def pair_cost(self, a: str, b: str) -> float:
        """Symmetric badness of co-scheduling classes ``a`` and ``b`` —
        the placement policy's objective (each direction's slowdown can
        differ; placement cares about the worse one)."""
        return max(self.pair_slowdown.get((a, b), 1.0),
                   self.pair_slowdown.get((b, a), 1.0))

    def hot_classes(self) -> set[str]:
        """Classes involved in any pairing past ``hot_threshold``."""
        hot: set[str] = set()
        for (a, b), s in self.pair_slowdown.items():
            if s > self.hot_threshold:
                hot.add(a)
                hot.add(b)
        return hot

    # -- persistence (CalibrationStore format 3 "interference" section) ----
    def to_dict(self) -> dict:
        return {
            "solo": dict(self.solo),
            "pairs": {f"{a}|{b}": s for (a, b), s in
                      sorted(self.pair_slowdown.items())},
            "hot_threshold": self.hot_threshold,
            "pinned": self.pinned,
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "ContentionModel":
        pairs: dict[tuple[str, str], float] = {}
        for key, s in d.get("pairs", {}).items():
            a, _, b = key.partition("|")
            pairs[(a, b)] = float(s)
        return cls(
            solo={k: float(v) for k, v in d.get("solo", {}).items()},
            pair_slowdown=pairs,
            hot_threshold=float(d.get("hot_threshold", 1.25)),
            pinned=bool(d.get("pinned", False)),
        )


class ContentionAwareCPF:
    """CPF priorities + contention-aware placement: steer each op onto the
    free executor whose most recent op's class interferes least with it.

    The executor-assignment hook only picks among executors free no later
    than the earliest one (the engine guarantees placement never delays
    dispatch), so this is strictly a *placement* refinement of CPF — with a
    contention-free model it degenerates to CPF exactly, which is what the
    never-worsens bench gate checks.
    """

    randomized = False

    def __init__(self, model: ContentionModel, *, name: str = "cpf-contention"):
        self.name = name
        self.model = model

    def priorities(self, ctx) -> Mapping[str, float]:
        return ctx.levels

    def assign_executor(self, ctx, op, free):
        if not free:
            return None
        last: dict[int, str] = ctx.scratch.setdefault(
            "contention.exec_class", {})
        cls = classify(ctx.graph[op])
        hot = ctx.scratch.get("contention.hot")
        if hot is None:
            hot = self.model.hot_classes()
            ctx.scratch["contention.hot"] = hot
        choice = free[0]
        if cls in hot:
            # among equally-early executors, minimize pairwise contention
            # with each executor's most recent op class; stable (lowest
            # executor id) on ties so schedules stay bit-reproducible
            choice = min(
                free,
                key=lambda e: (self.model.pair_cost(cls, last.get(e, "")), e))
        last[choice] = cls
        return choice


def install_contention_policy(
    model: ContentionModel, *, name: str = "cpf-contention"
) -> ContentionAwareCPF:
    """Register a :class:`ContentionAwareCPF` over ``model`` in the policy
    registry (replacing any previous installation — the model may have been
    re-measured).  Not done at import time: the registry's contents must be
    deterministic, and a contention policy is meaningless without a model.
    """
    from ..core.policies import register_policy

    policy = ContentionAwareCPF(model, name=name)
    register_policy(policy, replace=True)
    return policy
