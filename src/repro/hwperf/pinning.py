"""Pin executor threads to core sets (paper §3.1: pinned executors reach up
to ~1.45x the FLOPS of OS-scheduled threads).

The plan/apply split mirrors the rest of the stack: :func:`plan_pinning`
turns a :class:`~repro.hwperf.topology.CpuTopology` into a
:class:`PinningPlan` (executor -> disjoint CPU set, socket-aware, SMT
siblings kept together) and :func:`pin_pool` applies it to a live
:class:`~repro.core.engine.ExecutorPool` via ``os.sched_setaffinity`` on
each worker thread's native id.

Everything degrades to an unpinned no-op — with **one** process-wide
warning, never a crash — where affinity is unsupported: non-Linux (no
``sched_setaffinity``), a restricted cpuset that rejects the mask, or the
``REPRO_HWPERF_NO_AFFINITY`` environment variable (the CI smoke leg that
simulates a platform without affinity).
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass, field

from .topology import CpuTopology, detect_topology, disjoint_core_sets

__all__ = [
    "NO_AFFINITY_ENV",
    "AppliedPinning",
    "PinningPlan",
    "affinity_supported",
    "pin_current_thread",
    "pin_pool",
    "plan_pinning",
]

# set (to any non-empty value) to behave as if sched_setaffinity does not
# exist: the no-affinity smoke leg proves the whole stack degrades to
# unpinned execution instead of crashing
NO_AFFINITY_ENV = "REPRO_HWPERF_NO_AFFINITY"

_warned = False


def _warn_once(msg: str) -> None:
    """One warning per process: a serve loop re-leasing executors every step
    must not emit a warning per step on a platform that simply has no
    affinity syscall."""
    global _warned
    if not _warned:
        _warned = True
        warnings.warn(msg, RuntimeWarning, stacklevel=3)


def _reset_warning_for_tests() -> None:
    global _warned
    _warned = False


def affinity_supported() -> bool:
    """Whether thread pinning can work here at all: Linux-style
    ``sched_setaffinity`` present and not disabled via
    :data:`NO_AFFINITY_ENV`."""
    if os.environ.get(NO_AFFINITY_ENV):
        return False
    return hasattr(os, "sched_setaffinity") and hasattr(os, "sched_getaffinity")


@dataclass(frozen=True)
class PinningPlan:
    """Executor index -> CPU id set, plus the topology it was planned on."""

    assignments: tuple[tuple[int, ...], ...]
    topology: CpuTopology

    @property
    def n_executors(self) -> int:
        return len(self.assignments)

    @property
    def disjoint(self) -> bool:
        """Whether no CPU serves two executors (False only when the machine
        has fewer usable CPUs than executors)."""
        seen: set[int] = set()
        for cpus in self.assignments:
            if seen.intersection(cpus):
                return False
            seen.update(cpus)
        return True

    def cpus_for(self, executor: int) -> tuple[int, ...]:
        return self.assignments[executor % len(self.assignments)]

    def describe(self) -> str:
        sets = ", ".join(
            f"E{i}->[{','.join(map(str, c))}]"
            for i, c in enumerate(self.assignments))
        return (f"PinningPlan({self.n_executors} executors, "
                f"disjoint={self.disjoint}, {sets})")


def plan_pinning(
    n_executors: int,
    topology: CpuTopology | None = None,
    *,
    cpus_per_executor: int | None = None,
) -> PinningPlan:
    """Socket-aware executor->CPU-set assignment over ``topology``
    (detected from the running machine when not given)."""
    topo = topology if topology is not None else detect_topology()
    sets = disjoint_core_sets(topo, n_executors, cpus_per_set=cpus_per_executor)
    return PinningPlan(assignments=tuple(sets), topology=topo)


@dataclass
class AppliedPinning:
    """What actually happened when a plan met the OS."""

    plan: PinningPlan
    pinned: bool
    n_threads: int = 0
    errors: tuple[str, ...] = field(default_factory=tuple)

    def describe(self) -> str:
        state = "pinned" if self.pinned else "unpinned (no-op)"
        err = f", errors={list(self.errors)}" if self.errors else ""
        return f"AppliedPinning({state}, {self.n_threads} threads{err})"


def _set_affinity(tid: int, cpus: tuple[int, ...]) -> None:
    os.sched_setaffinity(tid, cpus)


def pin_current_thread(cpus: tuple[int, ...]) -> bool:
    """Pin the calling thread (the co-location harness's measurement
    threads); returns whether the pin took."""
    if not affinity_supported():
        _warn_once(
            "thread pinning unavailable on this platform "
            "(no sched_setaffinity); running unpinned")
        return False
    try:
        _set_affinity(0, cpus)   # tid 0 = the calling thread
        return True
    except OSError as e:
        _warn_once(
            f"thread pinning rejected by the OS ({e}); running unpinned")
        return False


def pin_pool(pool, plan: PinningPlan) -> AppliedPinning:
    """Pin each of ``pool``'s executor threads to its planned CPU set.

    Best-effort and all-or-nothing: if any pin is rejected (restricted
    cpuset, permissions) every already-pinned thread is restored to the
    full usable mask, one warning is emitted, and the pool runs unpinned —
    a half-pinned pool would concentrate every executor the OS *did* accept
    onto a fraction of the machine.
    """
    if not affinity_supported():
        _warn_once(
            "executor pinning unavailable on this platform "
            "(no sched_setaffinity); pool runs OS-scheduled")
        return AppliedPinning(plan=plan, pinned=False)
    tids = pool.executor_thread_ids()
    full_mask = tuple(sorted(c.cpu for c in plan.topology.cpus))
    pinned: list[int] = []
    for ex, tid in enumerate(tids):
        if tid is None:   # thread not started / already exited
            continue
        try:
            _set_affinity(tid, plan.cpus_for(ex))
            pinned.append(tid)
        except OSError as e:
            for done in pinned:
                try:
                    _set_affinity(done, full_mask)
                except OSError:  # pragma: no cover - rollback best-effort
                    pass
            _warn_once(
                f"executor pinning rejected by the OS ({e}); "
                "pool runs OS-scheduled")
            return AppliedPinning(plan=plan, pinned=False, errors=(str(e),))
    return AppliedPinning(plan=plan, pinned=bool(pinned), n_threads=len(pinned))
