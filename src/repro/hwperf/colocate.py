"""Co-location harness: run op-class workloads concurrently on pinned core
sets and measure the slowdown each pair inflicts vs running alone.

This is the measured version of the paper's Fig 3 axis.  ``calibrate()``
times every op *solo*; here two workloads start behind a barrier on
disjoint pinned core sets and each reports its own per-iteration time.
``pair / solo`` is the contention coefficient that
:mod:`repro.hwperf.model` turns into a cost adjustment and a placement
policy.

Workloads are small numpy kernels chosen to stress the three contended
resources the op classes map onto:

* ``gemm`` — execution ports / FMA throughput (compute-bound matmul);
* ``elementwise`` — modest bandwidth + ports (fused vector arithmetic);
* ``memory`` — cache and DRAM bandwidth (large streaming copy).

On a 1-CPU box (this container) the "disjoint" sets overlap, so measured
slowdowns just say "time-sharing costs 2x" — still a valid signal for the
model, but the bench marks the run degraded and skips hardware gates.
"""
from __future__ import annotations

import statistics
import threading
import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .pinning import pin_current_thread
from .topology import CpuTopology, detect_topology, disjoint_core_sets

__all__ = [
    "Workload",
    "InterferenceMatrix",
    "default_workloads",
    "measure_interference",
]


@dataclass(frozen=True)
class Workload:
    """One op-class proxy: ``setup()`` builds state once, ``run(state)`` is
    the timed unit of work."""

    op_class: str
    setup: Callable[[], object]
    run: Callable[[object], object]


def default_workloads(*, scale: int = 192) -> list[Workload]:
    """The three contended-resource proxies.  ``scale`` sets the matmul
    side / vector length so smoke runs finish in milliseconds."""
    n = max(32, scale)

    def gemm_setup():
        rng = np.random.default_rng(0)
        return (rng.standard_normal((n, n), dtype=np.float32),
                rng.standard_normal((n, n), dtype=np.float32))

    def gemm_run(state):
        a, b = state
        return a @ b

    def elem_setup():
        rng = np.random.default_rng(1)
        return rng.standard_normal(n * n, dtype=np.float32)

    def elem_run(x):
        return np.tanh(x * 1.0001 + 0.5)

    def mem_setup():
        # large enough to spill L2 even scaled down: streaming copy is
        # bandwidth-bound, the contended resource for memory-class ops
        rng = np.random.default_rng(2)
        return (rng.standard_normal(8 * n * n, dtype=np.float32),
                np.empty(8 * n * n, dtype=np.float32))

    def mem_run(state):
        src, dst = state
        np.copyto(dst, src)
        return dst

    return [
        Workload("gemm", gemm_setup, gemm_run),
        Workload("elementwise", elem_setup, elem_run),
        Workload("memory", mem_setup, mem_run),
    ]


@dataclass
class InterferenceMatrix:
    """Solo medians and pairwise co-run medians, seconds per iteration.

    ``pair[(a, b)]`` is *a*'s per-iteration time while *b* runs beside it —
    asymmetric by construction (a matmul barely notices a copy loop; the
    copy loop notices the matmul's cache pressure).
    """

    solo: dict[str, float] = field(default_factory=dict)
    pair: dict[tuple[str, str], float] = field(default_factory=dict)
    pinned: bool = False
    disjoint: bool = False

    def slowdown(self, a: str, b: str) -> float:
        """How much slower ``a`` runs beside ``b`` than alone (>= 1.0 when
        there is contention; clamped below at 1.0 — timer noise must not
        turn co-location into a speedup)."""
        base = self.solo.get(a)
        co = self.pair.get((a, b))
        if not base or co is None:
            return 1.0
        return max(1.0, co / base)

    def classes(self) -> list[str]:
        return sorted(self.solo)


def _timed_loop(wl: Workload, state, iters: int, barrier, cpus,
                out: dict, key: str, stop: threading.Event | None) -> None:
    """One measurement thread: pin, warm, sync on the barrier, then time
    ``iters`` runs (or loop until ``stop`` when acting as background load)."""
    if cpus:
        out[f"{key}_pinned"] = pin_current_thread(cpus)
    wl.run(state)  # warm caches / allocator before the barrier
    barrier.wait()
    if stop is not None:
        while not stop.is_set():
            wl.run(state)
        return
    t0 = time.perf_counter()
    for _ in range(iters):
        wl.run(state)
    out[key] = (time.perf_counter() - t0) / iters


def _run_pair(a: Workload, b: Workload, cpus_a, cpus_b,
              iters: int) -> float:
    """Per-iteration time of ``a`` while ``b`` loops beside it.  ``b`` runs
    until ``a`` finishes so ``a`` is co-resident for its whole window."""
    state_a, state_b = a.setup(), b.setup()
    barrier = threading.Barrier(2)
    stop = threading.Event()
    out: dict = {}
    ta = threading.Thread(
        target=_timed_loop, args=(a, state_a, iters, barrier, cpus_a,
                                  out, "a", None), daemon=True)
    tb = threading.Thread(
        target=_timed_loop, args=(b, state_b, 0, barrier, cpus_b,
                                  out, "b", stop), daemon=True)
    tb.start()
    ta.start()
    ta.join()
    stop.set()
    tb.join()
    return out["a"]


def measure_interference(
    workloads: list[Workload] | None = None,
    topology: CpuTopology | None = None,
    *,
    iters: int = 20,
    repeats: int = 3,
    pinned: bool = True,
) -> InterferenceMatrix:
    """Measure solo and pairwise co-run times for every workload pair.

    Each measurement repeats ``repeats`` times and keeps the median — a
    single descheduling event must not become a contention coefficient.
    With ``pinned=False`` (or where affinity is unsupported) threads run
    OS-scheduled; the matrix records which mode actually happened.
    """
    wls = workloads if workloads is not None else default_workloads()
    topo = topology if topology is not None else detect_topology()
    sets = disjoint_core_sets(topo, 2)
    cpus_a, cpus_b = (sets[0], sets[1]) if pinned else (None, None)
    disjoint = pinned and not set(sets[0]) & set(sets[1])

    m = InterferenceMatrix(pinned=False, disjoint=disjoint)
    pin_results: list[bool] = []
    for wl in wls:
        state = wl.setup()
        runs = []
        for _ in range(repeats):
            barrier = threading.Barrier(1)
            out: dict = {}
            t = threading.Thread(
                target=_timed_loop,
                args=(wl, state, iters, barrier, cpus_a, out, "a", None),
                daemon=True)
            t.start()
            t.join()
            runs.append(out["a"])
            if "a_pinned" in out:
                pin_results.append(out["a_pinned"])
        m.solo[wl.op_class] = statistics.median(runs)
    # "pinned" only when every attempted pin actually took — a matrix
    # measured with OS-rejected pins is an unpinned measurement
    m.pinned = bool(pin_results) and all(pin_results)
    for a in wls:
        for b in wls:
            runs = [_run_pair(a, b, cpus_a, cpus_b, iters)
                    for _ in range(repeats)]
            m.pair[(a.op_class, b.op_class)] = statistics.median(runs)
    return m
