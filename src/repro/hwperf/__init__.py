"""repro.hwperf — measured hardware performance: CPU topology discovery,
core-pinned executors, co-location interference measurement, and the
contention model that feeds measurements back into simulation and placement
(paper §3.1/Fig 3: pinned executors reach ~1.45x OS-scheduled FLOPS, and
concurrent ops interfere).

Layering: ``topology`` -> ``pinning`` -> ``colocate`` -> ``model``; the
model closes the loop into :mod:`repro.core.simulate` (duration adjustment)
and :mod:`repro.core.policies` (the ``cpf-contention`` placement policy).
"""
from .colocate import (
    InterferenceMatrix,
    Workload,
    default_workloads,
    measure_interference,
)
from .model import (
    ContentionAwareCPF,
    ContentionModel,
    classify,
    install_contention_policy,
)
from .pinning import (
    NO_AFFINITY_ENV,
    AppliedPinning,
    PinningPlan,
    affinity_supported,
    pin_current_thread,
    pin_pool,
    plan_pinning,
)
from .topology import (
    CpuTopology,
    LogicalCpu,
    detect_topology,
    disjoint_core_sets,
    synthetic_topology,
)

__all__ = [
    "AppliedPinning",
    "ContentionAwareCPF",
    "ContentionModel",
    "CpuTopology",
    "InterferenceMatrix",
    "LogicalCpu",
    "NO_AFFINITY_ENV",
    "PinningPlan",
    "Workload",
    "affinity_supported",
    "classify",
    "default_workloads",
    "detect_topology",
    "disjoint_core_sets",
    "install_contention_policy",
    "measure_interference",
    "pin_current_thread",
    "pin_pool",
    "plan_pinning",
    "synthetic_topology",
]
