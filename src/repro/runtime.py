"""``repro.Runtime`` — one process-wide runtime that owns executors,
calibration, and admission for every graph.

The paper's core claim is that concurrent operations must share a manycore
CPU *without interference*.  Before this module, every entry point — a
pool-less :class:`~repro.api.Executable`, the serve engine, the trainer,
each bench script — allocated its **own** executor threads and re-measured
its own calibration, so two executables in one process oversubscribed the
cores and repeated identical measurements.  A :class:`Runtime` consolidates
all of that per-process state:

* **One** :class:`~repro.core.engine.ExecutorPool` sized to the machine.
  Every graph run in the process executes on these threads; nothing else
  spawns executors.
* A persistent :class:`CalibrationStore` — measured per-op costs keyed by a
  structural :func:`graph_signature` — with JSON save/load, so
  ``Executable.calibrate`` survives process restarts and is shared across
  executables of the same graph.
* The per-(graph, width) ``StaticHostPlan`` / ``HostScheduler`` caches, so
  two executables over one graph freeze placements once.
* An **admission layer**: each run asks for an :class:`ExecutorLease` — a
  *disjoint subset* of the pool's executors sized by the run's calibrated
  CPF width.  CPF scheduling happens inside the lease; leases queue (FIFO,
  no barging) rather than oversubscribe, so a decode step and a train step
  share the pool with bounded interference instead of fighting for threads.

``repro.compile(...)`` is sugar over ``default_runtime().compile(...)``;
components that want an isolated pool (tests, benches) construct their own
``Runtime`` and pass it around.
"""
from __future__ import annotations

import hashlib
import json
import os
import random
import threading
import time
import weakref
from collections import deque
from typing import Any, Callable, Mapping

from repro.core.cost_model import KNL7250, HardwareModel
from repro.core.engine import DeadlineExceeded, ExecutorPool
from repro.core.graph import Graph

__all__ = [
    "AdmissionRejected",
    "CalibrationStore",
    "DeadlineExceeded",
    "ExecutorLease",
    "Runtime",
    "default_runtime",
    "graph_signature",
    "set_default_runtime",
]


class AdmissionRejected(RuntimeError):
    """Admission shed this request instead of queueing it (429-style).

    Raised by :meth:`Runtime.lease` when the estimated queue wait exceeds
    the caller's latency budget: under overload it is better to reject
    *now* with a :attr:`retry_after` hint than to accept work whose latency
    is already blown.  ``retry_after`` is jittered (seeded, deterministic
    per runtime) so a thundering herd of rejected callers does not retry in
    lock-step."""

    def __init__(self, message: str, retry_after: float):
        super().__init__(message)
        self.retry_after = retry_after


def graph_signature(graph: Graph, variant: str = "") -> str:
    """Stable structural hash of a graph: node names, kinds, deps, and the
    roofline stats that drive the cost model.

    Two captures of the same function at the same shapes produce the same
    signature, so a :class:`CalibrationStore` entry written by one process
    seeds the schedule of the next.  ``variant`` salts the key for
    executions whose per-op cost differs at identical structure (e.g.
    ``jit_nodes=True`` wraps every fn in ``jax.jit`` — dispatch cost, not
    flops, dominates tiny ops, so jitted and eager tables must not mix).
    """
    h = hashlib.sha256()
    h.update(variant.encode())
    for name in graph.names:
        nd = graph[name]
        h.update(
            f"{name}|{nd.kind}|{nd.flops:.6g}|{nd.bytes_in:.6g}|"
            f"{nd.bytes_out:.6g}|{','.join(nd.deps)}\n".encode()
        )
    return h.hexdigest()


class CalibrationStore:
    """Measured op-cost tables and searched-schedule winners, keyed by
    :func:`graph_signature`.

    Each signature owns two sections (JSON ``format: 3``):

    * ``costs`` — ``{op_name: seconds}`` from
      :func:`~repro.core.profiler.measure_op_costs`;
    * ``schedule`` — searched-winner records from
      :func:`~repro.core.search.search_schedule`, keyed by a *config key*
      (width × team × cost fingerprint, see ``api._cost_fp``): the
      ``{policy, seed, makespan_sim, runner_up_gap}`` dict that replays the
      winning schedule deterministically, so the simulator search runs once
      per (graph, executor config, cost model) across processes.

    Format 3 adds one machine-wide top-level section, ``interference`` —
    the measured contention model from :mod:`repro.hwperf`
    (``ContentionModel.to_dict()``: per-op-class solo times and pairwise
    co-run slowdowns).  It is machine state, not graph state, so it lives
    beside ``entries``, not inside them.

    Format-1 files (bare ``{sig: {op: seconds}}`` entries) and format-2
    files (no ``interference`` section) still load — they migrate in
    memory (costs and schedules are never lost to a format bump; the
    interference section starts empty) and are rewritten as format 3 on
    the next save.  Unknown *future* formats raise a :class:`ValueError`
    naming the file rather than guessing.

    With a ``path`` the store loads existing entries at construction and
    autosaves (atomic tmp+rename) on every :meth:`put` /
    :meth:`put_schedule`.  Thread-safe: a serve engine calibrating and a
    trainer reading may race.
    """

    _FORMAT = 3

    def __init__(self, path: str | None = None):
        self.path = path
        self._entries: dict[str, dict[str, float]] = {}
        # signature -> config_key -> winner record (JSON-able dict)
        self._schedules: dict[str, dict[str, dict]] = {}
        # machine-wide measured contention model (ContentionModel.to_dict());
        # empty dict = "measured nothing yet", kept distinct from format-2
        # files that predate the section (also loaded as empty)
        self._interference: dict = {}
        self._lock = threading.Lock()
        self._io_lock = threading.Lock()   # serializes concurrent save()s
        if path is not None and os.path.exists(path):
            self.load(path)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, signature: str) -> bool:
        return signature in self._entries

    def get(self, signature: str) -> dict[str, float] | None:
        with self._lock:
            costs = self._entries.get(signature)
            return dict(costs) if costs is not None else None

    def put(self, signature: str, costs: Mapping[str, float]) -> None:
        with self._lock:
            self._entries[signature] = {k: float(v) for k, v in costs.items()}
        if self.path is not None:
            self.save(self.path)

    def get_interference(self) -> dict | None:
        """The machine-wide measured contention section
        (``ContentionModel.to_dict()`` shape), or ``None`` when nothing has
        been measured (including stores migrated from formats 1/2)."""
        with self._lock:
            return dict(self._interference) if self._interference else None

    def put_interference(self, section: Mapping) -> None:
        """Persist a measured contention model (the whole section replaces
        the old one — coefficients from two different measurement runs must
        not interleave)."""
        with self._lock:
            self._interference = dict(section)
        if self.path is not None:
            self.save(self.path)

    def get_schedule(self, signature: str, config_key: str) -> dict | None:
        """The persisted search winner for (graph signature, config key),
        or ``None`` when that search has not run yet."""
        with self._lock:
            rec = self._schedules.get(signature, {}).get(config_key)
            return dict(rec) if rec is not None else None

    def put_schedule(self, signature: str, config_key: str, record: Mapping) -> None:
        """Persist a search winner (callers verify via ``repro.checks``
        *before* putting — the store holds only vetted schedules)."""
        with self._lock:
            self._schedules.setdefault(signature, {})[config_key] = dict(record)
        if self.path is not None:
            self.save(self.path)

    def save(self, path: str | None = None) -> str:
        path = path if path is not None else self.path
        if path is None:
            raise ValueError("CalibrationStore has no path; pass save(path)")
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        # pid + thread id: concurrent savers (two executables calibrating
        # on one runtime) must never truncate each other's tmp file
        tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
        # snapshot *inside* the io lock: replace order then matches snapshot
        # order, so the file on disk is always the newest state a saver saw
        # (snapshotting outside would let a stale snapshot win the last
        # replace under concurrent put()s)
        with self._io_lock:
            with self._lock:
                sigs = set(self._entries) | set(self._schedules)
                entries = {
                    sig: {
                        "costs": self._entries.get(sig, {}),
                        "schedule": self._schedules.get(sig, {}),
                    }
                    for sig in sigs
                }
                payload = {
                    "format": self._FORMAT,
                    "entries": entries,
                    "interference": dict(self._interference),
                }
                blob = json.dumps(payload, indent=1, sort_keys=True)
            with open(tmp, "w") as f:
                f.write(blob)
            os.replace(tmp, path)
        return path

    def load(self, path: str | None = None) -> int:
        """Merge entries from ``path`` (disk wins); returns the entry count.

        Accepts the current format 3 and migrates format-1 (bare cost
        tables) and format-2 (no interference section) files — measured
        seconds and searched schedules are never lost to a format bump; any
        other format raises a :class:`ValueError` naming the file.
        """
        path = path if path is not None else self.path
        if path is None:
            raise ValueError("CalibrationStore has no path; pass load(path)")
        with open(path) as f:
            payload = json.load(f)
        fmt = payload.get("format")
        costs_in: dict[str, dict[str, float]] = {}
        scheds_in: dict[str, dict[str, dict]] = {}
        interference_in: dict = {}
        if fmt == 1:
            # format 1: entries are bare {sig: {op: seconds}} cost tables
            for sig, costs in payload["entries"].items():
                costs_in[sig] = {k: float(v) for k, v in costs.items()}
        elif fmt in (2, self._FORMAT):
            # format 2 is format 3 minus the interference section: one
            # parse, sections default empty
            for sig, section in payload["entries"].items():
                costs_in[sig] = {
                    k: float(v) for k, v in section.get("costs", {}).items()
                }
                sch = section.get("schedule", {})
                if sch:
                    scheds_in[sig] = {ck: dict(rec) for ck, rec in sch.items()}
            interference_in = dict(payload.get("interference", {}))
        else:
            raise ValueError(
                f"calibration store {path!r} has format {fmt!r}; this build "
                f"reads formats 1, 2 and {self._FORMAT}"
            )
        with self._lock:
            # a format-2 sig may be schedule-only: an empty costs section
            # must not shadow (or fabricate) a measured table
            self._entries.update({s: c for s, c in costs_in.items() if c})
            for sig, by_cfg in scheds_in.items():
                self._schedules.setdefault(sig, {}).update(by_cfg)
            if interference_in:
                self._interference = interference_in
            return len(self._entries)


class _Admission:
    """FIFO executor leasing over one pool's executor ids.

    ``acquire(width)`` blocks until this request is at the **head** of the
    queue *and* ``width`` executors are free — strict FIFO, so a wide
    request is never starved by narrow ones barging past it, and total
    leased executors never exceed the pool (no oversubscription, the whole
    point of the admission layer).

    Robustness state on top of the free set:

    * **quarantine** — executors whose threads are still inside an op a
      deadline-aborted run abandoned.  They are *not* free (handing one out
      would give the next run a busy thread) and *not* leased; they heal
      automatically: every acquire/estimate probes the pool
      (:meth:`ExecutorPool.current_tasks`) and returns idle-again
      quarantined executors to the free set.
    * **leak accounting** — ``release`` of an id that is not out on a lease
      (double release, corrupt release) is counted and ignored instead of
      corrupting the free set; ids that never come back (a lease that lost
      them) are recovered by :meth:`reclaim` against the set of live
      leases, after a grant grace period.
    * **load estimate** — an EWMA of lease hold times turns queue depth
      into an expected wait, which :meth:`Runtime.lease` compares against a
      latency budget to shed (429-style) instead of queueing.
    """

    def __init__(self, n_executors: int, *, seed: int = 0,
                 reclaim_grace: float = 0.25):
        self.n_executors = n_executors
        self._free: set[int] = set(range(n_executors))
        self._cond = threading.Condition()
        self._queue: deque[object] = deque()
        self._quarantined: set[int] = set()
        self._granted_at: dict[int, float] = {}
        self._probe: Callable[[], list] | None = None   # pool.current_tasks
        self._hold_ewma = 0.0
        self._rng = random.Random(seed)                  # retry-after jitter
        self.reclaim_grace = reclaim_grace
        self.n_bad_releases = 0
        self.n_leaks_reclaimed = 0
        self.n_shed = 0

    def attach_probe(self, probe: Callable[[], list]) -> None:
        """Wire the pool's ``current_tasks`` snapshot in (set once, at pool
        creation): quarantined executors heal by observing it."""
        self._probe = probe

    @property
    def n_free(self) -> int:
        with self._cond:
            return len(self._free)

    @property
    def n_waiting(self) -> int:
        with self._cond:
            return len(self._queue)

    @property
    def n_quarantined(self) -> int:
        with self._cond:
            return len(self._quarantined)

    def _heal_locked(self) -> None:
        """Return quarantined executors whose hung op has finally finished
        (their thread is idle again) to the free set.  Lock held."""
        if not self._quarantined or self._probe is None:
            return
        cur = self._probe()
        healed = {e for e in self._quarantined if cur[e] is None}
        if healed:
            self._quarantined.difference_update(healed)
            self._free.update(healed)
            self._cond.notify_all()

    def estimated_wait(self, width: int) -> float:
        """Expected queue wait for a ``width`` lease right now: zero when it
        would be granted immediately, else queue depth times the EWMA of
        recent lease hold times.  Deliberately coarse — a shed decision
        needs the order of magnitude, not the schedule."""
        with self._cond:
            self._heal_locked()
            if not self._queue and len(self._free) >= width:
                return 0.0
            return (len(self._queue) + 1) * max(self._hold_ewma, 1e-3)

    def retry_after(self, estimate: float) -> float:
        """Jittered (seeded — deterministic per admission instance) backoff
        hint for a shed caller: 0.5x-1.5x the current wait estimate."""
        with self._cond:
            self.n_shed += 1
            return max(estimate, 1e-3) * (0.5 + self._rng.random())

    def acquire(
        self,
        width: int,
        timeout: float | None = None,
        prefer: tuple[int, ...] = (),
        deadline: float | None = None,
    ) -> tuple[int, ...]:
        if width < 1:
            raise ValueError(f"need width >= 1, got {width}")
        width = min(width, self.n_executors)
        if deadline is not None:
            remaining = deadline - time.monotonic()
            timeout = remaining if timeout is None else min(timeout, remaining)
        ticket = object()
        with self._cond:
            self._heal_locked()
            if (width > self.n_executors - len(self._quarantined)
                    and timeout is None):
                # unsatisfiable until quarantined executors heal: without a
                # timeout this wait could be forever — fail loudly instead
                raise RuntimeError(
                    f"lease of width {width} unsatisfiable: "
                    f"{len(self._quarantined)} of {self.n_executors} "
                    "executors quarantined (threads stuck in abandoned ops)"
                )
            self._queue.append(ticket)

            def ready() -> bool:
                self._heal_locked()
                return self._queue[0] is ticket and len(self._free) >= width

            try:
                ok = self._cond.wait_for(ready, timeout=timeout)
            except BaseException:
                # e.g. KeyboardInterrupt mid-wait: an orphaned ticket at the
                # queue head would wedge strict-FIFO admission forever
                self._queue.remove(ticket)
                self._cond.notify_all()
                raise
            if not ok:
                self._queue.remove(ticket)
                self._cond.notify_all()
                raise TimeoutError(
                    f"no lease of width {width} within {timeout}s "
                    f"({len(self._free)} free, {len(self._queue)} waiting, "
                    f"{len(self._quarantined)} quarantined)"
                )
            self._queue.popleft()
            # sticky leases: grant the caller's previous executors when they
            # are free (warm threads / cache affinity — a replayed graph
            # should not migrate between executors run to run), then fill
            # from the free set
            picked = [e for e in prefer if e in self._free][:width]
            if len(picked) < width:
                rest = sorted(self._free.difference(picked))
                picked.extend(rest[: width - len(picked)])
            ids = tuple(sorted(picked))
            self._free.difference_update(ids)
            now = time.monotonic()
            for e in ids:
                self._granted_at[e] = now
            # the next waiter may already be satisfiable (narrower request)
            self._cond.notify_all()
            return ids

    def release(self, ids: tuple[int, ...], held: float | None = None) -> None:
        with self._cond:
            # a release of ids that are not out on a lease (double release,
            # corrupt release) is counted and *ignored* — updating the free
            # set from a bad release would let leased executors be granted
            # twice
            good = [e for e in ids
                    if e not in self._free and e not in self._quarantined]
            self.n_bad_releases += len(ids) - len(good)
            self._free.update(good)
            for e in good:
                self._granted_at.pop(e, None)
            if held is not None and good:
                a = 0.2
                self._hold_ewma = (held if self._hold_ewma == 0.0
                                   else (1 - a) * self._hold_ewma + a * held)
            self._cond.notify_all()

    def quarantine(self, ids: tuple[int, ...]) -> None:
        """Move leased executors whose threads are stuck inside an abandoned
        op out of circulation; they heal via :meth:`_heal_locked` when the
        op eventually returns."""
        with self._cond:
            for e in ids:
                if e not in self._free:
                    self._quarantined.add(e)
                    self._granted_at.pop(e, None)
            self._cond.notify_all()

    def reclaim(self, expected_live: set[int]) -> int:
        """Recover leaked executor ids: leased-out ids no live lease claims
        (a corrupt release dropped them, or a lease object was lost).  Only
        ids granted more than ``reclaim_grace`` seconds ago are eligible, so
        a grant racing its lease-object registration is never torn away."""
        now = time.monotonic()
        with self._cond:
            leased = (set(range(self.n_executors)) - self._free
                      - self._quarantined)
            leaked = {
                e for e in leased - expected_live
                if now - self._granted_at.get(e, now) > self.reclaim_grace
            }
            if leaked:
                self._free.update(leaked)
                for e in leaked:
                    self._granted_at.pop(e, None)
                self.n_leaks_reclaimed += len(leaked)
                self._cond.notify_all()
            return len(leaked)


class ExecutorLease:
    """A disjoint slice of a :class:`Runtime`'s executor pool.

    Quacks like an :class:`~repro.core.engine.ExecutorPool` of
    ``len(executor_ids)`` executors — ``submit`` / ``submit_segments`` /
    ``qsize`` remap local executor indices onto the leased global ids — so
    both host runtimes (the dynamic :class:`HostScheduler` and compiled
    :class:`StaticHostPlan` segments) run *inside* the lease unchanged.
    Segment atomicity is inherited from the underlying pool's lock, so a
    leased plan still cannot cross-deadlock with anything else on the pool.

    ``close()`` aliases :meth:`release` so a lease can stand in anywhere a
    pool is owned; releasing twice is a no-op.
    """

    def __init__(self, runtime: "Runtime", executor_ids: tuple[int, ...]):
        self._runtime = runtime
        self._pool = runtime.pool
        self.executor_ids = executor_ids
        self.n_executors = len(executor_ids)
        self._granted = time.monotonic()
        self._released = False

    def submit(self, ex: int, name: str, task: Callable[[], Any],
               reply: Any, t_origin: float) -> None:
        self._pool.submit(self.executor_ids[ex], name, task, reply, t_origin)

    def submit_segments(self, items: list, reply: Any, t_origin: float) -> None:
        self._pool.submit_segments(
            [(self.executor_ids[e], name, task) for e, name, task in items],
            reply, t_origin,
        )

    def qsize(self, ex: int) -> int:
        return self._pool.qsize(self.executor_ids[ex])

    def current_tasks(self) -> list[tuple[str, float] | None]:
        """What each *leased* executor is running (local index order)."""
        cur = self._pool.current_tasks()
        return [cur[g] for g in self.executor_ids]

    @property
    def outstanding_ids(self) -> tuple[int, ...]:
        """Global executor ids this lease still owes back; the currency
        :meth:`Runtime.reclaim_leaks` reconciles against."""
        return () if self._released else self.executor_ids

    def release(self, *, quarantine_busy: bool = False) -> None:
        """Give the executors back.  ``quarantine_busy=True`` is the
        deadline-abort path: leased executors whose threads are *still
        inside an op* go to admission quarantine (they would hand the next
        run a busy thread) and only the idle ones return to the free set.
        Releasing twice is a no-op."""
        if self._released:
            return
        self._released = True
        held = time.monotonic() - self._granted
        adm = self._runtime._admission
        if quarantine_busy:
            cur = self._pool.current_tasks()
            busy = tuple(g for g in self.executor_ids if cur[g] is not None)
            if busy:
                adm.quarantine(busy)
            idle = tuple(g for g in self.executor_ids if g not in busy)
            if idle:
                adm.release(idle, held=held)
            return
        adm.release(self.executor_ids, held=held)

    # pool-interface compatibility: components that "own" their pool call
    # close(); for a lease that means giving the executors back
    close = release

    def __enter__(self) -> "ExecutorLease":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"ExecutorLease(ids={self.executor_ids}, "
                f"released={self._released})")


def _machine_workers() -> int:
    # at least 2 so every machine exercises real multi-executor placement
    return max(2, os.cpu_count() or 2)


class Runtime:
    """Process-wide session owning executors, calibration, and admission.

    Parameters
    ----------
    n_workers:
        Executor-thread count of the single shared pool (default: the
        machine's core count, floor 2).  This is the hard bound the
        admission layer enforces: total leased executors never exceed it.
    hw:
        Default :class:`HardwareModel` for ``compile`` (cost model +
        config-search worker count).
    calibration_path:
        JSON file backing the :class:`CalibrationStore`.  Loaded at
        construction when it exists; autosaved on every ``calibrate()``.
    pinning:
        Executor-thread core pinning (paper §3.1): ``"off"`` (default —
        OS-scheduled, the pre-hwperf behavior), ``"auto"`` (pin when the
        platform supports affinity, silently run unpinned otherwise), or
        ``"on"`` (pin, with a single warning where unsupported).  Applied
        when the pool is created; :attr:`pinning_applied` records what
        actually happened.

    The executor pool is created lazily on first host execution, so
    sim-only runtimes (the dry-run sweep) never spawn threads.  When the
    calibration store carries a measured ``interference`` section, the
    ``cpf-contention`` placement policy (:mod:`repro.hwperf.model`) is
    installed in the policy registry at construction, so
    ``policy="cpf-contention"`` resolves for every executable on this
    runtime.
    """

    PINNING_MODES = ("off", "auto", "on")

    def __init__(
        self,
        n_workers: int | None = None,
        *,
        hw: HardwareModel = KNL7250,
        reserved_workers: int = 2,
        calibration_path: str | None = None,
        shed_after_s: float | None = None,
        seed: int = 0,
        pinning: str = "off",
    ):
        self.n_workers = n_workers if n_workers is not None else _machine_workers()
        if self.n_workers < 1:
            raise ValueError(f"need >= 1 worker, got {self.n_workers}")
        if pinning not in self.PINNING_MODES:
            raise ValueError(
                f"pinning must be one of {self.PINNING_MODES}, got {pinning!r}")
        self.hw = hw
        self.reserved_workers = reserved_workers
        self.pinning = pinning
        self.pinning_applied = None   # hwperf.AppliedPinning once pool pins
        self._contention_model = None
        self.calibration = CalibrationStore(calibration_path)
        if self.calibration.get_interference() is not None:
            # measured contention on disk: make "cpf-contention" resolvable
            self._install_contention()
        # default latency budget for lease admission: when the estimated
        # queue wait exceeds it, lease() sheds (AdmissionRejected with a
        # jittered retry_after) instead of queueing.  None = never shed.
        self.shed_after_s = shed_after_s
        self._pool: ExecutorPool | None = None
        self._pool_lock = threading.Lock()
        self._admission = _Admission(self.n_workers, seed=seed)
        self._live_leases: "weakref.WeakSet[ExecutorLease]" = weakref.WeakSet()
        self._cache_lock = threading.Lock()
        self._closed = False

    # -- executors + admission ----------------------------------------------
    @property
    def pool(self) -> ExecutorPool:
        """The one shared pool (created on first use)."""
        if self._pool is None:
            with self._pool_lock:
                if self._pool is None:
                    if self._closed:
                        raise RuntimeError("Runtime is closed")
                    pool = ExecutorPool(self.n_workers)
                    # quarantined executors heal by observing the pool's
                    # per-executor busy state
                    self._admission.attach_probe(pool.current_tasks)
                    self._pool = pool
                    if self.pinning != "off":
                        self._apply_pinning(pool)
        return self._pool

    def _apply_pinning(self, pool: ExecutorPool) -> None:
        """Pin the pool's executor threads per :attr:`pinning` (lazy import:
        sim-only runtimes never touch hwperf)."""
        from repro.hwperf import pinning as hwpin

        if self.pinning == "auto" and not hwpin.affinity_supported():
            return   # auto = best-effort, silent where unsupported
        plan = hwpin.plan_pinning(self.n_workers)
        self.pinning_applied = hwpin.pin_pool(pool, plan)

    def set_pinning(self, mode: str) -> None:
        """Change the pinning mode; applies immediately when the pool is
        already live (``api.compile(pinning=...)`` threads through here)."""
        if mode not in self.PINNING_MODES:
            raise ValueError(
                f"pinning must be one of {self.PINNING_MODES}, got {mode!r}")
        self.pinning = mode
        if self._pool is not None and mode != "off":
            self._apply_pinning(self._pool)

    # -- measured contention -------------------------------------------------
    def contention_model(self):
        """The measured :class:`~repro.hwperf.model.ContentionModel` from
        the calibration store's ``interference`` section, or ``None`` when
        nothing has been measured.  Cached; invalidated by
        :meth:`set_contention_model`."""
        if self._contention_model is None:
            section = self.calibration.get_interference()
            if section is not None:
                from repro.hwperf.model import ContentionModel

                self._contention_model = ContentionModel.from_dict(section)
        return self._contention_model

    def set_contention_model(self, model) -> None:
        """Adopt a freshly measured contention model: persist it to the
        calibration store and (re)install the ``cpf-contention`` placement
        policy over it."""
        self.calibration.put_interference(model.to_dict())
        self._contention_model = model
        self._install_contention()

    def _install_contention(self) -> None:
        from repro.hwperf.model import install_contention_policy

        model = self.contention_model()
        if model is not None:
            install_contention_policy(model)

    def lease(
        self,
        width: int,
        timeout: float | None = None,
        prefer: tuple[int, ...] = (),
        *,
        deadline: float | None = None,
        shed_after_s: float | None = None,
    ) -> ExecutorLease:
        """Lease ``width`` executors (clamped to ``n_workers``); blocks in
        FIFO order until that many are free.  ``prefer`` are the caller's
        previous executor ids — granted first when free, so a replayed
        graph keeps warm executor threads instead of migrating.  Use as a
        context manager or call ``release()``; every host run through this
        runtime holds exactly one lease for its duration.

        ``deadline`` (absolute, ``time.monotonic``) caps the queue wait on
        top of ``timeout``.  ``shed_after_s`` (defaulting to the runtime's
        ``shed_after_s``) is the admission latency budget: when the
        estimated queue wait exceeds it, raise :class:`AdmissionRejected`
        immediately — with a jittered ``retry_after`` — instead of joining
        a queue whose latency is already blown."""
        if self._closed:
            raise RuntimeError("Runtime is closed")
        _ = self.pool  # materialize before handing out ids
        budget = shed_after_s if shed_after_s is not None else self.shed_after_s
        if budget is not None:
            est = self._admission.estimated_wait(width)
            if est > budget:
                raise AdmissionRejected(
                    f"admission queue wait ~{est:.3f}s exceeds latency "
                    f"budget {budget:.3f}s ({self._admission.n_waiting} "
                    "waiting) — shed",
                    retry_after=self._admission.retry_after(est),
                )
        if self._admission.n_free < width:
            # under pressure, reconcile first: a corrupt or lost release
            # must shrink capacity only until detected, not forever
            self.reclaim_leaks()
        ids = self._admission.acquire(width, timeout=timeout, prefer=prefer,
                                      deadline=deadline)
        lease = ExecutorLease(self, ids)
        self._live_leases.add(lease)
        return lease

    def reclaim_leaks(self) -> int:
        """Recover executor ids leased out but claimed by no live lease
        (corrupt release, dropped lease object).  Returns the count."""
        expected: set[int] = set()
        for lease in list(self._live_leases):
            expected.update(lease.outstanding_ids)
        return self._admission.reclaim(expected)

    @property
    def leased_executors(self) -> int:
        """Executors currently out on leases (observability/tests)."""
        return (self.n_workers - self._admission.n_free
                - self._admission.n_quarantined)

    def health(self) -> dict:
        """Liveness counters a supervisor (``repro.fleet``) samples into
        heartbeats: quarantine or leak growth marks a degrading worker."""
        adm = self._admission
        return {
            "n_workers": self.n_workers,
            "free": adm.n_free,
            "waiting": adm.n_waiting,
            "quarantined": adm.n_quarantined,
            "bad_releases": adm.n_bad_releases,
            "leaks_reclaimed": adm.n_leaks_reclaimed,
            "shed": adm.n_shed,
            "stuck_close": len(self._pool.stuck_executors) if self._pool else 0,
        }

    # -- planning caches -----------------------------------------------------
    def cached(self, graph: Graph, key: tuple, build: Callable[[], Any]) -> Any:
        """Per-graph artifact cache (plans, host schedulers) the runtime
        mediates.

        ``key`` must encode everything the artifact depends on besides the
        graph itself (width, team size, policy, cost fingerprint).  The
        store rides on the graph object (cached plans/schedulers hold a
        strong reference to their graph, so any runtime-side map would pin
        the graph alive forever — this way a dropped graph frees its
        artifacts with it, and two executables over one graph share).
        Entries for a graph are dropped wholesale by :meth:`invalidate`
        (an executable re-profiled with new measured costs).
        """
        with self._cache_lock:
            per_graph = graph.__dict__.setdefault("_graphi_artifacts", {})
            hit = per_graph.get(key)
        if hit is not None:
            return hit
        made = build()
        with self._cache_lock:
            return per_graph.setdefault(key, made)

    def invalidate(self, graph: Graph) -> None:
        with self._cache_lock:
            graph.__dict__.pop("_graphi_artifacts", None)

    # -- compile -------------------------------------------------------------
    def compile(self, target: Any, *specs: Any, **kw: Any):
        """``repro.compile`` bound to this runtime: the returned
        :class:`~repro.api.Executable` executes on leases from this
        runtime's pool, seeds its cost model from the calibration store,
        and writes ``calibrate()`` results back to it."""
        from repro import api

        kw.setdefault("hw", self.hw)
        kw.setdefault("reserved_workers", self.reserved_workers)
        return api.compile(target, *specs, runtime=self, **kw)

    # -- lifecycle -----------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Close the pool and persist the calibration store (idempotent).
        In-flight leases finish their queued work (pool close drains
        FIFO-before-sentinel); new leases and compiles raise."""
        if self._closed:
            return
        self._closed = True
        # persist calibration *before* joining executor threads: a stuck
        # executor must not cost the measured tables too
        if self.calibration.path is not None:
            self.calibration.save()
        if self._pool is not None:
            self._pool.close()

    def __enter__(self) -> "Runtime":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def describe(self) -> str:
        pin = self.pinning
        if self.pinning_applied is not None:
            pin += ":pinned" if self.pinning_applied.pinned else ":no-op"
        return (
            f"Runtime(n_workers={self.n_workers}, hw={self.hw.name}, "
            f"pool={'live' if self._pool is not None else 'lazy'}, "
            f"leased={self.leased_executors}, pinning={pin}, "
            f"calibrations={len(self.calibration)})"
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return self.describe()


# -- the process-wide default ------------------------------------------------
_default: Runtime | None = None
_default_lock = threading.Lock()


def default_runtime() -> Runtime:
    """The process-wide :class:`Runtime` behind bare ``repro.compile``.

    Created on first use (machine-sized pool, no calibration path); if the
    current default was closed, a fresh one replaces it.
    """
    global _default
    with _default_lock:
        if _default is None or _default.closed:
            _default = Runtime()
        return _default


def set_default_runtime(rt: Runtime | None) -> Runtime | None:
    """Swap the process default (tests, or an app that wants one configured
    runtime everywhere); returns the previous one (not closed)."""
    global _default
    with _default_lock:
        prev, _default = _default, rt
        return prev
