"""``repro.Runtime`` — one process-wide runtime that owns executors,
calibration, and admission for every graph.

The paper's core claim is that concurrent operations must share a manycore
CPU *without interference*.  Before this module, every entry point — a
pool-less :class:`~repro.api.Executable`, the serve engine, the trainer,
each bench script — allocated its **own** executor threads and re-measured
its own calibration, so two executables in one process oversubscribed the
cores and repeated identical measurements.  A :class:`Runtime` consolidates
all of that per-process state:

* **One** :class:`~repro.core.engine.ExecutorPool` sized to the machine.
  Every graph run in the process executes on these threads; nothing else
  spawns executors.
* A persistent :class:`CalibrationStore` — measured per-op costs keyed by a
  structural :func:`graph_signature` — with JSON save/load, so
  ``Executable.calibrate`` survives process restarts and is shared across
  executables of the same graph.
* The per-(graph, width) ``StaticHostPlan`` / ``HostScheduler`` caches, so
  two executables over one graph freeze placements once.
* An **admission layer**: each run asks for an :class:`ExecutorLease` — a
  *disjoint subset* of the pool's executors sized by the run's calibrated
  CPF width.  CPF scheduling happens inside the lease; leases queue (FIFO,
  no barging) rather than oversubscribe, so a decode step and a train step
  share the pool with bounded interference instead of fighting for threads.

``repro.compile(...)`` is sugar over ``default_runtime().compile(...)``;
components that want an isolated pool (tests, benches) construct their own
``Runtime`` and pass it around.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from collections import deque
from typing import Any, Callable, Mapping

from repro.core.cost_model import KNL7250, HardwareModel
from repro.core.engine import ExecutorPool
from repro.core.graph import Graph

__all__ = [
    "CalibrationStore",
    "ExecutorLease",
    "Runtime",
    "default_runtime",
    "graph_signature",
    "set_default_runtime",
]


def graph_signature(graph: Graph, variant: str = "") -> str:
    """Stable structural hash of a graph: node names, kinds, deps, and the
    roofline stats that drive the cost model.

    Two captures of the same function at the same shapes produce the same
    signature, so a :class:`CalibrationStore` entry written by one process
    seeds the schedule of the next.  ``variant`` salts the key for
    executions whose per-op cost differs at identical structure (e.g.
    ``jit_nodes=True`` wraps every fn in ``jax.jit`` — dispatch cost, not
    flops, dominates tiny ops, so jitted and eager tables must not mix).
    """
    h = hashlib.sha256()
    h.update(variant.encode())
    for name in graph.names:
        nd = graph[name]
        h.update(
            f"{name}|{nd.kind}|{nd.flops:.6g}|{nd.bytes_in:.6g}|"
            f"{nd.bytes_out:.6g}|{','.join(nd.deps)}\n".encode()
        )
    return h.hexdigest()


class CalibrationStore:
    """Measured op-cost tables and searched-schedule winners, keyed by
    :func:`graph_signature`.

    Each signature owns two sections (JSON ``format: 2``):

    * ``costs`` — ``{op_name: seconds}`` from
      :func:`~repro.core.profiler.measure_op_costs`;
    * ``schedule`` — searched-winner records from
      :func:`~repro.core.search.search_schedule`, keyed by a *config key*
      (width × team × cost fingerprint, see ``api._cost_fp``): the
      ``{policy, seed, makespan_sim, runner_up_gap}`` dict that replays the
      winning schedule deterministically, so the simulator search runs once
      per (graph, executor config, cost model) across processes.

    Format-1 files (bare ``{sig: {op: seconds}}`` entries) still load —
    they migrate to cost-only sections in memory and are rewritten as
    format 2 on the next save.  Unknown *future* formats raise a
    :class:`ValueError` naming the file rather than guessing.

    With a ``path`` the store loads existing entries at construction and
    autosaves (atomic tmp+rename) on every :meth:`put` /
    :meth:`put_schedule`.  Thread-safe: a serve engine calibrating and a
    trainer reading may race.
    """

    _FORMAT = 2

    def __init__(self, path: str | None = None):
        self.path = path
        self._entries: dict[str, dict[str, float]] = {}
        # signature -> config_key -> winner record (JSON-able dict)
        self._schedules: dict[str, dict[str, dict]] = {}
        self._lock = threading.Lock()
        self._io_lock = threading.Lock()   # serializes concurrent save()s
        if path is not None and os.path.exists(path):
            self.load(path)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, signature: str) -> bool:
        return signature in self._entries

    def get(self, signature: str) -> dict[str, float] | None:
        with self._lock:
            costs = self._entries.get(signature)
            return dict(costs) if costs is not None else None

    def put(self, signature: str, costs: Mapping[str, float]) -> None:
        with self._lock:
            self._entries[signature] = {k: float(v) for k, v in costs.items()}
        if self.path is not None:
            self.save(self.path)

    def get_schedule(self, signature: str, config_key: str) -> dict | None:
        """The persisted search winner for (graph signature, config key),
        or ``None`` when that search has not run yet."""
        with self._lock:
            rec = self._schedules.get(signature, {}).get(config_key)
            return dict(rec) if rec is not None else None

    def put_schedule(self, signature: str, config_key: str, record: Mapping) -> None:
        """Persist a search winner (callers verify via ``repro.checks``
        *before* putting — the store holds only vetted schedules)."""
        with self._lock:
            self._schedules.setdefault(signature, {})[config_key] = dict(record)
        if self.path is not None:
            self.save(self.path)

    def save(self, path: str | None = None) -> str:
        path = path if path is not None else self.path
        if path is None:
            raise ValueError("CalibrationStore has no path; pass save(path)")
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        # pid + thread id: concurrent savers (two executables calibrating
        # on one runtime) must never truncate each other's tmp file
        tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
        # snapshot *inside* the io lock: replace order then matches snapshot
        # order, so the file on disk is always the newest state a saver saw
        # (snapshotting outside would let a stale snapshot win the last
        # replace under concurrent put()s)
        with self._io_lock:
            with self._lock:
                sigs = set(self._entries) | set(self._schedules)
                entries = {
                    sig: {
                        "costs": self._entries.get(sig, {}),
                        "schedule": self._schedules.get(sig, {}),
                    }
                    for sig in sigs
                }
                payload = {"format": self._FORMAT, "entries": entries}
                blob = json.dumps(payload, indent=1, sort_keys=True)
            with open(tmp, "w") as f:
                f.write(blob)
            os.replace(tmp, path)
        return path

    def load(self, path: str | None = None) -> int:
        """Merge entries from ``path`` (disk wins); returns the entry count.

        Accepts the current format 2 and migrates format-1 files (costs
        only — measured seconds are never lost to a format bump); any other
        format raises a :class:`ValueError` naming the file.
        """
        path = path if path is not None else self.path
        if path is None:
            raise ValueError("CalibrationStore has no path; pass load(path)")
        with open(path) as f:
            payload = json.load(f)
        fmt = payload.get("format")
        costs_in: dict[str, dict[str, float]] = {}
        scheds_in: dict[str, dict[str, dict]] = {}
        if fmt == 1:
            # format 1: entries are bare {sig: {op: seconds}} cost tables
            for sig, costs in payload["entries"].items():
                costs_in[sig] = {k: float(v) for k, v in costs.items()}
        elif fmt == self._FORMAT:
            for sig, section in payload["entries"].items():
                costs_in[sig] = {
                    k: float(v) for k, v in section.get("costs", {}).items()
                }
                sch = section.get("schedule", {})
                if sch:
                    scheds_in[sig] = {ck: dict(rec) for ck, rec in sch.items()}
        else:
            raise ValueError(
                f"calibration store {path!r} has format {fmt!r}; this build "
                f"reads formats 1 and {self._FORMAT}"
            )
        with self._lock:
            # a format-2 sig may be schedule-only: an empty costs section
            # must not shadow (or fabricate) a measured table
            self._entries.update({s: c for s, c in costs_in.items() if c})
            for sig, by_cfg in scheds_in.items():
                self._schedules.setdefault(sig, {}).update(by_cfg)
            return len(self._entries)


class _Admission:
    """FIFO executor leasing over one pool's executor ids.

    ``acquire(width)`` blocks until this request is at the **head** of the
    queue *and* ``width`` executors are free — strict FIFO, so a wide
    request is never starved by narrow ones barging past it, and total
    leased executors never exceed the pool (no oversubscription, the whole
    point of the admission layer).
    """

    def __init__(self, n_executors: int):
        self.n_executors = n_executors
        self._free: set[int] = set(range(n_executors))
        self._cond = threading.Condition()
        self._queue: deque[object] = deque()

    @property
    def n_free(self) -> int:
        with self._cond:
            return len(self._free)

    @property
    def n_waiting(self) -> int:
        with self._cond:
            return len(self._queue)

    def acquire(
        self,
        width: int,
        timeout: float | None = None,
        prefer: tuple[int, ...] = (),
    ) -> tuple[int, ...]:
        if width < 1:
            raise ValueError(f"need width >= 1, got {width}")
        width = min(width, self.n_executors)
        ticket = object()
        with self._cond:
            self._queue.append(ticket)
            try:
                ok = self._cond.wait_for(
                    lambda: self._queue[0] is ticket and len(self._free) >= width,
                    timeout=timeout,
                )
            except BaseException:
                # e.g. KeyboardInterrupt mid-wait: an orphaned ticket at the
                # queue head would wedge strict-FIFO admission forever
                self._queue.remove(ticket)
                self._cond.notify_all()
                raise
            if not ok:
                self._queue.remove(ticket)
                self._cond.notify_all()
                raise TimeoutError(
                    f"no lease of width {width} within {timeout}s "
                    f"({len(self._free)} free, {len(self._queue)} waiting)"
                )
            self._queue.popleft()
            # sticky leases: grant the caller's previous executors when they
            # are free (warm threads / cache affinity — a replayed graph
            # should not migrate between executors run to run), then fill
            # from the free set
            picked = [e for e in prefer if e in self._free][:width]
            if len(picked) < width:
                rest = sorted(self._free.difference(picked))
                picked.extend(rest[: width - len(picked)])
            ids = tuple(sorted(picked))
            self._free.difference_update(ids)
            # the next waiter may already be satisfiable (narrower request)
            self._cond.notify_all()
            return ids

    def release(self, ids: tuple[int, ...]) -> None:
        with self._cond:
            self._free.update(ids)
            self._cond.notify_all()


class ExecutorLease:
    """A disjoint slice of a :class:`Runtime`'s executor pool.

    Quacks like an :class:`~repro.core.engine.ExecutorPool` of
    ``len(executor_ids)`` executors — ``submit`` / ``submit_segments`` /
    ``qsize`` remap local executor indices onto the leased global ids — so
    both host runtimes (the dynamic :class:`HostScheduler` and compiled
    :class:`StaticHostPlan` segments) run *inside* the lease unchanged.
    Segment atomicity is inherited from the underlying pool's lock, so a
    leased plan still cannot cross-deadlock with anything else on the pool.

    ``close()`` aliases :meth:`release` so a lease can stand in anywhere a
    pool is owned; releasing twice is a no-op.
    """

    def __init__(self, runtime: "Runtime", executor_ids: tuple[int, ...]):
        self._runtime = runtime
        self._pool = runtime.pool
        self.executor_ids = executor_ids
        self.n_executors = len(executor_ids)
        self._released = False

    def submit(self, ex: int, name: str, task: Callable[[], Any],
               reply: Any, t_origin: float) -> None:
        self._pool.submit(self.executor_ids[ex], name, task, reply, t_origin)

    def submit_segments(self, items: list, reply: Any, t_origin: float) -> None:
        self._pool.submit_segments(
            [(self.executor_ids[e], name, task) for e, name, task in items],
            reply, t_origin,
        )

    def qsize(self, ex: int) -> int:
        return self._pool.qsize(self.executor_ids[ex])

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._runtime._admission.release(self.executor_ids)

    # pool-interface compatibility: components that "own" their pool call
    # close(); for a lease that means giving the executors back
    close = release

    def __enter__(self) -> "ExecutorLease":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"ExecutorLease(ids={self.executor_ids}, "
                f"released={self._released})")


def _machine_workers() -> int:
    # at least 2 so every machine exercises real multi-executor placement
    return max(2, os.cpu_count() or 2)


class Runtime:
    """Process-wide session owning executors, calibration, and admission.

    Parameters
    ----------
    n_workers:
        Executor-thread count of the single shared pool (default: the
        machine's core count, floor 2).  This is the hard bound the
        admission layer enforces: total leased executors never exceed it.
    hw:
        Default :class:`HardwareModel` for ``compile`` (cost model +
        config-search worker count).
    calibration_path:
        JSON file backing the :class:`CalibrationStore`.  Loaded at
        construction when it exists; autosaved on every ``calibrate()``.

    The executor pool is created lazily on first host execution, so
    sim-only runtimes (the dry-run sweep) never spawn threads.
    """

    def __init__(
        self,
        n_workers: int | None = None,
        *,
        hw: HardwareModel = KNL7250,
        reserved_workers: int = 2,
        calibration_path: str | None = None,
    ):
        self.n_workers = n_workers if n_workers is not None else _machine_workers()
        if self.n_workers < 1:
            raise ValueError(f"need >= 1 worker, got {self.n_workers}")
        self.hw = hw
        self.reserved_workers = reserved_workers
        self.calibration = CalibrationStore(calibration_path)
        self._pool: ExecutorPool | None = None
        self._pool_lock = threading.Lock()
        self._admission = _Admission(self.n_workers)
        self._cache_lock = threading.Lock()
        self._closed = False

    # -- executors + admission ----------------------------------------------
    @property
    def pool(self) -> ExecutorPool:
        """The one shared pool (created on first use)."""
        if self._pool is None:
            with self._pool_lock:
                if self._pool is None:
                    if self._closed:
                        raise RuntimeError("Runtime is closed")
                    self._pool = ExecutorPool(self.n_workers)
        return self._pool

    def lease(
        self,
        width: int,
        timeout: float | None = None,
        prefer: tuple[int, ...] = (),
    ) -> ExecutorLease:
        """Lease ``width`` executors (clamped to ``n_workers``); blocks in
        FIFO order until that many are free.  ``prefer`` are the caller's
        previous executor ids — granted first when free, so a replayed
        graph keeps warm executor threads instead of migrating.  Use as a
        context manager or call ``release()``; every host run through this
        runtime holds exactly one lease for its duration."""
        if self._closed:
            raise RuntimeError("Runtime is closed")
        _ = self.pool  # materialize before handing out ids
        ids = self._admission.acquire(width, timeout=timeout, prefer=prefer)
        return ExecutorLease(self, ids)

    @property
    def leased_executors(self) -> int:
        """Executors currently out on leases (observability/tests)."""
        return self.n_workers - self._admission.n_free

    # -- planning caches -----------------------------------------------------
    def cached(self, graph: Graph, key: tuple, build: Callable[[], Any]) -> Any:
        """Per-graph artifact cache (plans, host schedulers) the runtime
        mediates.

        ``key`` must encode everything the artifact depends on besides the
        graph itself (width, team size, policy, cost fingerprint).  The
        store rides on the graph object (cached plans/schedulers hold a
        strong reference to their graph, so any runtime-side map would pin
        the graph alive forever — this way a dropped graph frees its
        artifacts with it, and two executables over one graph share).
        Entries for a graph are dropped wholesale by :meth:`invalidate`
        (an executable re-profiled with new measured costs).
        """
        with self._cache_lock:
            per_graph = graph.__dict__.setdefault("_graphi_artifacts", {})
            hit = per_graph.get(key)
        if hit is not None:
            return hit
        made = build()
        with self._cache_lock:
            return per_graph.setdefault(key, made)

    def invalidate(self, graph: Graph) -> None:
        with self._cache_lock:
            graph.__dict__.pop("_graphi_artifacts", None)

    # -- compile -------------------------------------------------------------
    def compile(self, target: Any, *specs: Any, **kw: Any):
        """``repro.compile`` bound to this runtime: the returned
        :class:`~repro.api.Executable` executes on leases from this
        runtime's pool, seeds its cost model from the calibration store,
        and writes ``calibrate()`` results back to it."""
        from repro import api

        kw.setdefault("hw", self.hw)
        kw.setdefault("reserved_workers", self.reserved_workers)
        return api.compile(target, *specs, runtime=self, **kw)

    # -- lifecycle -----------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Close the pool and persist the calibration store (idempotent).
        In-flight leases finish their queued work (pool close drains
        FIFO-before-sentinel); new leases and compiles raise."""
        if self._closed:
            return
        self._closed = True
        if self._pool is not None:
            self._pool.close()
        if self.calibration.path is not None:
            self.calibration.save()

    def __enter__(self) -> "Runtime":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def describe(self) -> str:
        return (
            f"Runtime(n_workers={self.n_workers}, hw={self.hw.name}, "
            f"pool={'live' if self._pool is not None else 'lazy'}, "
            f"leased={self.leased_executors}, "
            f"calibrations={len(self.calibration)})"
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return self.describe()


# -- the process-wide default ------------------------------------------------
_default: Runtime | None = None
_default_lock = threading.Lock()


def default_runtime() -> Runtime:
    """The process-wide :class:`Runtime` behind bare ``repro.compile``.

    Created on first use (machine-sized pool, no calibration path); if the
    current default was closed, a fresh one replaces it.
    """
    global _default
    with _default_lock:
        if _default is None or _default.closed:
            _default = Runtime()
        return _default


def set_default_runtime(rt: Runtime | None) -> Runtime | None:
    """Swap the process default (tests, or an app that wants one configured
    runtime everywhere); returns the previous one (not closed)."""
    global _default
    with _default_lock:
        prev, _default = _default, rt
        return prev
