"""Common finding/report types for the static verifier (DESIGN.md §3.3).

Every layer of ``repro.checks`` — structural invariants, effect inference,
hazard analysis, source scans — emits the same currency: a :class:`Finding`
``(rule_id, severity, context, message)``.  A :class:`Report` is an ordered
collection of findings with the aggregation the callers need: CLI rendering,
``ok`` gating (error severity only), and ``raise_if_errors`` for the
``check="strict"`` compile path.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Iterable, Iterator

from repro.core.graph import GraphValidationError

__all__ = ["Finding", "Report", "SEVERITIES"]

SEVERITIES = ("error", "warning", "info")
_RANK = {s: i for i, s in enumerate(SEVERITIES)}


@dataclass(frozen=True)
class Finding:
    """One verifier result.

    ``rule_id`` names the rule (catalog in DESIGN.md §3.3, e.g. ``G-CYCLE``,
    ``P-COUNTER``, ``H-WW``); ``where`` is the artifact the rule ran over
    (graph name, plan name, file path); ``node``/``executor`` narrow the
    location when the rule is about one op or one executor program.
    """

    rule_id: str
    severity: str                      # "error" | "warning" | "info"
    message: str
    where: str = ""
    node: str | None = None
    executor: int | None = None

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}")

    def render(self) -> str:
        loc = self.where
        if self.node is not None:
            loc = f"{loc}:{self.node}" if loc else self.node
        if self.executor is not None:
            loc = f"{loc}@e{self.executor}"
        return f"{self.severity.upper():7s} {self.rule_id:10s} {loc}: {self.message}"

    def __str__(self) -> str:
        return self.render()


@dataclass
class Report:
    """An ordered finding collection; ``ok`` gates on error severity only."""

    findings: list[Finding] = field(default_factory=list)

    # -- building ----------------------------------------------------------
    def add(
        self,
        rule_id: str,
        severity: str,
        message: str,
        *,
        where: str = "",
        node: str | None = None,
        executor: int | None = None,
    ) -> Finding:
        f = Finding(rule_id, severity, message, where=where, node=node,
                    executor=executor)
        self.findings.append(f)
        return f

    def extend(self, other: "Report | Iterable[Finding]") -> "Report":
        self.findings.extend(
            other.findings if isinstance(other, Report) else other)
        return self

    def scoped(self, where: str) -> "Report":
        """A copy with ``where`` filled in on findings that lack one."""
        return Report([
            replace(f, where=where) if not f.where else f
            for f in self.findings
        ])

    # -- aggregation -------------------------------------------------------
    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def by_rule(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.findings:
            out[f.rule_id] = out.get(f.rule_id, 0) + 1
        return out

    def raise_if_errors(self) -> None:
        """Raise :class:`GraphValidationError` listing the error findings
        (the ``check="strict"`` enforcement point)."""
        errs = self.errors
        if errs:
            head = "; ".join(f"{f.rule_id} {f.message}" for f in errs[:4])
            more = f" (+{len(errs) - 4} more)" if len(errs) > 4 else ""
            raise GraphValidationError(
                f"{len(errs)} check error(s): {head}{more}")

    def summary(self) -> str:
        n_e, n_w = len(self.errors), len(self.warnings)
        n_i = len(self.findings) - n_e - n_w
        return f"{n_e} error(s), {n_w} warning(s), {n_i} info"

    def render(self, *, min_severity: str = "info") -> str:
        keep = [f for f in self.findings
                if _RANK[f.severity] <= _RANK[min_severity]]
        if not keep:
            return "clean: no findings"
        ordered = sorted(keep, key=lambda f: (_RANK[f.severity], f.rule_id))
        return "\n".join(f.render() for f in ordered)

    def __iter__(self) -> Iterator[Finding]:
        return iter(self.findings)

    def __len__(self) -> int:
        return len(self.findings)

    def __str__(self) -> str:
        return self.render()
