"""Per-node buffer effect inference (DESIGN.md §3.3).

Answers, for every node of a captured graph, *which input buffers it reads
and which it writes*.  "Buffer" means a graph **input node** (a param leaf,
a cache pool, a token array): inside a graph every op output is a fresh SSA
value, so the only state that can be hazarded across nodes — or across two
graphs sharing arrays, like the paged decode step and a prefill chunk over
one page pool — is the inputs.

Inference walks the jaxpr equations each node carries in its meta
(``_eqns`` / ``_imports`` / ``_exports``, attached by ``core.capture``),
propagating the set of buffer *roots* every intermediate value is a version
of:

* ``scatter*`` / ``dynamic_update_slice`` **write** their operand's roots
  (functional update = a new version of the same logical buffer; the output
  carries the roots forward);
* view/layout primitives (reshape, transpose, convert, ...) carry roots
  unchanged;
* ``scan`` / ``while`` / ``cond`` and call-like primitives recurse into
  their sub-jaxprs with positional argument mapping, iterating loop carries
  to a fixpoint — the paged decode's pool scatters live *inside* a
  ``lax.scan`` over layers and must still be seen;
* every other primitive reads its operands and produces fresh values.

Hand-built graphs (no jaxpr meta) may annotate nodes explicitly with
``meta={"effects": {"reads": [...], "writes": [...], "carries": [...]}}``;
nodes with neither are treated conservatively as pure readers of everything
their deps carry.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Iterable, Mapping

from jax.extend import core as jex

from repro.core.graph import Graph

__all__ = ["NodeEffects", "GraphEffects", "infer_effects", "shared_buffers"]

_EMPTY: frozenset[str] = frozenset()

# primitives whose (single) output is the same logical buffer as invars[0]
_CARRY_PRIMS = {
    "reshape", "transpose", "squeeze", "expand_dims", "rev",
    "copy", "convert_element_type", "stop_gradient", "device_put",
    "sharding_constraint",
}
_LOOP_FIXPOINT_LIMIT = 8


def _is_write(prim: str) -> bool:
    return prim.startswith("scatter") or prim == "dynamic_update_slice"


@dataclass(frozen=True)
class NodeEffects:
    """Buffer footprint of one node.  ``source`` records inference precision:
    ``"jaxpr"`` (traced), ``"annotated"`` (meta), ``"input"`` (buffer root),
    or ``"opaque"`` (no information — conservative reader)."""

    node: str
    reads: frozenset[str]
    writes: frozenset[str]
    source: str = "jaxpr"


@dataclass
class GraphEffects:
    """Effect sets for every node of one graph, at one ``Graph.version``."""

    graph_name: str
    version: int
    buffers: tuple[str, ...]                 # graph input node names
    effects: dict[str, NodeEffects]
    # (node, export slot) -> buffer roots its output carries
    slot_roots: dict[str, tuple[frozenset[str], ...]]

    def writers(self, buf: str) -> list[str]:
        return [n for n, e in self.effects.items() if buf in e.writes]

    def readers(self, buf: str) -> list[str]:
        return [n for n, e in self.effects.items()
                if buf in e.reads and buf not in e.writes]

    def written(self) -> set[str]:
        out: set[str] = set()
        for e in self.effects.values():
            out |= e.writes
        return out

    def read_only(self, bufs: Iterable[str]) -> bool:
        """True when no node writes any of ``bufs`` — the static
        certification behind running this graph concurrently with another
        graph's writes to those buffers."""
        w = self.written()
        return not any(b in w for b in bufs)


def infer_effects(graph: Graph) -> GraphEffects:
    """Infer :class:`NodeEffects` for every node of ``graph``."""
    effects: dict[str, NodeEffects] = {}
    slot_roots: dict[str, tuple[frozenset[str], ...]] = {}
    buffers: list[str] = []

    for name in graph.topo_order():
        node = graph[name]
        if node.fn is None:
            buffers.append(name)
            effects[name] = NodeEffects(name, _EMPTY, _EMPTY, source="input")
            slot_roots[name] = (frozenset({name}),)
            continue
        meta = node.meta or {}

        def dep_roots(dep_idx: int, slot: int, n_slots: int,
                      _node=node) -> frozenset[str]:
            slots = slot_roots.get(_node.deps[dep_idx], ())
            if n_slots <= 1 or len(slots) <= 1:
                return slots[0] if slots else _EMPTY
            return slots[slot] if slot < len(slots) else _EMPTY

        if "_eqns" in meta and "_imports" in meta:
            reads, writes, outs = _jaxpr_effects(meta, dep_roots)
            effects[name] = NodeEffects(name, reads, writes)
            slot_roots[name] = outs
        elif "effects" in meta:
            ann = meta["effects"]
            effects[name] = NodeEffects(
                name,
                reads=frozenset(ann.get("reads", ())),
                writes=frozenset(ann.get("writes", ())),
                source="annotated",
            )
            slot_roots[name] = (frozenset(ann.get("carries", ())),)
        else:
            all_dep = _EMPTY
            for d in node.deps:
                for r in slot_roots.get(d, ()):
                    all_dep |= r
            effects[name] = NodeEffects(name, all_dep, _EMPTY, source="opaque")
            slot_roots[name] = (_EMPTY,)

    return GraphEffects(
        graph_name=graph.name,
        version=graph.version,
        buffers=tuple(buffers),
        effects=effects,
        slot_roots=slot_roots,
    )


# -- jaxpr walk --------------------------------------------------------------

def _jaxpr_effects(
    meta: Mapping[str, Any],
    dep_roots: Callable[[int, int, int], frozenset[str]],
) -> tuple[frozenset[str], frozenset[str], tuple[frozenset[str], ...]]:
    env: dict[Any, frozenset[str]] = {}
    for var, dep_idx, slot, n_slots in meta["_imports"]:
        env[var] = dep_roots(dep_idx, slot, n_slots)
    reads: set[str] = set()
    writes: set[str] = set()
    _walk_eqns(meta["_eqns"], env, reads, writes)
    outs = tuple(_roots_of(env, v) for v in meta["_exports"])
    return frozenset(reads), frozenset(writes), outs


def _roots_of(env: Mapping[Any, frozenset[str]], v: Any) -> frozenset[str]:
    if isinstance(v, jex.Var):
        return env.get(v, _EMPTY)
    return _EMPTY   # literals / dropped vars carry no buffer


def _walk_eqns(
    eqns: Iterable[Any],
    env: dict[Any, frozenset[str]],
    reads: set[str],
    writes: set[str],
) -> None:
    for eqn in eqns:
        prim = eqn.primitive.name
        in_roots = [_roots_of(env, v) for v in eqn.invars]
        for r in in_roots:
            reads.update(r)
        n_out = len(eqn.outvars)
        out_roots: list[frozenset[str]] = [_EMPTY] * n_out

        if _is_write(prim):
            # functional update: a new version of the operand's buffer
            writes.update(in_roots[0])
            out_roots[0] = in_roots[0]
        elif prim in _CARRY_PRIMS:
            out_roots[0] = in_roots[0]
        elif prim == "scan":
            out_roots = _walk_scan(eqn, in_roots, reads, writes)
        elif prim == "while":
            out_roots = _walk_while(eqn, in_roots, reads, writes)
        elif prim == "cond":
            out_roots = _walk_cond(eqn, in_roots, reads, writes)
        else:
            sub, _ = _sub_jaxpr(eqn)
            if sub is not None and len(sub.invars) == len(eqn.invars):
                out_roots = _walk_sub(sub, in_roots, reads, writes)
            # else: opaque primitive — fresh outputs, no carried roots

        for ov, r in zip(eqn.outvars, out_roots):
            if isinstance(ov, jex.Var):
                env[ov] = r


def _sub_jaxpr(eqn: Any) -> tuple[Any, Any]:
    """Open jaxpr of a call-like eqn (pjit / remat / custom_*), or (None, None)."""
    sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
    if sub is None:
        return None, None
    if hasattr(sub, "jaxpr"):          # ClosedJaxpr
        return sub.jaxpr, list(sub.consts)
    return sub, []


def _walk_sub(
    sub: Any,
    in_roots: list[frozenset[str]],
    reads: set[str],
    writes: set[str],
) -> list[frozenset[str]]:
    """Walk a sub-jaxpr with positional invar/outvar mapping; returns the
    eqn-level output roots."""
    env = {v: r for v, r in zip(sub.invars, in_roots) if isinstance(v, jex.Var)}
    _walk_eqns(sub.eqns, env, reads, writes)
    return [_roots_of(env, v) for v in sub.outvars]


def _walk_scan(
    eqn: Any,
    in_roots: list[frozenset[str]],
    reads: set[str],
    writes: set[str],
) -> list[frozenset[str]]:
    sub = eqn.params["jaxpr"].jaxpr
    n_const = eqn.params["num_consts"]
    n_carry = eqn.params["num_carry"]
    body_in = list(in_roots)    # consts + carry + xs, positionally = sub.invars
    outs: list[frozenset[str]] = []
    for _ in range(_LOOP_FIXPOINT_LIMIT):
        outs = _walk_sub(sub, body_in, reads, writes)
        changed = False
        for k in range(n_carry):
            merged = body_in[n_const + k] | outs[k]
            if merged != body_in[n_const + k]:
                body_in[n_const + k] = merged
                changed = True
        if not changed:
            break
    # eqn outvars = carry outs + ys, positionally = sub outvars
    return outs


def _walk_while(
    eqn: Any,
    in_roots: list[frozenset[str]],
    reads: set[str],
    writes: set[str],
) -> list[frozenset[str]]:
    cond = eqn.params["cond_jaxpr"].jaxpr
    body = eqn.params["body_jaxpr"].jaxpr
    n_cc = eqn.params["cond_nconsts"]
    n_bc = eqn.params["body_nconsts"]
    cond_consts = in_roots[:n_cc]
    body_in = list(in_roots[n_cc:])           # body consts + carry
    carry0 = n_bc
    outs: list[frozenset[str]] = []
    for _ in range(_LOOP_FIXPOINT_LIMIT):
        outs = _walk_sub(body, body_in, reads, writes)
        changed = False
        for k in range(len(outs)):            # body outvars = the carry
            merged = body_in[carry0 + k] | outs[k]
            if merged != body_in[carry0 + k]:
                body_in[carry0 + k] = merged
                changed = True
        if not changed:
            break
    _walk_sub(cond, cond_consts + body_in[carry0:], reads, writes)
    return outs


def _walk_cond(
    eqn: Any,
    in_roots: list[frozenset[str]],
    reads: set[str],
    writes: set[str],
) -> list[frozenset[str]]:
    branches = eqn.params["branches"]
    operand_roots = in_roots[1:]              # invars[0] is the predicate
    merged: list[frozenset[str]] | None = None
    for br in branches:
        outs = _walk_sub(br.jaxpr, operand_roots, reads, writes)
        if merged is None:
            merged = outs
        else:
            merged = [a | b for a, b in zip(merged, outs)]
    return merged or []


# -- cross-graph aliasing ----------------------------------------------------

def shared_buffers(
    bind_a: Mapping[str, Any],
    bind_b: Mapping[str, Any],
) -> list[tuple[str, str]]:
    """Input buffers two graphs share, found by array **object identity**
    over their bound name→value input mappings (``CapturedGraph.bind``).

    Two graphs alias state exactly when the caller passes the *same* array
    to both — e.g. the serving engine threads one page pool through the
    decode step and every prefill chunk.  Leaf names differ per graph
    (``in.1pagesk`` vs ``in.1k``), so identity, not naming, is the ground
    truth.  Returns ``(name_in_a, name_in_b)`` pairs.
    """
    by_id: dict[int, list[str]] = {}
    for name, val in bind_a.items():
        if val is not None and not isinstance(val, (int, float, bool)):
            by_id.setdefault(id(val), []).append(name)
    pairs: list[tuple[str, str]] = []
    for name_b, val in bind_b.items():
        if val is None or isinstance(val, (int, float, bool)):
            continue
        for name_a in by_id.get(id(val), ()):
            pairs.append((name_a, name_b))
    return pairs
