"""``repro.checks`` — static verifier & concurrency-hazard analysis.

Three layers over one currency (:class:`Finding` / :class:`Report`,
catalog in DESIGN.md §3.3):

1. **Structural invariants** (:mod:`.invariants`) — the Graph is a DAG with
   a consistent successor cache; a Schedule covers every node exactly once,
   respects dep edges, and never overlaps an executor; a StaticHostPlan's
   dependency counters equal executed in-degrees, its per-executor programs
   are topologically consistent, every op is reachable from the seeds under
   the counter protocol (deadlock freedom), the poison failure protocol can
   reach every segment, and concurrent plans' segment submission is
   FIFO-consistent — replayed from pool evidence, not assumed.
2. **Effect & hazard analysis** (:mod:`.effects`, :mod:`.hazards`) — per-node
   read/write buffer sets traced from captured jaxpr equations (including
   inside ``scan``/``while``/``cond`` bodies), happens-before from dep edges
   (plus executor program order when a schedule is given), unordered
   write/write and read/write pairs flagged; cross-graph conflicts over
   aliased buffers (the paged pools) reported by
   :func:`cross_graph_hazards`.
3. **Source rules** (:mod:`.assertscan`) — W-ASSERT keeps bare ``assert``
   statements out of library code.

Entry points: ``Executable.verify()`` and ``repro.compile(..., check=)``
for in-process use; ``python -m repro.checks --zoo`` for the config-zoo
sweep CI runs.
"""
from __future__ import annotations

from repro.core.graph import Graph
from repro.core.scheduler import Schedule
from repro.core.static_host import StaticHostPlan

from .assertscan import scan_asserts
from .effects import GraphEffects, NodeEffects, infer_effects, shared_buffers
from .hazards import check_hazards, cross_graph_hazards
from .invariants import (check_graph, check_plan, check_schedule,
                         check_segment_fifo, segment_queues)
from .report import SEVERITIES, Finding, Report

__all__ = [
    "Finding",
    "Report",
    "SEVERITIES",
    "check_graph",
    "check_schedule",
    "check_plan",
    "check_segment_fifo",
    "segment_queues",
    "NodeEffects",
    "GraphEffects",
    "infer_effects",
    "shared_buffers",
    "check_hazards",
    "cross_graph_hazards",
    "scan_asserts",
    "verify_all",
]


def verify_all(
    graph: Graph,
    schedule: Schedule | None = None,
    plan: StaticHostPlan | None = None,
    *,
    hazards: bool = True,
) -> Report:
    """Run every applicable checker over one graph's planning artifacts."""
    rep = check_graph(graph)
    if schedule is not None:
        rep.extend(check_schedule(schedule, graph))
    if plan is not None:
        rep.extend(check_plan(plan, graph))
    if hazards:
        rep.extend(check_hazards(graph, schedule=schedule))
    return rep
