"""``python -m repro.checks`` — the static-verification sweep CI runs.

Default run: source rules (W-ASSERT) plus a live segment-FIFO probe (two
static plans replayed concurrently on one pool, journal replayed through
E-FIFO).  ``--zoo`` adds the config-zoo model sweep: for every arch, capture
the lm_loss, prefill, and decode graphs (plus the paged decode /
chunk-prefill pair where supported), then run every structural checker and
the hazard analysis over graph, schedule, and compiled host plan.  Exit
status 1 when any error-severity finding survives.
"""
from __future__ import annotations

import argparse
import threading
from typing import Any

from .assertscan import scan_asserts
from .effects import infer_effects, shared_buffers
from .hazards import check_hazards, cross_graph_hazards
from .invariants import check_segment_fifo, segment_queues
from .report import Report
from . import verify_all

__all__ = ["main", "run_fifo_probe", "run_zoo_arch"]

# zoo capture shape — small enough that ten archs sweep in CI minutes,
# deep enough (2 smoke layers, real vocab padding) that fusion, scan
# bodies, and cache scatters all appear in the captured graphs
_B, _SEQ, _MAX_LEN, _PAGE = 2, 16, 32, 8
_N_WORKERS = 8


def run_fifo_probe(*, runs: int = 6) -> Report:
    """Replay two static plans concurrently on one journaled pool and verify
    segment-submission FIFO consistency from the evidence."""
    import repro
    from repro.core.engine import ExecutorPool
    from repro.core.static_host import layered_graph

    g = layered_graph(3, 2)
    exe = repro.compile(g, n_workers=4, n_executors=2, team_size=2)
    plan = exe.host_plan(2)
    pool = ExecutorPool(2)
    pool.segment_log = []
    try:
        def worker() -> None:
            for _ in range(runs):
                plan.run({"x": 1.0}, pool=pool)

        threads = [threading.Thread(target=worker) for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        pool.close()
    return check_segment_fifo(segment_queues(pool.segment_log))


def _check_executable(exe: Any, label: str) -> Report:
    rep = verify_all(exe.graph, exe.schedule, exe.host_plan())
    return rep.scoped(label)


def run_zoo_arch(arch: str) -> Report:
    """Capture and verify one arch's graphs (lm_loss, prefill, decode, and
    the paged pair where :func:`~repro.models.transformer.paged_supported`)."""
    import jax
    import jax.numpy as jnp

    import repro
    from repro.configs.base import ShapeSpec, get_config
    from repro.models import api as model_api
    from repro.models import transformer
    from repro.serve.step import (make_decode_step, make_paged_decode_step,
                                  make_prefill_chunk_step, make_prefill_step)
    from repro.train.step import compile_lm_loss

    rep = Report()
    cfg = get_config(arch, smoke=True)
    shape = ShapeSpec("check", _SEQ, _B, "train")
    key = jax.random.key(0)
    params = transformer.init_params(cfg, key)

    def guarded(label: str, build) -> None:
        try:
            rep.extend(build())
        except Exception as e:  # noqa: BLE001 — one bad graph must not hide the rest
            rep.add("Z-SKIP", "warning",
                    f"{type(e).__name__}: {e}", where=f"{arch}/{label}")

    def loss() -> Report:
        exe = compile_lm_loss(cfg, shape, backend="host",
                              n_workers=_N_WORKERS)
        return _check_executable(exe, f"{arch}/lm_loss")

    def prefill() -> Report:
        cache = transformer.init_cache(cfg, _B, _MAX_LEN)
        batch = model_api.input_specs(cfg, shape, kind="prefill")
        exe = repro.compile(make_prefill_step(cfg), params, cache, batch,
                            n_workers=_N_WORKERS,
                            name=f"{arch}.prefill")
        return _check_executable(exe, f"{arch}/prefill")

    def decode() -> Report:
        cache = transformer.init_cache(cfg, _B, _MAX_LEN)
        tok = jax.ShapeDtypeStruct((_B, 1), jnp.int32)
        exe = repro.compile(make_decode_step(cfg), params, cache, tok,
                            n_workers=_N_WORKERS,
                            name=f"{arch}.decode")
        return _check_executable(exe, f"{arch}/decode")

    guarded("lm_loss", loss)
    guarded("prefill", prefill)
    guarded("decode", decode)

    if transformer.paged_supported(cfg):
        def paged() -> Report:
            sub = Report()
            n_pt = _MAX_LEN // _PAGE
            pcache = transformer.init_paged_cache(
                cfg, _B, _MAX_LEN, n_pages=_B * n_pt, page_size=_PAGE)
            pages = pcache["pages"]   # ONE pool object for both graphs
            cache_spec = {"len": jnp.zeros((_B,), jnp.int32),
                          "table": jnp.full((_B, n_pt), -1, jnp.int32),
                          "pages": pages}
            tok = jnp.zeros((_B, 1), jnp.int32)
            dec = repro.compile(
                make_paged_decode_step(cfg, _PAGE), params, cache_spec, tok,
                n_workers=_N_WORKERS, name=f"{arch}.paged_decode")
            row = jnp.full((n_pt,), -1, jnp.int32)
            chunk_batch = {"tokens": jnp.zeros((1, _PAGE), jnp.int32)}
            start, valid = jnp.int32(0), jnp.int32(_PAGE)
            chunk = repro.compile(
                make_prefill_chunk_step(cfg, _PAGE), params, pages, row,
                chunk_batch, start, valid,
                n_workers=_N_WORKERS, name=f"{arch}.prefill_chunk")
            sub.extend(_check_executable(dec, f"{arch}/paged_decode"))
            sub.extend(_check_executable(chunk, f"{arch}/prefill_chunk"))

            # cross-graph: the decode step scatters into the pools; every
            # chunk-prefill must be read-only over them (PR 6's concurrency
            # protocol) — certified here, not assumed
            eff_d = infer_effects(dec.graph)
            eff_c = infer_effects(chunk.graph)
            bind_d = dec.captured.bind((params, cache_spec, tok))
            bind_c = chunk.captured.bind(
                (params, pages, row, chunk_batch, start, valid))
            shared = shared_buffers(bind_d, bind_c)
            pool_leaves = {id(x) for x in jax.tree.leaves(pages)}
            pool_shared = [
                (a, b) for a, b in shared if id(bind_d[a]) in pool_leaves]
            if not pool_shared:
                sub.add("H-XWW", "error",
                        "paged decode and prefill chunk share no pool "
                        "buffers — alias discovery broke",
                        where=f"{arch}/paged")
            if not eff_d.written() & {a for a, _ in pool_shared}:
                sub.add("H-XWW", "error",
                        "paged decode writes no pool buffer — effect "
                        "inference lost the scan-body scatters",
                        where=f"{arch}/paged")
            sub.extend(cross_graph_hazards(eff_d, eff_c, shared))
            if eff_c.read_only(b for _, b in pool_shared):
                sub.add("C-RO", "info",
                        f"prefill chunk certified read-only over "
                        f"{len(pool_shared)} shared pool buffer(s)",
                        where=f"{arch}/paged")
            return sub

        guarded("paged", paged)
    return rep


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.checks",
        description="static verifier: structural invariants, effect/hazard "
                    "analysis, source rules",
    )
    ap.add_argument("--zoo", action="store_true",
                    help="capture and verify the config-zoo model graphs")
    ap.add_argument("--arch", action="append", default=None,
                    help="restrict --zoo to this arch (repeatable)")
    ap.add_argument("--no-asserts", action="store_true",
                    help="skip the W-ASSERT source scan")
    ap.add_argument("--no-fifo", action="store_true",
                    help="skip the live segment-FIFO probe")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="show info-severity findings")
    args = ap.parse_args(argv)

    total = Report()
    if not args.no_asserts:
        asserts = scan_asserts()
        total.extend(asserts)
        print(f"asserts : {asserts.summary()}")
    if not args.no_fifo:
        fifo = run_fifo_probe()
        total.extend(fifo)
        print(f"fifo    : {fifo.summary()}")
    if args.zoo or args.arch:
        from repro.configs.base import list_archs

        archs = args.arch or list_archs()
        for arch in archs:
            rep = run_zoo_arch(arch)
            total.extend(rep)
            print(f"{arch:22s}: {rep.summary()}")

    min_sev = "info" if args.verbose else "warning"
    body = total.render(min_severity=min_sev)
    if body != "clean: no findings" or args.verbose:
        print()
        print(body)
    print()
    print(f"TOTAL   : {total.summary()}")
    return 0 if total.ok else 1
