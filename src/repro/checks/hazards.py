"""Concurrency-hazard analysis over buffer effects (DESIGN.md §3.3).

Within one graph, the only ordering the runtimes *guarantee* is the dep
edges: the dynamic scheduler and the static host plan both run any two
dep-unordered ops concurrently whenever executors are free.  So two ops
touching the same buffer with at least one writer must be ordered by a dep
path, or the run is a data race:

* **H-WW** (error) — two writes to one buffer with no dep path between them;
* **H-RW** (error) — a read and a write unordered by deps.

When a schedule is supplied, a pair that *is* serialized by landing on the
same executor (program order) — but not by deps — downgrades to a warning:
today's placement hides the race, the next profile re-plan may not.

Across graphs there is no dep order at all; :func:`cross_graph_hazards`
takes two :class:`~repro.checks.effects.GraphEffects` plus the buffer alias
pairs (:func:`~repro.checks.effects.shared_buffers`) and reports:

* **H-XWW** (error) — both graphs write a shared buffer: never safe to run
  concurrently;
* **H-XRW** (info)  — one writes, the other only reads: safe exactly when
  the caller serializes the runs externally (the paged serving engine's
  insert-after-decode protocol), which is why chunked-prefill graphs must
  stay read-only over the pools — the certification this rule states.
"""
from __future__ import annotations

from typing import Iterable, Mapping

from repro.core.graph import Graph
from repro.core.scheduler import Schedule

from .effects import GraphEffects, infer_effects
from .report import Report

__all__ = ["check_hazards", "cross_graph_hazards"]

_MAX_PER_RULE = 8


def _descendant_bits(
    order: list[str],
    succs: Mapping[str, Iterable[str]],
) -> dict[str, int]:
    """Per-node descendant set (self included) as int bitmasks over a topo
    order — reflexive-transitive closure in O(V·E/word)."""
    idx = {n: i for i, n in enumerate(order)}
    reach: dict[str, int] = {}
    for n in reversed(order):
        bits = 1 << idx[n]
        for s in succs.get(n, ()):
            bits |= reach[s]
        reach[n] = bits
    return reach


def _topo(names: Iterable[str], succs: Mapping[str, Iterable[str]]) -> list[str] | None:
    names = list(names)
    indeg = {n: 0 for n in names}
    for n in names:
        for s in succs.get(n, ()):
            indeg[s] += 1
    ready = [n for n in names if indeg[n] == 0]
    order: list[str] = []
    while ready:
        n = ready.pop()
        order.append(n)
        for s in succs.get(n, ()):
            indeg[s] -= 1
            if indeg[s] == 0:
                ready.append(s)
    return order if len(order) == len(names) else None


def check_hazards(
    graph: Graph,
    effects: GraphEffects | None = None,
    schedule: Schedule | None = None,
) -> Report:
    """H-* rules: unordered same-buffer access pairs within one graph."""
    rep = Report()
    where = graph.name
    if effects is None:
        effects = infer_effects(graph)
    if effects.version != graph.version:
        rep.add("H-STALE", "error",
                f"effects inferred at graph version {effects.version}, graph "
                f"is at {graph.version} — re-run infer_effects", where=where)
        return rep

    names = list(graph.names)
    dep_succs = {n: tuple(graph.successors(n)) for n in names}
    order = _topo(names, dep_succs)
    if order is None:
        rep.add("H-ORDER", "error",
                "graph is cyclic — hazard analysis needs check_graph to pass",
                where=where)
        return rep
    idx = {n: i for i, n in enumerate(order)}
    dep_reach = _descendant_bits(order, dep_succs)

    sched_reach: dict[str, int] | None = None
    if schedule is not None:
        # program order on one executor serializes its ops even without deps
        both = {n: set(dep_succs[n]) for n in names}
        for ops in schedule.by_executor():
            placed = [n for n in ops if n in both]
            for a, b in zip(placed, placed[1:]):
                both[a].add(b)
        sorder = _topo(names, both)
        if sorder is None:
            rep.add("H-ORDER", "error",
                    "schedule executor order contradicts dep edges "
                    "(check_schedule S-DEP) — placement serialization ignored",
                    where=where)
        else:
            sched_reach = _descendant_bits(sorder, both)

    def ordered(reach: Mapping[str, int], a: str, b: str) -> bool:
        return bool(reach[a] >> idx[b] & 1) or bool(reach[b] >> idx[a] & 1)

    counts = {"H-WW": 0, "H-RW": 0}

    def emit(rule: str, a: str, b: str, buf: str, kind: str) -> None:
        counts[rule] += 1
        if counts[rule] > _MAX_PER_RULE:
            return
        if sched_reach is not None and ordered(sched_reach, a, b):
            rep.add(rule, "warning",
                    f"{kind} of buffer {buf!r} by {a!r} and {b!r} is "
                    "serialized only by executor placement — a re-profile "
                    "can reorder it", where=where, node=a)
        else:
            rep.add(rule, "error",
                    f"unordered {kind} of buffer {buf!r}: no dep path "
                    f"between {a!r} and {b!r}", where=where, node=a)

    for buf in sorted(effects.written()):
        writers = sorted(effects.writers(buf), key=idx.__getitem__)
        readers = sorted(effects.readers(buf), key=idx.__getitem__)
        for i, a in enumerate(writers):
            for b in writers[i + 1:]:
                if not ordered(dep_reach, a, b):
                    emit("H-WW", a, b, buf, "write/write")
            for b in readers:
                if not ordered(dep_reach, a, b):
                    emit("H-RW", a, b, buf, "read/write")
    for rule, n in counts.items():
        if n > _MAX_PER_RULE:
            rep.add(rule, "info",
                    f"{n - _MAX_PER_RULE} further {rule} pairs suppressed",
                    where=where)
    return rep


def cross_graph_hazards(
    eff_a: GraphEffects,
    eff_b: GraphEffects,
    shared: Iterable[tuple[str, str]],
) -> Report:
    """H-X* rules: conflicting access to buffers aliased across two graphs."""
    rep = Report()
    where = f"{eff_a.graph_name}×{eff_b.graph_name}"
    wrote_a = eff_a.written()
    wrote_b = eff_b.written()
    n_shared = 0
    for buf_a, buf_b in shared:
        n_shared += 1
        a_w, b_w = buf_a in wrote_a, buf_b in wrote_b
        if a_w and b_w:
            rep.add("H-XWW", "error",
                    f"both graphs write shared buffer ({buf_a!r} in "
                    f"{eff_a.graph_name!r}, {buf_b!r} in "
                    f"{eff_b.graph_name!r}) — concurrent runs race",
                    where=where, node=buf_a)
        elif a_w or b_w:
            writer = eff_a.graph_name if a_w else eff_b.graph_name
            rep.add("H-XRW", "info",
                    f"shared buffer {buf_a!r}/{buf_b!r} written by "
                    f"{writer!r} only — concurrent runs need external "
                    "serialization of the write", where=where, node=buf_a)
    if n_shared and rep.ok:
        rep.add("H-XOK", "info",
                f"{n_shared} shared buffer(s), no write/write conflicts",
                where=where)
    return rep
