"""W-ASSERT: no bare ``assert`` statements in library code.

``python -O`` strips asserts, so an invariant guarded by one silently stops
being checked in optimized deployments.  The library was swept to typed
exceptions (``GraphValidationError`` / ``ValueError`` / ``RuntimeError``);
this rule keeps regressions out.  Error severity on purpose: the CI checks
job must block a reintroduced assert, not shrug at it.

Scans with ``ast`` (not grep) so strings, comments, and doctests never
false-positive.  Test trees are exempt by default — pytest asserts are the
idiom there.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .report import Report

__all__ = ["scan_asserts", "LIBRARY_ROOT"]

# src/repro — the tree the no-assert contract covers
LIBRARY_ROOT = Path(__file__).resolve().parents[1]


def scan_asserts(root: str | Path | None = None) -> Report:
    """Scan ``root`` (default: the installed ``repro`` package tree) for
    ``assert`` statements; one W-ASSERT error finding per occurrence."""
    rep = Report()
    base = Path(root) if root is not None else LIBRARY_ROOT
    if base.is_file():
        files = [base]
        rel_to = base.parent
    else:
        files = sorted(base.rglob("*.py"))
        rel_to = base
    n_files = 0
    for py in files:
        try:
            tree = ast.parse(py.read_text(encoding="utf-8"), filename=str(py))
        except SyntaxError as e:
            rep.add("W-PARSE", "error", f"unparseable: {e}",
                    where=str(py.relative_to(rel_to)))
            continue
        n_files += 1
        where = str(py.relative_to(rel_to))
        for node in ast.walk(tree):
            if isinstance(node, ast.Assert):
                rep.add(
                    "W-ASSERT", "error",
                    f"bare assert at line {node.lineno} — python -O strips "
                    "it; raise a typed exception instead",
                    where=where,
                )
    if rep.ok:
        rep.add("W-ASSERT", "info",
                f"{n_files} file(s) scanned, no bare asserts",
                where=str(base))
    return rep
