"""Structural invariants over the planning artifacts (DESIGN.md §3.3).

One checker per artifact, each returning a :class:`~repro.checks.report.Report`:

* :func:`check_graph`      — G-* rules over the :class:`~repro.core.graph.Graph`
  (acyclicity, dep resolution, successor-cache consistency).
* :func:`check_schedule`   — S-* rules over a :class:`~repro.core.scheduler.Schedule`
  (coverage, dep ordering, executor overlap, executor range).
* :func:`check_plan`       — P-* rules over a :class:`~repro.core.static_host.StaticHostPlan`
  (id maps, coverage, dependency counters vs in-degrees, per-executor
  topological consistency, seed sets, counter-driven reachability — i.e.
  deadlock freedom — poison fan-out, staleness vs ``Graph.version``).
* :func:`check_segment_fifo` — E-FIFO over an :class:`~repro.core.engine.ExecutorPool`
  segment journal: concurrent plans' segments must enqueue in a consistent
  batch order on every executor (verified from evidence, not assumed).

Checkers never raise on a bad artifact — they report.  Callers that want
enforcement use ``Report.raise_if_errors()``.
"""
from __future__ import annotations

from typing import Iterable, Iterator, Mapping, Sequence

from repro.core.graph import Graph
from repro.core.scheduler import Schedule
from repro.core.static_host import StaticHostPlan

from .report import Report

__all__ = [
    "check_graph",
    "check_schedule",
    "check_plan",
    "check_segment_fifo",
    "segment_queues",
]

_EPS = 1e-12
_MAX_PER_RULE = 8   # cap repeated findings of one rule per artifact


def _kahn(nodes: Mapping[str, Sequence[str]]) -> tuple[list[str], list[str]]:
    """(topo order, leftover-in-cycle names) over ``name -> deps``.

    Local to the checker on purpose: ``Graph.topo_order`` raises on a cycle,
    and a verifier must diagnose the broken artifact, not die on it.
    """
    indeg = {n: 0 for n in nodes}
    succs: dict[str, list[str]] = {n: [] for n in nodes}
    for n, deps in nodes.items():
        for d in deps:
            if d in indeg:
                indeg[n] += 1
                succs[d].append(n)
    ready = [n for n, k in indeg.items() if k == 0]
    order: list[str] = []
    while ready:
        n = ready.pop()
        order.append(n)
        for s in succs[n]:
            indeg[s] -= 1
            if indeg[s] == 0:
                ready.append(s)
    leftover = [n for n in nodes if indeg[n] > 0]
    return order, leftover


def check_graph(graph: Graph) -> Report:
    """G-* rules: the graph is a resolvable DAG with a fresh successor cache."""
    rep = Report()
    where = graph.name
    names = set(graph.names)

    # G-DEP: every dep names a node of this graph, and not the node itself.
    # Graph.add enforces both, but checkers verify — artifacts can be built
    # by tests, deserialized, or mutated through the private dicts.
    deps_of: dict[str, Sequence[str]] = {}
    n_dep = 0
    for n in graph.names:
        node = graph[n]
        deps_of[n] = node.deps
        for d in node.deps:
            if d == n:
                n_dep += 1
                if n_dep <= _MAX_PER_RULE:
                    rep.add("G-DEP", "error", "node depends on itself",
                            where=where, node=n)
            elif d not in names:
                n_dep += 1
                if n_dep <= _MAX_PER_RULE:
                    rep.add("G-DEP", "error", f"unknown dep {d!r}",
                            where=where, node=n)

    # G-CYCLE: acyclic (Kahn leftover = the nodes on/behind a cycle)
    _, leftover = _kahn(deps_of)
    if leftover:
        rep.add("G-CYCLE", "error",
                f"{len(leftover)} node(s) unreachable under topological "
                f"order (cycle through {sorted(leftover)[:4]})", where=where)

    # G-SUCC: the successor cache agrees with the dep edges (the cache is
    # version-invalidated on add; a stale copy misroutes plan notify edges)
    succ_ref: dict[str, list[str]] = {n: [] for n in graph.names}
    for n, deps in deps_of.items():
        for d in deps:
            if d in succ_ref:
                succ_ref[d].append(n)
    n_succ = 0
    for n in graph.names:
        got = list(graph.successors(n))
        want = succ_ref[n]
        if sorted(got) != sorted(want):
            n_succ += 1
            if n_succ <= _MAX_PER_RULE:
                rep.add("G-SUCC", "error",
                        f"successor cache {got!r} != dep edges {want!r}",
                        where=where, node=n)
    return rep


class _GraphFacts:
    """Per-``Graph.version`` precomputation the plan/schedule checkers
    compare against.  Cached on the graph (like the runtime's artifact
    cache) so ``check="strict"`` re-verification of every plan build pays
    the O(V+E) derivation once per graph version, then C-level tuple
    comparisons per build."""

    __slots__ = ("names", "name_set", "ids", "is_input",
                 "arg_ids", "succ_ids", "n_wait", "input_ids", "topo_names")

    def __init__(self, g: Graph):
        names = tuple(g.names)
        ids = {n: i for i, n in enumerate(names)}
        nodes = [g[n] for n in names]
        self.names = names
        self.name_set = frozenset(names)
        self.ids = ids
        self.is_input = tuple(nd.fn is None for nd in nodes)
        self.arg_ids = tuple(
            tuple(ids.get(d, -1) for d in nd.deps) for nd in nodes)
        self.succ_ids = tuple(
            () if self.is_input[i]
            else tuple(ids[s] for s in g.successors(n))
            for i, n in enumerate(names)
        )
        self.n_wait = tuple(
            sum(1 for j in row if j >= 0 and not self.is_input[j])
            for row in self.arg_ids
        )
        self.input_ids = tuple(
            i for i in range(len(names)) if self.is_input[i])
        # does insertion order witness a topological order (every dep id
        # precedes its consumer)?  Graph.add guarantees it; a graph tampered
        # through the private dicts may not.  True proves acyclicity, which
        # lets check_plan discharge P-REACH/P-TOPO by induction instead of
        # replaying the counter protocol on the clean fast path.
        self.topo_names = all(
            0 <= j < i for i, row in enumerate(self.arg_ids) for j in row)


def _graph_facts(g: Graph) -> _GraphFacts:
    cached = g.__dict__.get("_checks_facts")
    if cached is not None and cached[0] == g.version:
        return cached[1]
    facts = _GraphFacts(g)
    g.__dict__["_checks_facts"] = (g.version, facts)
    return facts


def check_schedule(schedule: Schedule, graph: Graph) -> Report:
    """S-* rules: the schedule covers the graph exactly once and is feasible."""
    rep = Report()
    where = f"{graph.name}/{schedule.policy}"
    facts = _graph_facts(graph)
    pl = schedule.placements

    # S-COVER: every node exactly once, nothing foreign
    if pl.keys() != facts.name_set:
        placed = set(pl)
        for n in sorted(facts.name_set - placed)[:_MAX_PER_RULE]:
            rep.add("S-COVER", "error", "node missing from schedule",
                    where=where, node=n)
        for n in sorted(placed - facts.name_set)[:_MAX_PER_RULE]:
            rep.add("S-COVER", "error", "scheduled op not in graph",
                    where=where, node=n)

    # S-EXEC / S-OVERLAP detection: one C-level sort of the placement rows
    # by (executor, start); the executor range falls out of the sorted ends
    # and overlap is a single adjacent-pair pass.  The named per-node
    # diagnosis below only runs when a violation is detected.
    width = schedule.n_executors
    rows = sorted(pl.values())
    exec_bad = bool(rows) and (rows[0][0] < 0 or rows[-1][0] >= width)
    ovl_bad = any(a[0] == b[0] and a[2] > b[1] + _EPS
                  for a, b in zip(rows, rows[1:]))

    if exec_bad:
        n_exec = 0
        for n, (e, _, _) in pl.items():
            if not 0 <= e < width:
                n_exec += 1
                if n_exec <= _MAX_PER_RULE:
                    rep.add("S-EXEC", "error",
                            f"executor {e} outside [0, {width})",
                            where=where, node=n, executor=e)

    # S-DEP: every dep finishes before its consumer starts.  Placements are
    # fetched once into an id-aligned list so the per-edge loop is list
    # indexing, not dict hashing.
    get = pl.get
    recs = [get(n) for n in facts.names]
    n_dep = 0
    for i, row in enumerate(facts.arg_ids):
        if not row:
            continue
        rec = recs[i]
        if rec is None:
            continue    # already an S-COVER error
        start = rec[1] + _EPS
        for j in row:
            drec = recs[j] if j >= 0 else None   # j < 0: G-DEP's problem
            if drec is not None and drec[2] > start:
                n_dep += 1
                if n_dep <= _MAX_PER_RULE:
                    rep.add("S-DEP", "error",
                            f"starts at {rec[1]:.3e} before dep "
                            f"{facts.names[j]!r} ends at {drec[2]:.3e}",
                            where=where, node=facts.names[i])

    # S-OVERLAP diagnosis: one op at a time per executor
    if ovl_bad:
        per_exec: dict[int, list[tuple[float, float, str]]] = {}
        for n, (e, s, t) in pl.items():
            per_exec.setdefault(e, []).append((s, t, n))
        n_ovl = 0
        for e, iv in sorted(per_exec.items()):
            iv.sort()
            for (s0, t0, a), (s1, t1, b) in zip(iv, iv[1:]):
                if t0 > s1 + _EPS:
                    n_ovl += 1
                    if n_ovl <= _MAX_PER_RULE:
                        rep.add("S-OVERLAP", "error",
                                f"{a!r} [{s0:.3e},{t0:.3e}] overlaps {b!r} "
                                f"[{s1:.3e},{t1:.3e}]",
                                where=where, executor=e)
    return rep


def check_plan(plan: StaticHostPlan, graph: Graph | None = None) -> Report:
    """P-* rules over a compiled static host plan.

    Verifies the frozen integer-id artifact against the graph it claims to
    execute: a wrong dependency counter deadlocks a run (too high) or races
    an op before its inputs exist (too low); a wrong owner or missing notify
    edge strands a segment forever.  ``graph`` defaults to ``plan.graph``.
    """
    rep = Report()
    g = graph if graph is not None else plan.graph
    where = f"{g.name}/plan{plan.n_executors}"

    # P-STALE: the plan was compiled against this exact graph version
    if plan.graph_version != g.version:
        rep.add("P-STALE", "error",
                f"plan compiled at graph version {plan.graph_version}, "
                f"graph is at {g.version} — recompile", where=where)
        return rep      # id maps below are meaningless against a mutated graph

    # the expected graph-derived half of the plan (names/ids/arg_ids/
    # succ_ids/n_wait/input_ids) is cached per graph version; the fast path
    # is one C-level tuple comparison per field, and the per-node diagnostic
    # loops below only run when a comparison fails — this is what keeps
    # check="strict" inside its <10% plan-build budget
    facts = _graph_facts(g)
    n_nodes = len(facts.names)
    is_input = facts.is_input

    # P-IDS: names/ids are a bijection mirroring the graph
    if tuple(plan.names) != facts.names:
        rep.add("P-IDS", "error",
                f"plan names ({len(plan.names)}) != graph names "
                f"({len(g)})", where=where)
        return rep
    if dict(plan.ids) != facts.ids:
        for n, i in plan.ids.items():
            if not (0 <= i < n_nodes) or plan.names[i] != n:
                rep.add("P-IDS", "error",
                        f"ids[{n!r}]={i} does not invert names",
                        where=where, node=n)
                return rep

    # does the plan's graph-derived half mirror the cached facts exactly?
    # (used below to discharge P-TOPO/P-REACH by induction on the clean path)
    mirror_ok = (plan.arg_ids == facts.arg_ids
                 and plan.succ_ids == facts.succ_ids
                 and plan.n_wait == facts.n_wait)

    # P-COVER: owner/programs partition exactly the executed (non-input) ops
    owner = plan.owner
    seen = [-1] * n_nodes
    n_dup = 0
    for e, prog in enumerate(plan.programs):
        for i in prog:
            if seen[i] >= 0:
                n_dup += 1
                rep.add("P-COVER", "error",
                        f"op in programs of executors {seen[i]} and {e}",
                        where=where, node=plan.names[i], executor=e)
            seen[i] = e
            if owner[i] != e:
                rep.add("P-COVER", "error",
                        f"owner {owner[i]} != program executor {e}",
                        where=where, node=plan.names[i], executor=e)
    # no duplicates and a matching placement count ⇒ programs hold exactly
    # the executed ops iff no input was placed; the per-node scan only runs
    # when the counts disagree
    n_placed = sum(len(prog) for prog in plan.programs) - n_dup
    if n_placed != n_nodes - len(facts.input_ids) or \
            any(seen[i] >= 0 for i in facts.input_ids):
        for i in range(n_nodes):
            if is_input[i]:
                if seen[i] >= 0:
                    rep.add("P-COVER", "error",
                            "input node appears in a program",
                            where=where, node=plan.names[i])
            elif seen[i] < 0:
                rep.add("P-COVER", "error",
                        "executed op missing from programs",
                        where=where, node=plan.names[i])
    if plan.input_ids != facts.input_ids and \
            set(plan.input_ids) != set(facts.input_ids):
        rep.add("P-COVER", "error", "input_ids != fn-less nodes", where=where)

    # P-ARGS: argument ids and notify edges mirror the graph's dep edges
    if plan.arg_ids != facts.arg_ids:
        for i in range(n_nodes):
            if plan.arg_ids[i] != facts.arg_ids[i]:
                rep.add("P-ARGS", "error",
                        f"arg_ids {plan.arg_ids[i]} != deps "
                        f"{facts.arg_ids[i]}",
                        where=where, node=plan.names[i])
    if plan.succ_ids != facts.succ_ids:
        n_succ = 0
        for i in range(n_nodes):
            if set(plan.succ_ids[i]) != set(facts.succ_ids[i]):
                n_succ += 1
                if n_succ <= _MAX_PER_RULE:
                    rep.add("P-ARGS", "error",
                            f"succ_ids {sorted(plan.succ_ids[i])} != "
                            f"consumers {sorted(facts.succ_ids[i])}",
                            where=where, node=plan.names[i])

    # P-COUNTER: each counter target equals the executed-dep in-degree
    if plan.n_wait != facts.n_wait:
        for i in range(n_nodes):
            got, want = plan.n_wait[i], facts.n_wait[i]
            if got != want:
                rep.add("P-COUNTER", "error",
                        f"dependency counter {got} != executed "
                        f"in-degree {want} — run would "
                        + ("deadlock" if got > want
                           else "fire before its inputs exist"),
                        where=where, node=plan.names[i])

    # P-SEED: seeds are exactly the zero-wait ops of each program
    n_wait = plan.n_wait
    for e, prog in enumerate(plan.programs):
        want_seed = tuple(i for i in prog if n_wait[i] == 0)
        if tuple(plan.seeds[e]) != want_seed:
            rep.add("P-SEED", "error",
                    f"seeds {plan.seeds[e]} != zero-wait program ops "
                    f"{want_seed}", where=where, executor=e)

    # P-TOPO: no program lists an op after one of its dependents — the
    # frozen order must embed the dependency partial order per executor.
    # Fast path: when the plan mirrors the facts and insertion order is
    # topological (every edge points small id -> large id), a strictly
    # ascending program cannot invert an edge; only non-ascending programs
    # pay the per-edge scan.
    succ_ids = plan.succ_ids
    topo_fast = mirror_ok and facts.topo_names
    pos: list[int] | None = None
    n_topo = 0
    for e, prog in enumerate(plan.programs):
        if topo_fast and all(a < b for a, b in zip(prog, prog[1:])):
            continue
        if pos is None:
            pos = [-1] * n_nodes
            for p in plan.programs:
                for k, i in enumerate(p):
                    pos[i] = k
        for i in prog:
            pi = pos[i]
            for s in succ_ids[i]:
                if owner[s] == e and 0 <= pos[s] < pi:
                    n_topo += 1
                    if n_topo <= _MAX_PER_RULE:
                        rep.add("P-TOPO", "error",
                                f"program lists {plan.names[s]!r} before its "
                                f"dep {plan.names[i]!r}", where=where,
                                executor=e)

    # P-REACH: every op must fire under the counter protocol — the
    # deadlock-freedom proof of the plan *as compiled*.  When the plan
    # mirrors the facts exactly, the graph is provably acyclic
    # (facts.topo_names), and coverage/seeds checked clean, reachability
    # follows by induction over the topological order (each op's counter
    # target equals its executed in-degree and every producer notifies it),
    # so the replay is skipped.  Any mismatch or prior finding forces the
    # full replay, which re-detects a dropped counter or notify edge as the
    # op that never becomes ready.
    if not (mirror_ok and facts.topo_names and not rep.findings):
        fired = [False] * n_nodes
        count = [0] * n_nodes
        stack = [i for seed in plan.seeds for i in seed]
        while stack:
            i = stack.pop()
            if fired[i]:
                continue
            fired[i] = True
            for s in succ_ids[i]:
                count[s] += 1
                if count[s] >= n_wait[s]:
                    stack.append(s)
        stranded = [i for i in range(n_nodes)
                    if seen[i] >= 0 and not fired[i]]
        for i in stranded[:_MAX_PER_RULE]:
            rep.add("P-REACH", "error",
                    f"never becomes ready (counter target {n_wait[i]}, "
                    f"notifiers deliver {count[i]}) — executor "
                    f"{owner[i]}'s segment would deadlock",
                    where=where, node=plan.names[i], executor=owner[i])

    # P-POISON: the failure protocol must reach every segment — one ready
    # queue per executor in [0, n_executors), every owner in range, so
    # ``_PlanRun.fail`` poisons each segment's blocking ``get``
    if len(plan.programs) != plan.n_executors or \
            len(plan.seeds) != plan.n_executors:
        rep.add("P-POISON", "error",
                f"{len(plan.programs)} programs / {len(plan.seeds)} seed "
                f"sets for {plan.n_executors} executors — failure poison "
                "cannot reach every segment", where=where)
    # owner entries are -1 (input) or an executor id; min/max are C-level,
    # the per-node scan only runs when the range check trips
    n_execs = plan.n_executors
    if n_nodes and (min(owner) < -1 or max(owner) >= n_execs):
        for i in range(n_nodes):
            if seen[i] >= 0 and not 0 <= owner[i] < n_execs:
                rep.add("P-POISON", "error",
                        f"owner {owner[i]} outside [0, {n_execs})",
                        where=where, node=plan.names[i])
                break
    return rep


def segment_queues(
    log: Iterable[tuple[int, int, str]],
) -> dict[int, list[int]]:
    """Per-executor submission-batch order from an
    :class:`~repro.core.engine.ExecutorPool` ``segment_log``.

    The journal records ``(executor, batch, segment_name)`` per enqueued
    segment, in enqueue order, under the pool's segment lock.
    """
    queues: dict[int, list[int]] = {}
    for e, batch, _name in log:
        queues.setdefault(e, []).append(batch)
    return queues


def check_segment_fifo(
    queues: Mapping[int, Sequence[int]] | Iterable[tuple[int, int, str]],
) -> Report:
    """E-FIFO: concurrent plans' segments are FIFO-consistent across executors.

    ``submit_segments`` enqueues a whole plan's segments atomically, so the
    *batch precedence* relation observed on the executors — batch a precedes
    batch b if some executor queue holds an ``a`` segment before a ``b``
    segment — must be acyclic; a cycle means two runs would each wait on an
    executor the other holds (the deadlock the segment lock exists to
    prevent).  Accepts either the per-executor queues or a raw
    ``segment_log``.  Also flags a batch enqueued twice on one executor.
    """
    rep = Report()
    if not isinstance(queues, Mapping):
        queues = segment_queues(queues)

    edges: dict[int, set[int]] = {}
    for e, q in sorted(queues.items()):
        seen: set[int] = set()
        for a, b in zip(q, q[1:]):
            if a != b:
                edges.setdefault(a, set()).add(b)
        for batch in q:
            if batch in seen:
                rep.add("E-FIFO", "error",
                        f"batch {batch} enqueued twice on one executor",
                        executor=e)
            seen.add(batch)

    # cycle detection over the precedence relation (iterative 3-color DFS)
    WHITE, GRAY, BLACK = 0, 1, 2
    color: dict[int, int] = {}
    for root in edges:
        if color.get(root, WHITE) != WHITE:
            continue
        stack: list[tuple[int, Iterator]] = [(root, iter(edges.get(root, ())))]
        color[root] = GRAY
        while stack:
            node, it = stack[-1]
            advanced = False
            for nxt in it:
                c = color.get(nxt, WHITE)
                if c == GRAY:
                    rep.add("E-FIFO", "error",
                            f"segment batches {node} and {nxt} enqueued in "
                            "opposite orders on different executors — "
                            "cross-plan deadlock")
                    continue
                if c == WHITE:
                    color[nxt] = GRAY
                    stack.append((nxt, iter(edges.get(nxt, ()))))
                    advanced = True
                    break
            if not advanced:
                color[node] = BLACK
                stack.pop()
    if rep.ok and queues:
        n_seg = sum(len(q) for q in queues.values())
        rep.add("E-FIFO", "info",
                f"{n_seg} segment enqueues over {len(queues)} executors: "
                "batch order consistent")
    return rep
