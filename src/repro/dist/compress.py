"""Compressed cross-replica gradient reduction with error feedback.

The multi-pod mesh's ``pod`` axis crosses DCN (launch/mesh.py), where
gradient all-reduces are bandwidth-bound; 8-bit quantization cuts the wire
format 4x.  Plain quantized reduction biases training, so we carry the
per-shard quantization residual forward (error feedback, Seide et al. /
Karimireddy et al.): what this step rounds away is added back before the
next step's quantization, making the *accumulated* gradient unbiased.

``compressed_psum`` is a ``shard_map`` collective: each shard contributes
its local gradient block, the wire carries int8 codes + one f32 scale, and
every shard reconstructs the mean.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum"]


def compressed_psum(
    g: jax.Array,
    err: jax.Array,
    *,
    axis_name: str,
    bits: int = 8,
) -> tuple[jax.Array, jax.Array]:
    """Mean of ``g`` over ``axis_name`` through a ``bits``-wide codebook.

    Returns ``(mean, new_err)``: the dequantized cross-shard mean (same
    shape as the local ``g``) and this shard's new quantization residual,
    to be fed back as ``err`` on the next call.
    """
    if not 2 <= bits <= 8:
        raise ValueError(f"bits={bits}: int8 wire format supports 2..8 bits")
    comp = g.astype(jnp.float32) + err.astype(jnp.float32)
    levels = float(2 ** (bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(comp)) / levels, 1e-12)  # scalar/shard
    q = jnp.clip(jnp.round(comp / scale), -levels, levels)
    new_err = comp - q * scale

    # wire format: int8 codes + one f32 scale per shard (the compression)
    codes = jax.lax.all_gather(q.astype(jnp.int8), axis_name)   # [n, ...]
    scales = jax.lax.all_gather(scale, axis_name)               # [n]
    n = codes.shape[0]
    bshape = (n,) + (1,) * g.ndim
    mean = (codes.astype(jnp.float32) * scales.reshape(bshape)).sum(0) / n
    return mean, new_err
