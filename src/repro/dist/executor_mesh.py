"""Slot → executor sub-mesh bridge (DESIGN.md §2.1).

The paper's executors are disjoint worker teams; on an SPMD mesh they are
disjoint *sub-meshes*.  This module maps the scheduler's static plan
(``core.scheduler.slot_assignment`` — barrier-separated groups of mutually
independent ops, each at most ``n_executors`` wide) onto real device
placement, two ways:

* **disjoint sub-meshes** (:func:`executor_groups` / :func:`plan_from_schedule`)
  — each slot lane owns a contiguous slice of one mesh axis; independent ops
  of a slot run simultaneously with zero resource overlap (the paper's
  interference-free condition, §1/§6).
* **stacked execution** (:func:`executor_stacked_mesh` / :func:`lane_pspec`)
  — the lanes of a slot are stacked on a leading array axis and that axis is
  sharded over an ``executor`` mesh axis: one SPMD program, spatially
  multiplexed, which is how ``core.wavefront.stacked_wavefront_lstm`` runs a
  whole anti-diagonal per step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import Graph
from repro.core.scheduler import Schedule, slot_assignment

__all__ = [
    "ExecutorGroup",
    "ExecutorMeshPlan",
    "pick_executor_axis",
    "executor_groups",
    "executor_stacked_mesh",
    "lane_pspec",
    "plan_from_schedule",
]


@dataclass(frozen=True)
class ExecutorGroup:
    """One executor: a disjoint sub-mesh slice of the parent mesh."""

    index: int
    mesh: Mesh
    device_ids: tuple[int, ...]


@dataclass(frozen=True)
class ExecutorMeshPlan:
    """A frozen Graphi schedule bound to device placement.

    ``slots[s]`` lists the ops of barrier slot ``s``; op at lane ``k`` runs
    on ``groups[k]``; ``placement`` is the flattened op -> group index map.
    """

    groups: tuple[ExecutorGroup, ...]
    slots: tuple[tuple[str, ...], ...]
    placement: dict[str, int]

    @property
    def n_executors(self) -> int:
        return len(self.groups)

    def group_of(self, op: str) -> ExecutorGroup:
        return self.groups[self.placement[op]]


def pick_executor_axis(mesh: Mesh, n_executors: int) -> str:
    """The axis executor groups slice: ``model`` when it divides (TP stays
    intra-group, the paper's team locality), else the largest divisible axis."""
    names = tuple(mesh.axis_names)
    if "model" in names and mesh.shape["model"] % n_executors == 0:
        return "model"
    cands = [a for a in names if mesh.shape[a] % n_executors == 0]
    if not cands:
        raise ValueError(
            f"no mesh axis of {dict(mesh.shape)} divisible by {n_executors} executors"
        )
    return max(cands, key=lambda a: mesh.shape[a])


def _resolve_axis(mesh: Mesh, n_executors: int, axis: str | None) -> tuple[str, int]:
    """(axis name, its index) for an executor split, divisibility-checked."""
    ax = axis or pick_executor_axis(mesh, n_executors)
    if mesh.shape[ax] % n_executors != 0:
        raise ValueError(f"axis {ax}={mesh.shape[ax]} not divisible by {n_executors}")
    return ax, tuple(mesh.axis_names).index(ax)


def executor_groups(
    mesh: Mesh, n_executors: int, *, axis: str | None = None
) -> list[ExecutorGroup]:
    """Split ``mesh`` into ``n_executors`` disjoint sub-meshes along ``axis``.

    Group ``g`` keeps the full extent of every other axis and a contiguous
    ``1/n_executors`` slice of ``axis`` (ICI-contiguous on a torus), so the
    union of groups is exactly the parent mesh and intersections are empty.
    """
    ax, i = _resolve_axis(mesh, n_executors, axis)
    per = mesh.shape[ax] // n_executors
    devs = mesh.devices
    groups = []
    for g in range(n_executors):
        sl: list[Any] = [slice(None)] * devs.ndim
        sl[i] = slice(g * per, (g + 1) * per)
        sub = devs[tuple(sl)]
        groups.append(
            ExecutorGroup(
                index=g,
                mesh=Mesh(sub, mesh.axis_names),
                device_ids=tuple(int(d.id) for d in sub.flat),
            )
        )
    return groups


def executor_stacked_mesh(
    mesh: Mesh, n_executors: int, *, axis: str | None = None
) -> Mesh:
    """Reshape ``axis`` (size A) into ``("executor", axis)`` = (E, A/E): the
    mesh for slot-stacked execution, where a slot's lanes live on a leading
    array axis sharded over ``executor`` (one program, disjoint partitions)."""
    ax, i = _resolve_axis(mesh, n_executors, axis)
    devs = mesh.devices
    new_shape = (
        devs.shape[:i] + (n_executors, devs.shape[i] // n_executors) + devs.shape[i + 1:]
    )
    names = tuple(mesh.axis_names[:i]) + ("executor", ax) + tuple(mesh.axis_names[i + 1:])
    return Mesh(devs.reshape(new_shape), names)


def lane_pspec(rank: int) -> P:
    """Spec for a slot-stacked array [n_lanes, ...]: lanes over ``executor``."""
    return P(*(("executor",) + (None,) * max(0, rank - 1)))


def plan_from_schedule(
    graph: Graph, schedule: Schedule, mesh: Mesh, *, axis: str | None = None
) -> ExecutorMeshPlan:
    """Bind a :class:`Schedule` to devices: derive the barrier slots and give
    lane ``k`` of every slot the ``k``-th executor sub-mesh.

    Lane order within a slot follows the schedule's start order (how
    ``slot_assignment`` emits it), so at most ``schedule.n_executors`` lanes
    exist and ops sharing a slot never share a group — the static-plan
    analogue of the paper's one-op-per-executor invariant.
    """
    slots = slot_assignment(graph, schedule)
    groups = executor_groups(mesh, schedule.n_executors, axis=axis)
    placement: dict[str, int] = {}
    for slot in slots:
        for lane, op in enumerate(slot):
            placement[op] = lane
    return ExecutorMeshPlan(
        groups=tuple(groups),
        slots=tuple(tuple(s) for s in slots),
        placement=placement,
    )
