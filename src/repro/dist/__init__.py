"""``repro.dist`` — the sharding / collectives subsystem.

Graphi's premise is that independent ops pay off only when they run on
*disjoint* resource partitions (paper §1); on an SPMD mesh the partitioning
layer IS the interference-isolation mechanism.  This package is that layer:

* :mod:`repro.dist.sharding` — logical-axis mesh context (``MeshCtx`` /
  ``use_mesh`` / ``shard``) plus the PartitionSpec factories every launch
  path lowers through (``param_pspecs``, ``state_pspecs``, ``batch_pspecs``,
  ``cache_pspecs``, ``batch_axes``).
* :mod:`repro.dist.overlap` — compute/communication-overlapped collective
  matmuls (``ring_allgather_matmul`` / ``ring_reducescatter_matmul``).
* :mod:`repro.dist.compress` — gradient compression (``compressed_psum``)
  with error feedback for the DCN-crossing ``pod`` axis.
* :mod:`repro.dist.executor_mesh` — the bridge from the scheduler's barrier
  slots (``core.scheduler.slot_assignment``) to disjoint executor sub-meshes
  (DESIGN.md §2.1).
"""
from . import compress, executor_mesh, overlap, sharding
from .executor_mesh import (
    ExecutorGroup,
    ExecutorMeshPlan,
    executor_groups,
    executor_stacked_mesh,
    plan_from_schedule,
)
from .sharding import (
    MeshCtx,
    batch_axes,
    batch_pspecs,
    cache_pspecs,
    mesh_context,
    param_pspecs,
    shard,
    state_pspecs,
    use_mesh,
)

__all__ = [
    "compress",
    "executor_mesh",
    "overlap",
    "sharding",
    "ExecutorGroup",
    "ExecutorMeshPlan",
    "executor_groups",
    "executor_stacked_mesh",
    "plan_from_schedule",
    "MeshCtx",
    "batch_axes",
    "batch_pspecs",
    "cache_pspecs",
    "mesh_context",
    "param_pspecs",
    "shard",
    "state_pspecs",
    "use_mesh",
]
