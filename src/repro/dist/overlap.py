"""Compute/communication-overlapped collective matmuls (``shard_map`` body).

The Graphi argument applied to collectives: a blocking all-gather before a
matmul serializes communication and compute on the same "executor"; the ring
formulation below decomposes both into per-shard chunks so each ``ppermute``
hop is in flight while the previous chunk's partial matmul runs (the
"collective matmul" of Wang et al., and the TPU pattern XLA's latency-hiding
scheduler overlaps).  Both functions are numerically exact — chunk order
only changes summation order of disjoint blocks.

Usage (under ``shard_map``; see tests/test_dist_multidevice.py)::

    f = shard_map(partial(ring_allgather_matmul, axis_name="model"), mesh=mesh,
                  in_specs=(P("model", None), P(None, "model")),
                  out_specs=P(None, "model"))
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ring_allgather_matmul", "ring_reducescatter_matmul"]


def _ring_perm(n: int, *, forward: bool) -> list[tuple[int, int]]:
    step = 1 if forward else -1
    return [(j, (j + step) % n) for j in range(n)]


def ring_allgather_matmul(x: jax.Array, w: jax.Array, *, axis_name: str) -> jax.Array:
    """``allgather(x, axis) @ w`` without materializing the gather barrier.

    Per shard: ``x`` holds rows [m, k] of the [n*m, k] global operand, ``w``
    a column block [k, c].  Each of the ``n`` steps multiplies the row chunk
    currently held and forwards it around the ring; the next hop is issued
    *before* the local matmul so the transfer overlaps the compute.
    Returns the full-row output [n*m, c] (out_specs gathers rows).
    """
    n = jax.lax.psum(1, axis_name)  # static: mesh extent
    idx = jax.lax.axis_index(axis_name)
    m = x.shape[0]
    perm = _ring_perm(n, forward=False)  # receive from idx+1
    out = jnp.zeros((n * m, w.shape[1]), jnp.result_type(x, w))
    cur = x
    for i in range(n):
        nxt = jax.lax.ppermute(cur, axis_name, perm) if i + 1 < n else None
        src = jax.lax.rem(idx + i, n)  # whose rows we currently hold
        blk = jnp.dot(cur, w).astype(out.dtype)
        out = jax.lax.dynamic_update_slice(out, blk, (src * m, 0))
        cur = nxt
    return out


def ring_reducescatter_matmul(x: jax.Array, w: jax.Array, *, axis_name: str) -> jax.Array:
    """``reducescatter(x @ w, axis)`` with the partial-sum ring fused in.

    Per shard: ``x`` holds a column block [M, k], ``w`` a row block [k, c];
    the full product is the sum over shards of ``x_j @ w_j``.  The
    accumulator for output-row chunk ``b`` starts at shard ``b+1`` and walks
    the ring forward, each shard adding its own contribution to that chunk
    before passing it on, so chunk ``b`` lands fully-reduced on shard ``b``
    after ``n-1`` hops.  Returns the local output-row chunk [M/n, c].
    """
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    if x.shape[0] % n != 0:
        raise ValueError(f"rows {x.shape[0]} not divisible by ring size {n}")
    rows = x.shape[0] // n
    perm = _ring_perm(n, forward=True)

    def block(b: jax.Array) -> jax.Array:
        xb = jax.lax.dynamic_slice(x, (b * rows, 0), (rows, x.shape[1]))
        return jnp.dot(xb, w)

    acc = block(jax.lax.rem(idx - 1 + n, n))
    for i in range(1, n):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        acc = acc + block(jax.lax.rem(idx - 1 - i + 2 * n, n))
    return acc
