"""Logical-axis sharding: mesh context management + PartitionSpec factories.

The models layer speaks *logical* axes ("batch", "seq", "model", "attn_seq");
this module owns the mapping onto physical mesh axes.  Everything degrades to
a no-op without an active mesh, so the same model code runs single-device
smoke tests and 512-chip dry-runs unchanged (DESIGN.md §4).

Key behaviours:

* ``shard(x, *logical)`` applies ``with_sharding_constraint`` and silently
  **drops** any logical axis whose mesh extent does not divide the dimension
  (e.g. sequence-parallel residual streams when ``S % tp != 0``) or whose
  mesh axes were already consumed by an earlier dimension.
* ``param_pspecs(..., fsdp=True)`` adds ZeRO-3: on top of the tensor-parallel
  rules, the largest still-replicated dimension of every leaf is sharded
  over the ``data`` axis (moments included via ``state_pspecs``).
"""
from __future__ import annotations

from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass
from typing import Any, Iterator, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "MeshCtx",
    "use_mesh",
    "mesh_context",
    "shard",
    "batch_axes",
    "param_pspecs",
    "state_pspecs",
    "batch_pspecs",
    "cache_pspecs",
]

# mesh axes that never carry the batch dimension (tensor/executor parallel)
_NON_BATCH_AXES = frozenset({"model", "executor"})


def batch_axes(mesh: Any, global_batch: int) -> tuple[str, ...]:
    """Mesh axes the batch dimension shards over: every non-model axis, in
    mesh order, as long as the running product still divides the batch
    (``long_500k``'s B=1 legitimately returns ``()``)."""
    out: list[str] = []
    prod = 1
    for a in mesh.axis_names:
        if a in _NON_BATCH_AXES:
            continue
        size = mesh.shape[a]
        if size > 1 and global_batch % (prod * size) == 0:
            out.append(a)
            prod *= size
    return tuple(out)


def _resolve(
    logical: str | None, mesh: Any, batch: tuple[str, ...], seq: str | None
) -> tuple[str, ...]:
    """Logical axis name -> physical mesh axes (possibly empty)."""
    if logical is None:
        return ()
    names = tuple(mesh.axis_names)
    if logical == "batch":
        return tuple(a for a in batch if a in names)
    if logical == "seq":
        return (seq,) if seq and seq in names else ()
    if logical in ("model", "attn_seq"):
        # attn_seq: independent q rows over the model axis (the MQA path)
        return ("model",) if "model" in names else ()
    if logical in names:
        return (logical,)
    return ()


def _entry(axes: Sequence[str]) -> Any:
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def _build_spec(
    shape: Sequence[int],
    logical: Sequence[str | None],
    mesh: Any,
    batch: tuple[str, ...] = (),
    seq: str | None = None,
) -> P:
    """Resolve a logical spec against concrete dims: per-dim, keep the
    greedy prefix of mesh axes whose cumulative extent divides the dim and
    that no earlier dim consumed."""
    used: set[str] = set()
    entries: list[Any] = []
    for dim, l in zip(shape, logical):
        keep: list[str] = []
        prod = 1
        for a in _resolve(l, mesh, batch, seq):
            size = mesh.shape[a]
            if a in used or size <= 0 or dim % (prod * size) != 0:
                break
            keep.append(a)
            prod *= size
        used.update(keep)
        entries.append(_entry(keep))
    return P(*entries)


@dataclass(frozen=True)
class MeshCtx:
    """An activated mesh plus the logical->physical axis bindings for one
    cell: which axes carry the batch, and whether the residual-stream
    sequence dim is sharded (Megatron-SP)."""

    mesh: Any
    batch: tuple[str, ...] = ()
    seq: str | None = None

    def resolve(self, logical: str | None) -> tuple[str, ...]:
        return _resolve(logical, self.mesh, tuple(self.batch), self.seq)

    def extent(self, axes: str | Sequence[str] | None) -> int:
        """Product of mesh extents for the given physical axes."""
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= int(self.mesh.shape[a])
        return n

    def pspec(self, shape: Sequence[int], logical: Sequence[str | None]) -> P:
        return _build_spec(shape, logical, self.mesh, tuple(self.batch), self.seq)


_CURRENT: ContextVar[MeshCtx | None] = ContextVar("repro_mesh_ctx", default=None)


def mesh_context() -> MeshCtx | None:
    """The active :class:`MeshCtx`, or None (single-device / smoke paths)."""
    return _CURRENT.get()


@contextmanager
def use_mesh(ctx: MeshCtx | None) -> Iterator[MeshCtx | None]:
    """Activate ``ctx`` for the dynamic extent (tracing included): every
    ``shard`` call inside resolves against it."""
    token = _CURRENT.set(ctx)
    try:
        yield ctx
    finally:
        _CURRENT.reset(token)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names; no-op without an
    active mesh.  One logical name (or None) per dimension."""
    ctx = mesh_context()
    if ctx is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"shard: {len(logical)} axes for rank-{x.ndim} array")
    spec = ctx.pspec(x.shape, logical)
    if all(e is None for e in spec):
        return x  # fully replicated constraint would only pessimize GSPMD
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# PartitionSpec factories (the launch layer's acceptance contract)
# ---------------------------------------------------------------------------

# Tensor-parallel rules per *leaf name*: logical spec for the core (unstacked)
# rank; scan-stacked leaves get a leading None via padding.  Megatron layout:
# qkv/gate/up column-parallel, o/down row-parallel; embeddings vocab-sharded.
_PARAM_RULES: dict[str, tuple[str | None, ...]] = {
    # attention
    "wq": (None, "model"),
    "wk": (None, "model"),
    "wv": (None, "model"),
    "wo": ("model", None),
    # dense GLU ffn
    "w_gate": (None, "model"),
    "w_up": (None, "model"),
    "w_down": ("model", None),
    # embeddings / unembedding
    "embed": ("model", None),
    "unembed": (None, "model"),
    # mamba (channel dim d_inner over model)
    "in_proj": (None, "model"),
    "x_proj": ("model", None),
    "dt_proj": (None, "model"),
    "out_proj": ("model", None),
    "A_log": ("model", None),
    "D": ("model",),
    "dt_bias": ("model",),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    # RG-LRU (width dim over model)
    "w_y": (None, "model"),
    "w_x": (None, "model"),
    "w_r": (None, "model"),
    "w_i": (None, "model"),
    "w_o": ("model", None),
    "lam": ("model",),
    # router stays replicated (tiny, fp32, every shard routes)
    "router": (None, None),
}

# MoE expert weights: [E, d, f] (+L) — experts ARE the executor groups
# (DESIGN.md §6), sharded over the model axis.
_MOE_RULES: dict[str, tuple[str | None, ...]] = {
    "w_gate": ("model", None, None),
    "w_up": ("model", None, None),
    "w_down": ("model", None, None),
}


def _leaf_name(path: tuple) -> str:
    for e in reversed(path):
        if isinstance(e, jax.tree_util.DictKey):
            return str(e.key)
        if isinstance(e, jax.tree_util.GetAttrKey):
            return str(e.name)
    return ""


def _shape_of(leaf: Any) -> tuple[int, ...]:
    return tuple(leaf.shape) if hasattr(leaf, "shape") else ()


def _fsdp_axis(mesh: Any) -> str | None:
    names = tuple(mesh.axis_names)
    if "data" in names:
        return "data"
    for a in names:
        if a not in _NON_BATCH_AXES:
            return a
    return None


def _param_rule(cfg: Any, name: str, rank: int) -> tuple[str | None, ...]:
    rule = _PARAM_RULES.get(name)
    if getattr(cfg, "n_experts", 0) and name in _MOE_RULES and rank >= 3:
        rule = _MOE_RULES[name]
    if rule is None or rank < len(rule):
        return (None,) * rank
    return (None,) * (rank - len(rule)) + rule


def _apply_fsdp(shape: Sequence[int], spec: P, mesh: Any) -> P:
    """ZeRO-3: shard the largest still-replicated dim over the data axis."""
    axis = _fsdp_axis(mesh)
    if axis is None:
        return spec
    extent = int(mesh.shape[axis])
    if extent <= 1 or any(
        axis in ((e,) if isinstance(e, str) else tuple(e or ()))
        for e in spec
    ):
        return spec
    best, best_size = -1, 0
    for i, (dim, e) in enumerate(zip(shape, spec)):
        if e is None and dim % extent == 0 and dim > best_size:
            best, best_size = i, dim
    if best < 0:
        return spec
    entries = list(spec)
    entries[best] = axis
    return P(*entries)


def param_pspecs(cfg: Any, shapes: Any, mesh: Any, *, fsdp: bool = False) -> Any:
    """PartitionSpec pytree mirroring a params (or train-state) pytree.

    Tensor-parallel Megatron rules by leaf name; ``fsdp=True`` additionally
    shards every leaf's largest replicated dim over ``data`` (ZeRO-3).
    Indivisible dims stay replicated — the factories see concrete shapes, so
    aggressive rules are safe.
    """

    def one(path: tuple, leaf: Any) -> P:
        shape = _shape_of(leaf)
        name = _leaf_name(path)
        if name == "step":
            return P()
        spec = _build_spec(shape, _param_rule(cfg, name, len(shape)), mesh)
        if fsdp:
            spec = _apply_fsdp(shape, spec, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(one, shapes)


def state_pspecs(cfg: Any, state_shapes: Any, mesh: Any, *, fsdp: bool = False) -> Any:
    """Specs for the train state ``{"params", "m", "v", "step"}`` — moments
    inherit the parameter rules (fsdp shards them too: that's the ZeRO part),
    ``step`` is replicated."""
    return param_pspecs(cfg, state_shapes, mesh, fsdp=fsdp)


def batch_pspecs(batch_shapes: Any, mesh: Any, global_batch: int) -> Any:
    """Input batches: leading dim over the data axes, rest replicated."""
    bt = batch_axes(mesh, global_batch)

    def one(leaf: Any) -> P:
        shape = _shape_of(leaf)
        rule = ("batch",) + (None,) * max(0, len(shape) - 1)
        return _build_spec(shape, rule[: len(shape)], mesh, bt)

    return jax.tree.map(one, batch_shapes)


# Cache rules by leaf name (core rank, i.e. without the scan-layer stack dim):
# KV caches are *sequence*-sharded over the model axis so MQA archs scale too
# (serve/step.py); recurrent state caches shard their channel dim.
_CACHE_RULES: dict[str, tuple[str | None, ...]] = {
    "k": ("batch", "model", None, None),
    "v": ("batch", "model", None, None),
    "pos": (None,),
    # "h" is rank-dispatched in cache_pspecs (mamba rank-3 vs RG-LRU rank-2)
    "conv": ("batch", None, "model"),
    "enc": ("batch", None, None),
}


def cache_pspecs(cfg: Any, cache_shapes: Any, mesh: Any, global_batch: int) -> Any:
    """Specs for a decode/prefill cache pytree (``transformer.init_cache``)."""
    bt = batch_axes(mesh, global_batch)
    stacked = bool(getattr(cfg, "scan_layers", False)) and bool(
        getattr(cfg, "is_homogeneous", False)
    )

    def one(path: tuple, leaf: Any) -> P:
        shape = _shape_of(leaf)
        name = _leaf_name(path)
        if name == "len":
            return P()
        under_layers = any(
            isinstance(e, jax.tree_util.DictKey) and str(e.key) == "layers"
            for e in path
        )
        pad = 1 if (stacked and under_layers) else 0
        core = len(shape) - pad  # rank without the scan-layer stack dim
        if name == "h":
            # mamba state [B, d_inner, state] vs RG-LRU state [B, width]
            rule = {2: ("batch", "model"), 3: ("batch", "model", None)}.get(core)
        else:
            rule = _CACHE_RULES.get(name)
        if rule is None or core != len(rule):
            return P(*([None] * len(shape)))
        return _build_spec(shape, (None,) * pad + tuple(rule), mesh, bt)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)
