"""Mamba selective-scan Pallas kernel.

The GPU reference implementation is a fused CUDA scan over shared memory;
the TPU-native translation (DESIGN.md §2) keeps the chunk resident in VMEM
and replaces the per-thread sequential loop with a **within-chunk
associative scan** (log2(bs) VPU passes) — sequential chains don't
vectorize on the VPU, associative combines do.  The recurrent state h is
carried across sequence chunks in VMEM scratch (grid's innermost,
``arbitrary`` axis), so HBM traffic is exactly one read of a/b/c and one
write of y: the memory roofline for this op.

grid = (B, D/bd, S/bs); VMEM per step: a,b tiles [bs, bd, St] f32 +
h scratch [bd, St].  Defaults bs=128, bd=128, St=16 -> ~2.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssm_scan_kernel_call"]


def _combine(x, y):
    a1, b1 = x
    a2, b2 = y
    return a1 * a2, a2 * b1 + b2


def _kernel(a_ref, b_ref, c_ref, y_ref, hlast_ref, h_ref, *, n_seq: int):
    isq = pl.program_id(2)

    @pl.when(isq == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)      # [bs, bd, St]
    b = b_ref[0].astype(jnp.float32)
    c = c_ref[0].astype(jnp.float32)      # [bs, St]

    a_cum, b_scan = jax.lax.associative_scan(_combine, (a, b), axis=0)
    hs = a_cum * h_ref[...][None] + b_scan           # [bs, bd, St]
    y_ref[0] = (hs * c[:, None, :]).sum(axis=-1).astype(y_ref.dtype)
    h_ref[...] = hs[-1]

    @pl.when(isq == n_seq - 1)
    def _done():
        hlast_ref[0] = h_ref[...].astype(hlast_ref.dtype)


def ssm_scan_kernel_call(
    a: jax.Array,  # [B, S, D, St]
    b: jax.Array,
    c: jax.Array,  # [B, S, St]
    *,
    block_d: int,
    block_s: int,
    interpret: bool,
):
    B, S, D, St = a.shape
    bd = min(block_d, D)
    bs = min(block_s, S)
    if D % bd != 0 or S % bs != 0:
        raise ValueError(f"block sizes must tile the array: D={D} bd={bd} S={S} bs={bs}")
    grid = (B, D // bd, S // bs)

    kern = functools.partial(_kernel, n_seq=S // bs)
    y, h_last = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bd, St), lambda bb, id_, is_: (bb, is_, id_, 0)),
            pl.BlockSpec((1, bs, bd, St), lambda bb, id_, is_: (bb, is_, id_, 0)),
            pl.BlockSpec((1, bs, St), lambda bb, id_, is_: (bb, is_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bd), lambda bb, id_, is_: (bb, is_, id_)),
            pl.BlockSpec((1, bd, St), lambda bb, id_, is_: (bb, id_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D, St), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, St), jnp.float32)],
        interpret=interpret,
    )(a, b, c)
    return y, h_last
