"""Pure-jnp oracle for the Mamba selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssm_scan_ref"]


def ssm_scan_ref(
    a: jax.Array,  # [B, S, D, St] decay (exp(dt*A))
    b: jax.Array,  # [B, S, D, St] input contribution (dt*B*x)
    c: jax.Array,  # [B, S, St]    output projection
    h0: jax.Array,  # [B, D, St]
):
    """h_t = a_t * h_{t-1} + b_t;   y_t = sum_s h_t[:, s] * c_t[s].

    Returns (y [B, S, D] f32, h_last [B, D, St] f32).
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)

    def step(h, inp):
        a_t, b_t, c_t = inp
        h = a_t * h + b_t
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    h, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a.transpose(1, 0, 2, 3), b.transpose(1, 0, 2, 3), c.transpose(1, 0, 2)),
    )
    return ys.transpose(1, 0, 2), h
