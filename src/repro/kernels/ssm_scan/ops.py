"""jit-able wrapper for the selective-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import default_interpret
from .kernel import ssm_scan_kernel_call

__all__ = ["ssm_scan"]


@partial(jax.jit, static_argnames=("block_d", "block_s", "interpret"))
def ssm_scan(
    a: jax.Array,  # [B, S, D, St]
    b: jax.Array,
    c: jax.Array,  # [B, S, St]
    *,
    block_d: int = 128,
    block_s: int = 128,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = default_interpret()
    return ssm_scan_kernel_call(
        a, b, c, block_d=block_d, block_s=block_s, interpret=interpret
    )
