from .ops import ssm_scan

__all__ = ["ssm_scan"]
