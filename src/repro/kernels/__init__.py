"""Pallas TPU kernels for the compute hot-spots.

Each kernel ships as a subpackage: ``kernel.py`` (pl.pallas_call + explicit
BlockSpec VMEM tiling), ``ops.py`` (the jit-able wrapper with shape policy),
``ref.py`` (the pure-jnp oracle every test asserts against).

On this CPU-only container the kernels execute through ``interpret=True``
(the kernel body runs in Python per grid step).  ``default_interpret()``
resolves the mode from the backend; the models call the pure-jnp paths by
default (same math as ref.py) and switch to the kernels when
``REPRO_USE_PALLAS=1`` or a TPU backend is present — interpret-mode kernels
inside a 40-cell dry-run would only slow compilation without changing the
lowered collectives.
"""
from __future__ import annotations

import os

import jax

__all__ = ["default_interpret", "kernels_enabled"]


def default_interpret() -> bool:
    """interpret=True everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def kernels_enabled() -> bool:
    """Should the model layers route through the Pallas kernels?"""
    if os.environ.get("REPRO_USE_PALLAS", "") == "1":
        return True
    return jax.default_backend() == "tpu"
