"""Flash-decode Pallas kernel: one query token vs a long KV cache.

Decode is memory-bound (arithmetic intensity ~1 flop/byte: every cached
K/V byte is read once per step), so the tiling goal is pure streaming:
grid = (B, S/bk) with the KV axis innermost carrying the online-softmax
state; all Hq heads of a batch element are processed per tile (q is tiny).

Slot-position masking (``kv_pos`` per cache slot, -1 = empty) makes the
same kernel serve linear caches and the ring buffers of sliding-window
archs.  VMEM per step with bk=512, Hkv*hd<=8k: k/v tiles ~8 MB bf16 —
the tile streams at HBM bandwidth, which IS the roofline for this op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_kernel_call", "paged_decode_attention_kernel_call"]

_NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, pos_ref, qpos_ref, o_ref, acc_ref, m_ref, l_ref,
    *, n_kv: int, G: int, window: int | None, scale: float,
):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale     # [Hq, hd]
    k = k_ref[0]                                 # [bk, Hkv, hd]
    v = v_ref[0]
    kv_pos = pos_ref[...]                        # [bk]
    q_pos = qpos_ref[0]

    Hq, hd = q.shape
    bk, Hkv, _ = k.shape
    qg = q.reshape(Hkv, G, hd)
    # s[h, g, c] = sum_d qg[h,g,d] * k[c,h,d]
    s = jax.lax.dot_general(
        qg, k.astype(jnp.float32),
        (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32,
    )                                            # [Hkv, G, bk]
    keep = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window is not None:
        keep &= kv_pos > q_pos - window
    s = jnp.where(keep[None, None, :], s, _NEG_INF)

    sm = s.reshape(Hq, bk)
    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_cur = jnp.maximum(m_prev, sm.max(axis=-1, keepdims=True))
    p = jnp.exp(sm - m_cur)                      # [Hq, bk]
    corr = jnp.exp(m_prev - m_cur)
    l_cur = l_prev * corr + p.sum(axis=-1, keepdims=True)
    # pv[h, g, d] = sum_c p[h,g,c] * v[c,h,d]
    pv = jax.lax.dot_general(
        p.reshape(Hkv, G, bk), v.astype(jnp.float32),
        (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32,
    )                                            # [Hkv, G, hd]
    acc_ref[...] = acc_ref[...] * corr + pv.reshape(Hq, hd)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)

    @pl.when(ik == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_kernel_call(
    q: jax.Array,        # [B, Hq, hd]
    k_cache: jax.Array,  # [B, S, Hkv, hd]
    v_cache: jax.Array,
    kv_pos: jax.Array,   # [S] int32
    q_pos: jax.Array,    # [] int32
    *,
    window: int | None,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    B, Hq, hd = q.shape
    _, S, Hkv, _ = k_cache.shape
    if Hq % Hkv != 0:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    G = Hq // Hkv
    bk = min(block_k, S)
    if S % bk != 0:
        raise ValueError(f"block_k must tile the cache: S={S} bk={bk}")
    n_kv = S // bk

    kern = functools.partial(
        _kernel, n_kv=n_kv, G=G, window=window, scale=hd ** -0.5,
    )
    return pl.pallas_call(
        kern,
        grid=(B, n_kv),
        in_specs=[
            pl.BlockSpec((1, Hq, hd), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, bk, Hkv, hd), lambda b, ik: (b, ik, 0, 0)),
            pl.BlockSpec((1, bk, Hkv, hd), lambda b, ik: (b, ik, 0, 0)),
            pl.BlockSpec((bk,), lambda b, ik: (ik,)),
            pl.BlockSpec((1,), lambda b, ik: (0,)),
        ],
        out_specs=pl.BlockSpec((1, Hq, hd), lambda b, ik: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hq, hd), jnp.float32),
            pltpu.VMEM((Hq, 128), jnp.float32),
            pltpu.VMEM((Hq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, kv_pos, q_pos.reshape(1))


# ---------------------------------------------------------------------------
# paged variant: the KV lives in a global page pool [P, ps, Hkv, hd] and each
# batch row owns a page *table*.  The table is a scalar-prefetch operand
# (PrefetchScalarGridSpec), so the BlockSpec index map itself chases the
# table: grid step (b, ip) DMAs physical page table[b, ip] — the kernel
# never materializes a gathered [B, S] cache, it streams exactly the pages
# the row owns.  Unmapped entries (table[b, ip] < 0) clamp to page 0 for the
# DMA and are masked out of the online softmax in the body.
# ---------------------------------------------------------------------------

def _paged_kernel(
    table_ref, qpos_ref,                 # scalar-prefetch: [B, n_pt], [B]
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, n_pt: int, ps: int, G: int, window: int | None, scale: float,
):
    b = pl.program_id(0)
    ip = pl.program_id(1)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale     # [Hq, hd]
    k = k_ref[0]                                 # [ps, Hkv, hd]
    v = v_ref[0]
    page = table_ref[b, ip]
    q_pos = qpos_ref[b]
    kv_pos = ip * ps + jax.lax.broadcasted_iota(jnp.int32, (ps,), 0)

    Hq, hd = q.shape
    _, Hkv, _ = k.shape
    qg = q.reshape(Hkv, G, hd)
    s = jax.lax.dot_general(
        qg, k.astype(jnp.float32),
        (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32,
    )                                            # [Hkv, G, ps]
    keep = (page >= 0) & (kv_pos <= q_pos)
    if window is not None:
        keep &= kv_pos > q_pos - window
    s = jnp.where(keep[None, None, :], s, _NEG_INF)

    sm = s.reshape(Hq, ps)
    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_cur = jnp.maximum(m_prev, sm.max(axis=-1, keepdims=True))
    p = jnp.exp(sm - m_cur)
    corr = jnp.exp(m_prev - m_cur)
    l_cur = l_prev * corr + p.sum(axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.reshape(Hkv, G, ps), v.astype(jnp.float32),
        (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * corr + pv.reshape(Hq, hd)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)

    @pl.when(ip == n_pt - 1)
    def _done():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention_kernel_call(
    q: jax.Array,           # [B, Hq, hd]
    k_pages: jax.Array,     # [P, ps, Hkv, hd]
    v_pages: jax.Array,
    page_table: jax.Array,  # [B, n_pt] int32, -1 = unmapped
    q_pos: jax.Array,       # [B] int32
    *,
    window: int | None,
    interpret: bool,
) -> jax.Array:
    B, Hq, hd = q.shape
    P, ps, Hkv, _ = k_pages.shape
    n_pt = page_table.shape[1]
    if Hq % Hkv != 0:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    G = Hq // Hkv

    kern = functools.partial(
        _paged_kernel, n_pt=n_pt, ps=ps, G=G, window=window, scale=hd ** -0.5,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pt),
        in_specs=[
            pl.BlockSpec((1, Hq, hd), lambda b, ip, tbl, qp: (b, 0, 0)),
            pl.BlockSpec(
                (1, ps, Hkv, hd),
                lambda b, ip, tbl, qp: (jnp.maximum(tbl[b, ip], 0), 0, 0, 0),
            ),
            pl.BlockSpec(
                (1, ps, Hkv, hd),
                lambda b, ip, tbl, qp: (jnp.maximum(tbl[b, ip], 0), 0, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, Hq, hd), lambda b, ip, tbl, qp: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq, hd), jnp.float32),
            pltpu.VMEM((Hq, 128), jnp.float32),
            pltpu.VMEM((Hq, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, hd), q.dtype),
        interpret=interpret,
    )(page_table, q_pos, q, k_pages, v_pages)
