from .ops import decode_attention

__all__ = ["decode_attention"]
