from .ops import decode_attention, paged_decode_attention

__all__ = ["decode_attention", "paged_decode_attention"]
