"""Pure-jnp oracle for single-token decode attention against a (ring) cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["decode_attention_ref", "paged_decode_attention_ref"]

_NEG_INF = -1e30


def decode_attention_ref(
    q: jax.Array,        # [B, Hq, hd]
    k_cache: jax.Array,  # [B, S, Hkv, hd]
    v_cache: jax.Array,
    kv_pos: jax.Array,   # [S] absolute position per slot, -1 = empty
    q_pos: jax.Array,    # [] absolute position of the query
    *,
    window: int | None = None,
) -> jax.Array:
    B, Hq, hd = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32))
    keep = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window is not None:
        keep &= kv_pos > q_pos - window
    s = jnp.where(keep[None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, hd).astype(q.dtype)


def paged_decode_attention_ref(
    q: jax.Array,           # [B, Hq, hd]
    k_pages: jax.Array,     # [P, ps, Hkv, hd] global page pool
    v_pages: jax.Array,
    page_table: jax.Array,  # [B, n_pt] physical page per logical page, -1 = unmapped
    q_pos: jax.Array,       # [B] absolute position of each query token
    *,
    window: int | None = None,
) -> jax.Array:
    """Oracle for gather-by-page-table decode attention.

    Logical KV position of page-table entry ``(j, t)`` is ``j*ps + t``;
    entries of unmapped pages (and positions beyond ``q_pos``) are masked.
    """
    B, Hq, hd = q.shape
    P, ps, Hkv, _ = k_pages.shape
    n_pt = page_table.shape[1]
    kc = k_pages[jnp.maximum(page_table, 0)].reshape(B, n_pt * ps, Hkv, hd)
    vc = v_pages[jnp.maximum(page_table, 0)].reshape(B, n_pt * ps, Hkv, hd)
    idx = jnp.arange(n_pt * ps)
    mapped = jnp.repeat(page_table >= 0, ps, axis=1)
    kv_pos = jnp.where(mapped, idx[None], -1)             # [B, n_pt*ps]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bhgd,bshd->bhgs", qg, kc.astype(jnp.float32))
    keep = (kv_pos >= 0) & (kv_pos <= q_pos[:, None])
    if window is not None:
        keep &= kv_pos > q_pos[:, None] - window
    s = jnp.where(keep[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vc.astype(jnp.float32))
    return out.reshape(B, Hq, hd).astype(q.dtype)
