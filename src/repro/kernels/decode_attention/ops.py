"""jit-able wrapper for the flash-decode kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from .kernel import decode_attention_kernel_call

__all__ = ["decode_attention", "paged_decode_attention"]

_NEG_INF = -1e30


@partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(
    q: jax.Array,        # [B, 1, Hq, hd] (model layout) or [B, Hq, hd]
    k_cache: jax.Array,  # [B, S, Hkv, hd]
    v_cache: jax.Array,
    kv_pos: jax.Array,   # [S]
    q_pos: jax.Array,    # []
    *,
    window: int | None = None,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    out = decode_attention_kernel_call(
        q, k_cache, v_cache,
        kv_pos.astype(jnp.int32), q_pos.astype(jnp.int32),
        window=window, block_k=block_k, interpret=interpret,
    )
    return out[:, None] if squeeze else out


@partial(jax.jit, static_argnames=("window", "use_kernel", "interpret"))
def paged_decode_attention(
    q: jax.Array,           # [B, 1, Hq, hd] (model layout) or [B, Hq, hd]
    k_pages: jax.Array,     # [P, ps, Hkv, hd] global page pool
    v_pages: jax.Array,
    page_table: jax.Array,  # [B, n_pt] physical page ids, -1 = unmapped
    q_pos: jax.Array,       # [B] absolute position per row
    *,
    window: int | None = None,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Gather-by-page-table decode attention (the paged-KV hot path).

    Logical position of page-table entry ``(j, t)`` is ``j*ps + t``, so a
    request's pages reconstruct its linear KV cache without the cache ever
    existing contiguously.  Two paths:

    - the pure-jnp gather path (default off-TPU) — this is what the serving
      decode graph captures: an explicit ``pages[table]`` gather plus the
      same position-table-masked softmax as :func:`decode_attention`, so
      graphi fuses the gather into the attention group and ``StaticHostPlan``
      replay sees a fixed-shape movement op;
    - the Pallas kernel (``REPRO_USE_PALLAS=1`` or real TPU), whose
      scalar-prefetch BlockSpec index map chases the page table directly.
    """
    from repro.kernels import kernels_enabled

    from .kernel import paged_decode_attention_kernel_call

    if use_kernel is None:
        use_kernel = kernels_enabled()
    if interpret is None:
        interpret = default_interpret()
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    page_table = page_table.astype(jnp.int32)
    q_pos = q_pos.astype(jnp.int32)
    if use_kernel:
        out = paged_decode_attention_kernel_call(
            q, k_pages, v_pages, page_table, q_pos,
            window=window, interpret=interpret,
        )
        return out[:, None] if squeeze else out

    B, Hq, hd = q.shape
    P, ps, Hkv, _ = k_pages.shape
    n_pt = page_table.shape[1]
    clamped = jnp.maximum(page_table, 0)
    kc = k_pages[clamped].reshape(B, n_pt * ps, Hkv, hd)
    vc = v_pages[clamped].reshape(B, n_pt * ps, Hkv, hd)
    idx = jnp.arange(n_pt * ps)
    mapped = jnp.repeat(page_table >= 0, ps, axis=1)
    kv_pos = jnp.where(mapped, idx[None], -1)
    # masked softmax identical (op for op) to layers.decode_attention's 2-D
    # path: the paged engine must stay bit-exact with the per-slot engine
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd) * hd ** -0.5
    s = jnp.einsum("bhgd,bshd->bhgs", qg, kc).astype(jnp.float32)
    qp = q_pos[:, None]
    keep = (kv_pos >= 0) & (kv_pos <= qp)
    if window is not None:
        keep &= kv_pos > qp - window
    s = jnp.where(keep[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(vc.dtype), vc)
    out = out.reshape(B, Hq, hd).astype(q.dtype)
    return out[:, None] if squeeze else out
