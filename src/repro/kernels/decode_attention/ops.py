"""jit-able wrapper for the flash-decode kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from .kernel import decode_attention_kernel_call

__all__ = ["decode_attention"]


@partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(
    q: jax.Array,        # [B, 1, Hq, hd] (model layout) or [B, Hq, hd]
    k_cache: jax.Array,  # [B, S, Hkv, hd]
    v_cache: jax.Array,
    kv_pos: jax.Array,   # [S]
    q_pos: jax.Array,    # []
    *,
    window: int | None = None,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    out = decode_attention_kernel_call(
        q, k_cache, v_cache,
        kv_pos.astype(jnp.int32), q_pos.astype(jnp.int32),
        window=window, block_k=block_k, interpret=interpret,
    )
    return out[:, None] if squeeze else out
