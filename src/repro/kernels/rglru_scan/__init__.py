from .ops import rglru_scan

__all__ = ["rglru_scan"]
