"""jit-able wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import default_interpret
from .kernel import rglru_scan_kernel_call

__all__ = ["rglru_scan"]


@partial(jax.jit, static_argnames=("block_r", "block_s", "interpret"))
def rglru_scan(
    a: jax.Array,  # [B, S, R]
    b: jax.Array,
    *,
    block_r: int = 512,
    block_s: int = 256,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = default_interpret()
    return rglru_scan_kernel_call(
        a, b, block_r=block_r, block_s=block_s, interpret=interpret
    )
