"""RG-LRU linear-recurrence Pallas kernel (Griffin / recurrentgemma).

Same VMEM schedule as ssm_scan (chunk-resident associative scan, state
carried in scratch across the innermost sequence-chunk axis) but for a
diagonal [R]-channel recurrence — the state is a vector, not a matrix,
and the full sequence of states IS the output.

grid = (B, R/br, S/bs); VMEM per step: a,b tiles [bs, br] f32 + h [1, br].
Defaults bs=256, br=512 -> ~1 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_scan_kernel_call"]


def _combine(x, y):
    a1, b1 = x
    a2, b2 = y
    return a1 * a2, a2 * b1 + b2


def _kernel(a_ref, b_ref, hs_ref, hlast_ref, h_ref, *, n_seq: int):
    isq = pl.program_id(2)

    @pl.when(isq == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)                  # [bs, br]
    b = b_ref[0].astype(jnp.float32)
    a_cum, b_scan = jax.lax.associative_scan(_combine, (a, b), axis=0)
    hs = a_cum * h_ref[0][None] + b_scan              # [bs, br]
    hs_ref[0] = hs.astype(hs_ref.dtype)
    h_ref[0] = hs[-1]

    @pl.when(isq == n_seq - 1)
    def _done():
        hlast_ref[0] = h_ref[0].astype(hlast_ref.dtype)


def rglru_scan_kernel_call(
    a: jax.Array,  # [B, S, R]
    b: jax.Array,
    *,
    block_r: int,
    block_s: int,
    interpret: bool,
):
    B, S, R = a.shape
    br = min(block_r, R)
    bs = min(block_s, S)
    if R % br != 0 or S % bs != 0:
        raise ValueError(f"block sizes must tile the array: R={R} br={br} S={S} bs={bs}")
    grid = (B, R // br, S // bs)

    kern = functools.partial(_kernel, n_seq=S // bs)
    hs, h_last = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, br), lambda bb, ir, is_: (bb, is_, ir)),
            pl.BlockSpec((1, bs, br), lambda bb, ir, is_: (bb, is_, ir)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, br), lambda bb, ir, is_: (bb, is_, ir)),
            pl.BlockSpec((1, br), lambda bb, ir, is_: (bb, ir)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, R), jnp.float32),
            jax.ShapeDtypeStruct((B, R), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, br), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return hs, h_last
