"""Pure-jnp oracle for the RG-LRU gated linear recurrence (Griffin)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rglru_scan_ref"]


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array):
    """h_t = a_t * h_{t-1} + b_t over axis 1.

    a, b: [B, S, R]; h0: [B, R].  Returns (hs [B, S, R] f32, h_last f32).
    """
    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    h, hs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a.astype(jnp.float32).transpose(1, 0, 2), b.astype(jnp.float32).transpose(1, 0, 2)),
    )
    return hs.transpose(1, 0, 2), h
