"""Grouped per-expert matmul Pallas kernel (MoE expert FFN).

Experts are the Graphi "executor groups" of the MoE archs (DESIGN.md §6):
the leading E axis is embarrassingly parallel (sharded over the mesh's
expert/model axis at the SPMD level; within a chip it is a parallel grid
dimension).  Per expert this is a standard MXU-blocked matmul:

grid = (E, C/bc, F/bf, D/bd), D innermost accumulating into f32 VMEM
scratch.  Defaults bc=bf=bd=256 keep every MXU dim >=128 at ~0.8 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["moe_gmm_kernel_call"]


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    idd = pl.program_id(3)

    @pl.when(idd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(idd == n_d - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm_kernel_call(
    x: jax.Array,  # [E, C, D]
    w: jax.Array,  # [E, D, F]
    *,
    block_c: int,
    block_f: int,
    block_d: int,
    interpret: bool,
) -> jax.Array:
    E, C, D = x.shape
    _, _, F = w.shape
    bc = min(block_c, C)
    bf = min(block_f, F)
    bd = min(block_d, D)
    if C % bc != 0 or F % bf != 0 or D % bd != 0:
        raise ValueError(f"block sizes must tile the array: C={C} bc={bc} F={F} bf={bf} D={D} bd={bd}")
    grid = (E, C // bc, F // bf, D // bd)

    kern = functools.partial(_kernel, n_d=D // bd)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, if_, id_: (e, ic, id_)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, if_, id_: (e, id_, if_)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ic, if_, id_: (e, ic, if_)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
