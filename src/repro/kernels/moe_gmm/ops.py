"""jit-able wrapper for the grouped-matmul kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import default_interpret
from .kernel import moe_gmm_kernel_call

__all__ = ["moe_gmm"]


@partial(jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret"))
def moe_gmm(
    x: jax.Array,  # [E, C, D]
    w: jax.Array,  # [E, D, F]
    *,
    block_c: int = 256,
    block_f: int = 256,
    block_d: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    return moe_gmm_kernel_call(
        x, w, block_c=block_c, block_f=block_f, block_d=block_d,
        interpret=interpret,
    )
