"""Pure-jnp oracle for the grouped (per-expert) matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["moe_gmm_ref"]


def moe_gmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: [E, C, D]; w: [E, D, F] -> [E, C, F] (f32 accumulation)."""
    out = jnp.einsum(
        "ecd,edf->ecf",
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(x.dtype)
