from .ops import moe_gmm

__all__ = ["moe_gmm"]
