"""Pure-jnp oracle for flash attention (GQA, causal, sliding window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]

_NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # [B, Sq, Hq, hd]
    k: jax.Array,  # [B, Skv, Hkv, hd]
    v: jax.Array,  # [B, Skv, Hkv, hd]
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Materializes the full [Sq, Skv] score matrix — test sizes only."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    keep = jnp.ones((Sq, Skv), bool)
    if causal:
        keep &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        keep &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(keep[None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)
