"""jit-able wrapper: layout policy + block-size selection for the kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import default_interpret
from .kernel import flash_attention_kernel_call

__all__ = ["flash_attention"]


@partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, hd]  (model layout)
    k: jax.Array,  # [B, Skv, Hkv, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention with GQA/MQA, causal and sliding-window masking.

    Accepts the model's [B, S, H, hd] layout; the kernel runs on
    [B, H, S, hd] (sequence-minor tiles keep the MXU dims contiguous).
    Sequence lengths must divide the (clipped) block sizes.
    """
    if interpret is None:
        interpret = default_interpret()
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_kernel_call(
        qt, kt, vt,
        causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)
