"""Flash-attention forward Pallas kernel (TPU target).

Tiling: grid = (B, Hq, Sq/bq, Skv/bk), KV innermost (``arbitrary`` — it
carries the online-softmax state in VMEM scratch across iterations; the
other three axes are ``parallel``).  Per grid step the VMEM working set is

    q tile   [bq, hd]                (bf16)
    k,v tile [bk, hd]                (bf16)
    scores   [bq, bk]                (f32, VREG-resident)
    acc      [bq, hd] + m,l [bq,128] (f32 scratch)

With the default bq=bk=512, hd<=256 this is ~1.8 MB — comfortably inside
the 16 MB v5e VMEM while keeping the MXU matmul dims >= 128.

Causal/window block pruning: fully-masked KV tiles are skipped via
``pl.when`` (the scheduling analogue of not dispatching a no-op — on real
TPU the block's DMA is still issued by the pipeline, so the roofline win is
the MXU time only; a fully pruned grid via index remapping is noted in
EXPERIMENTS.md §Perf as a further step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

_NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, bq: int, bk: int, n_kv: int, scale: float,
    causal: bool, window: int | None, q_offset: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level pruning: is any (q, k) pair in this tile live?
    live = jnp.bool_(True)
    if causal:
        # newest q position in tile >= oldest k position in tile
        live &= (q_offset + iq * bq + bq - 1) >= ik * bk
    if window is not None:
        # newest k position > oldest q position - window
        live &= (ik * bk + bk - 1) > (q_offset + iq * bq - window)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, hd]
        k = k_ref[0, 0]                                      # [bk, hd]
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # [bq, bk]
        q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        if causal:
            s = jnp.where(k_pos <= q_pos, s, _NEG_INF)
        if window is not None:
            s = jnp.where(k_pos > q_pos - window, s, _NEG_INF)

        m_prev = m_ref[:, :1]                                # [bq, 1]
        l_prev = l_ref[:, :1]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)                               # [bq, bk]
        corr = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # [bq, hd]
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)

    @pl.when(ik == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel_call(
    q: jax.Array,  # [B, Hq, Sq, hd]
    k: jax.Array,  # [B, Hkv, Skv, hd]
    v: jax.Array,
    *,
    causal: bool,
    window: int | None,
    q_offset: int,
    block_q: int,
    block_k: int,
    interpret: bool,
) -> jax.Array:
    B, Hq, Sq, hd = q.shape
    _, Hkv, Skv, _ = k.shape
    if Hq % Hkv != 0:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    G = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    if Sq % bq != 0 or Skv % bk != 0:
        raise ValueError(f"block sizes must tile the sequence: Sq={Sq} bq={bq} Skv={Skv} bk={bk}")
    n_q, n_kv = Sq // bq, Skv // bk

    kern = functools.partial(
        _kernel, bq=bq, bk=bk, n_kv=n_kv, scale=hd ** -0.5,
        causal=causal, window=window, q_offset=q_offset,
    )
    grid = (B, Hq, n_q, n_kv)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq, 128), jnp.float32),  # m (lane-replicated)
            pltpu.VMEM((bq, 128), jnp.float32),  # l
        ],
        interpret=interpret,
    )(q, k, v)
