"""Fused LSTM gate/state-update Pallas kernel.

This is the paper's element-wise hot-spot (Fig 2b / §5.2): after the two
GEMMs (left on the MXU), the cell update is 8+ elementwise ops over
[N, 4H].  Unfused, each op round-trips HBM; fused, every gate byte is
read once and h/c written once — the TPU analogue of the paper's
"stream store" trick for elementwise outputs (§6).

Tiling: grid = (N/bn, H/bh); the wrapper views the gate tensors as
[N, 4, H] so one BlockSpec block (bn, 4, bh) carries all four gates of a
tile; i/f/g/o are VREG slices.  All math f32 in-register, stores in the
caller dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lstm_cell_kernel_call"]


def _kernel(gx_ref, gh_ref, b_ref, c_ref, h_ref, cn_ref):
    g4 = gx_ref[...].astype(jnp.float32) + gh_ref[...].astype(jnp.float32)
    g4 = g4 + b_ref[...].astype(jnp.float32)[None]   # [bn, 4, bh]
    i, f, g, o = g4[:, 0], g4[:, 1], g4[:, 2], g4[:, 3]
    c = c_ref[...].astype(jnp.float32)
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    h_ref[...] = h.astype(h_ref.dtype)
    cn_ref[...] = c_new.astype(cn_ref.dtype)


def lstm_cell_kernel_call(
    gx: jax.Array,  # [N, 4, H]
    gh: jax.Array,  # [N, 4, H]
    b: jax.Array,   # [4, H]
    c: jax.Array,   # [N, H]
    *,
    block_n: int,
    block_h: int,
    interpret: bool,
):
    N, _, H = gx.shape
    bn = min(block_n, N)
    bh = min(block_h, H)
    if N % bn != 0 or H % bh != 0:
        raise ValueError(f"block sizes must tile the array: N={N} bn={bn} H={H} bh={bh}")
    grid = (N // bn, H // bh)
    h, c_new = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, 4, bh), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bn, 4, bh), lambda i, j: (i, 0, j)),
            pl.BlockSpec((4, bh), lambda i, j: (0, j)),
            pl.BlockSpec((bn, bh), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bh), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bh), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, H), gx.dtype),
            jax.ShapeDtypeStruct((N, H), c.dtype),
        ],
        interpret=interpret,
    )(gx, gh, b, c)
    return h, c_new
