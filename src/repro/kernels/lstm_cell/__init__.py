from .ops import lstm_cell_fused

__all__ = ["lstm_cell_fused"]
