"""jit-able wrapper: [N, 4H] gate layout -> [N, 4, H] tiles for the kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import default_interpret
from .kernel import lstm_cell_kernel_call

__all__ = ["lstm_cell_fused"]


@partial(jax.jit, static_argnames=("block_n", "block_h", "interpret"))
def lstm_cell_fused(
    gx: jax.Array,  # [N, 4H]
    gh: jax.Array,  # [N, 4H]
    b: jax.Array,   # [4H]
    c: jax.Array,   # [N, H]
    *,
    block_n: int = 256,
    block_h: int = 512,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = default_interpret()
    N, H4 = gx.shape
    H = H4 // 4
    return lstm_cell_kernel_call(
        gx.reshape(N, 4, H), gh.reshape(N, 4, H), b.reshape(4, H), c,
        block_n=block_n, block_h=block_h, interpret=interpret,
    )
