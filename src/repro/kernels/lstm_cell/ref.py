"""Pure-jnp oracle for the fused LSTM gate/state update."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lstm_cell_ref"]


def lstm_cell_ref(gx: jax.Array, gh: jax.Array, b: jax.Array, c: jax.Array):
    """gx, gh: [N, 4H] (input / recurrent GEMM outputs); b: [4H]; c: [N, H].

    Gate order i|f|g|o; forget-gate bias +1 (the standard init).  Returns
    (h [N,H], c_new [N,H]).
    """
    gates = gx.astype(jnp.float32) + gh.astype(jnp.float32) + b.astype(jnp.float32)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f + 1.0) * c.astype(jnp.float32) + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h.astype(gx.dtype), c_new.astype(c.dtype)
