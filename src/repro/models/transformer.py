"""Unified model: decoder LMs (dense/GQA/SWA/MoE), SSM (mamba), hybrid
(Griffin RG-LRU), encoder-decoder (whisper) and VLM (llava) backbones.

Functional API (pure fns over a params pytree):

    init_params(cfg, key)                       -> params
    forward(cfg, params, batch, remat=False)    -> (logits [B,S,Vp], aux)
    init_cache(cfg, batch, max_len)             -> cache
    prefill(cfg, params, batch, cache)          -> (logits [B,Vp], cache)
    decode_step(cfg, params, tokens [B,1], cache) -> (logits [B,Vp], cache)

Layers are stacked + ``lax.scan``-swept when the block pattern is homogeneous
(``cfg.scan_layers``), which keeps compile time flat in depth — essential for
the 40-cell dry-run sweep.  Heterogeneous archs (recurrentgemma) use a python
loop over per-layer param dicts.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.sharding import mesh_context, shard
from .griffin import init_rglru_cache, init_rglru_params, rglru_block, rglru_decode_step
from .layers import (
    apply_rope,
    chunked_attention,
    decode_attention,
    glu_ffn,
    masked_attention,
    rms_norm,
    sinusoidal_positions,
)
from .mamba import init_mamba_cache, init_mamba_params, mamba_block, mamba_decode_step
from .moe import init_moe_params, moe_ffn

__all__ = [
    "init_params",
    "forward",
    "init_cache",
    "prefill",
    "decode_step",
    "cache_insert_slot",
    "cache_evict_slot",
    "paged_supported",
    "init_paged_cache",
    "alloc_page",
    "free_pages",
    "paged_decode_step",
    "paged_prefill_chunk",
    "paged_insert_chunk",
    "paged_copy_page",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    so = (cfg.n_heads * hd) ** -0.5
    return {
        "wq": (jax.random.normal(kq, (d, cfg.n_heads * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d, cfg.n_kv_heads * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d, cfg.n_kv_heads * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (cfg.n_heads * hd, d)) * so).astype(dtype),
    }


def _init_mlp(key, cfg: ModelConfig, dtype):
    if cfg.n_experts:
        return init_moe_params(key, cfg.d_model, cfg.d_ff, cfg.n_experts, dtype)
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, f)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d)) * f ** -0.5).astype(dtype),
    }


def _init_layer(key, cfg: ModelConfig, kind: str, dtype, cross: bool = False):
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    if kind == "ssm":
        return {"ln1": jnp.zeros((d,), dtype), "ssm": init_mamba_params(ks[0], cfg, dtype)}
    lp: dict[str, Any] = {"ln1": jnp.zeros((d,), dtype)}
    if kind == "attn":
        lp["attn"] = _init_attn(ks[0], cfg, dtype)
    elif kind == "rglru":
        lp["rnn"] = init_rglru_params(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if cross:
        lp["ln_x"] = jnp.zeros((d,), dtype)
        lp["xattn"] = _init_attn(ks[1], cfg, dtype)
    lp["ln2"] = jnp.zeros((d,), dtype)
    lp["mlp"] = _init_mlp(ks[2], cfg, dtype)
    return lp


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = cfg.dtype
    keys = jax.random.split(key, cfg.n_layers + cfg.n_encoder_layers + 3)
    params: dict[str, Any] = {
        "embed": (
            jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model)) * cfg.d_model ** -0.5
        ).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(keys[1], (cfg.d_model, cfg.padded_vocab)) * cfg.d_model ** -0.5
        ).astype(dtype)

    kinds = cfg.layer_kinds()
    layer_keys = keys[2 : 2 + cfg.n_layers]
    if cfg.scan_layers and cfg.is_homogeneous:
        stacked = [
            _init_layer(layer_keys[i], cfg, kinds[i], dtype, cross=cfg.cross_attention)
            for i in range(cfg.n_layers)
        ]
        params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    else:
        params["layers"] = [
            _init_layer(layer_keys[i], cfg, kinds[i], dtype, cross=cfg.cross_attention)
            for i in range(cfg.n_layers)
        ]

    if cfg.n_encoder_layers:
        ekeys = keys[2 + cfg.n_layers : 2 + cfg.n_layers + cfg.n_encoder_layers]
        stacked = [_init_layer(k, cfg, "attn", dtype) for k in ekeys]
        params["enc_layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attn_apply(cfg: ModelConfig, ap, x, *, positions, causal, window, kv_override=None):
    """Full-sequence attention. kv_override: (k_src, kv_positions) for cross.

    Megatron layout: inside attention the *head* dim carries the model axis
    (seq gathered); the residual stream outside is seq-sharded.  Explicit
    constraints here stop GSPMD from guessing a seq-sharded q through the
    attention chunking reshape (which it can only realize by involuntary
    full rematerialization — replicating the whole tensor).
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, ap["wq"]).reshape(B, S, cfg.n_heads, hd)
    src = x if kv_override is None else kv_override[0]
    Skv = src.shape[1]
    k = jnp.einsum("bsd,de->bse", src, ap["wk"]).reshape(B, Skv, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", src, ap["wv"]).reshape(B, Skv, cfg.n_kv_heads, hd)
    # attention parallelization policy: heads over the model axis when they
    # divide it (Megatron); otherwise shard the independent q rows over it
    # (the MQA/few-head case — replicating attention over 16 chips would
    # waste 16x compute).  KV stays gathered in the q-row case.
    ctx = mesh_context()
    tp = ctx.extent(ctx.resolve("model")) if ctx else 1
    head_parallel = tp > 1 and cfg.n_heads % tp == 0
    q_chunk = cfg.attn_q_chunk
    if head_parallel:
        spec = ("batch", None, "model", None)
        q = shard(q, *spec)
        k = shard(k, *spec)
        v = shard(v, *spec)
    else:
        q = shard(q, "batch", "attn_seq", None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
        if tp > 1:
            q_chunk = 0   # q rows sharded: no q loop (a lax.map would
            #               serialize one device-resident chunk at a time)
    q = apply_rope(q, positions, cfg.rope_theta)
    if kv_override is None:
        k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(
        q, k, v, causal=causal, window=window,
        chunk=cfg.attn_chunk, q_chunk=q_chunk,
    )
    if head_parallel:
        out = shard(out, "batch", None, "model", None)
    else:
        out = shard(out, "batch", "attn_seq", None, None)
    out = out.reshape(B, S, cfg.n_heads * hd)
    proj = jnp.einsum("bse,ed->bsd", out, ap["wo"])
    # row-parallel epilogue lands sequence-sharded (reduce-scatter, not a
    # full f32 all-reduce — same Megatron-SP pinning as glu_ffn)
    return shard(proj, "batch", "seq", None), (k, v)


def _mlp_apply(cfg: ModelConfig, mp, x):
    """Returns (out, aux)."""
    if cfg.n_experts:
        return moe_ffn(mp, x, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor, act=cfg.act)
    return glu_ffn(x, mp["w_gate"], mp["w_up"], mp["w_down"], cfg.act), 0.0


def _block_train(cfg: ModelConfig, lp, kind: str, x, *, positions, window, enc=None, causal=True):
    """One residual block, full-sequence (train/prefill). Returns (x, aux)."""
    aux = 0.0
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "ssm":
        return x + mamba_block(lp["ssm"], h), aux
    if kind == "attn":
        mix, _ = _attn_apply(cfg, lp["attn"], h, positions=positions, causal=causal, window=window)
    else:  # rglru
        mix = rglru_block(lp["rnn"], h)
    if cfg.parallel_block:
        mlp_out, aux = _mlp_apply(cfg, lp["mlp"], h)
        x = x + mix + mlp_out
    else:
        x = x + mix
        if enc is not None:
            hx = rms_norm(x, lp["ln_x"], cfg.norm_eps)
            xo, _ = _attn_apply(
                cfg, lp["xattn"], hx,
                positions=jnp.arange(hx.shape[1]),
                causal=False, window=None, kv_override=(enc, None),
            )
            x = x + xo
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        mlp_out, aux = _mlp_apply(cfg, lp["mlp"], h2)
        x = x + mlp_out
    return x, aux


# ---------------------------------------------------------------------------
# embedding / logits
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params, tokens, pos_offset=None):
    x = params["embed"][tokens]
    if cfg.tie_embeddings:  # gemma-family normalizes the tied embedding
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.rope_theta <= 0:  # whisper-style absolute sinusoidal positions
        S = x.shape[1]
        if pos_offset is None:
            x = x + sinusoidal_positions(S, cfg.d_model, x.dtype)[None]
        else:
            tab = sinusoidal_positions(1, cfg.d_model, x.dtype)  # freq basis
            # single-position embedding at pos_offset (decode)
            half = cfg.d_model // 2
            freqs = jnp.exp(
                -math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)
            )
            ang = pos_offset.astype(jnp.float32)[..., None] * freqs
            pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(x.dtype)
            # pos_offset is a scalar (shared decode position) or [B]
            # (per-slot continuous batching)
            x = x + (pe[None, None, :] if pe.ndim == 1 else pe[:, None, :])
    return x


def _logits(cfg: ModelConfig, params, x):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("...d,dv->...v", x, w).astype(jnp.float32)


def _window_for(cfg: ModelConfig, kind: str) -> int | None:
    return cfg.sliding_window if (kind == "attn" and cfg.sliding_window) else None


def _encode(cfg: ModelConfig, params, frames):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    x = frames + sinusoidal_positions(frames.shape[1], cfg.d_model, frames.dtype)[None]
    pos = jnp.arange(frames.shape[1])

    def f(x, lp):
        x, _ = _block_train(cfg, lp, "attn", x, positions=pos, window=None, causal=False)
        return x, None

    x, _ = jax.lax.scan(f, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# forward (training)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, batch: dict, *, remat: bool = False):
    """Training forward. batch: tokens [B,S] (+ image_embeds | frames).
    Returns (logits [B, S_total, padded_vocab] fp32, aux_loss)."""
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)

    enc = None
    if cfg.frontend == "vision" and "image_embeds" in batch:
        x = jnp.concatenate([batch["image_embeds"].astype(x.dtype), x], axis=1)
    if cfg.frontend == "audio":
        enc = _encode(cfg, params, batch["frames"])

    S = x.shape[1]
    positions = jnp.arange(S)
    kinds = cfg.layer_kinds()
    # residual stream: batch over DP axes, sequence over the model axis when
    # sequence-parallel activations are enabled (Megatron-SP; saves the remat
    # carries — see DESIGN.md §9). Dropped automatically when S % tp != 0.
    x = shard(x, "batch", "seq", None)

    if cfg.scan_layers and cfg.is_homogeneous:
        kind = kinds[0]
        window = _window_for(cfg, kind)

        def body(carry, lp):
            x, aux = carry
            x, a = _block_train(cfg, lp, kind, x, positions=positions, window=window, enc=enc)
            return (shard(x, "batch", "seq", None), aux + a), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    else:
        aux = 0.0
        for lp, kind in zip(params["layers"], kinds):
            blk = partial(
                _block_train, cfg, lp, kind,
                positions=positions, window=_window_for(cfg, kind), enc=enc,
            )
            if remat:
                blk = jax.checkpoint(blk, prevent_cse=False)
            x, a = blk(x)
            x = shard(x, "batch", "seq", None)
            aux = aux + a

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x), aux


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------

def _attn_cache_len(cfg: ModelConfig, max_len: int) -> int:
    w = cfg.sliding_window
    return min(max_len, w) if w else max_len


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype,
                 per_slot: bool = False):
    if kind == "ssm":
        return init_mamba_cache(cfg, batch, dtype)
    if kind == "rglru":
        return init_rglru_cache(cfg, batch, dtype)
    C = _attn_cache_len(cfg, max_len)
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, C, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, C, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch, C) if per_slot else (C,), -1, jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, per_slot: bool = False) -> dict:
    """KV/state cache for ``batch`` sequences of up to ``max_len`` tokens.

    ``per_slot=True`` is the continuous-batching layout: every batch row is
    an independent request *slot* with its own decode position (``len`` is
    ``[batch]``, attention position tables are ``[batch, C]``), so rows at
    different depths decode in one step and free slots are re-filled via
    :func:`cache_insert_slot` / :func:`cache_evict_slot`.
    """
    dtype = cfg.dtype
    kinds = cfg.layer_kinds()
    if cfg.scan_layers and cfg.is_homogeneous:
        per = [_layer_cache(cfg, kinds[i], batch, max_len, dtype, per_slot)
               for i in range(cfg.n_layers)]
        layers = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    else:
        layers = [_layer_cache(cfg, k, batch, max_len, dtype, per_slot) for k in kinds]
    shape = (batch,) if per_slot else ()
    cache: dict[str, Any] = {"len": jnp.zeros(shape, jnp.int32), "layers": layers}
    if cfg.frontend == "audio":
        cache["enc"] = jnp.zeros((batch, cfg.encoder_len, cfg.d_model), dtype)
    return cache


def _write_prefill(lc, k, v):
    """Write full-sequence K/V [B,S,...] into a (possibly ring) cache."""
    C = lc["k"].shape[1]
    S = k.shape[1]
    take = min(S, C)
    pos = jnp.arange(S - take, S)
    slots = pos % C
    lc = dict(lc)
    lc["k"] = lc["k"].at[:, slots].set(k[:, -take:])
    lc["v"] = lc["v"].at[:, slots].set(v[:, -take:])
    if lc["pos"].ndim == 2:   # per-slot table: broadcast over the batch rows
        lc["pos"] = lc["pos"].at[:, slots].set(pos)
    else:
        lc["pos"] = lc["pos"].at[slots].set(pos)
    return lc


def _cache_batch_axis(cfg: ModelConfig) -> int:
    """Leading axis index of the batch/slot dim in cache leaves (stacked
    homogeneous layouts carry the layer dim first)."""
    return 1 if (cfg.scan_layers and cfg.is_homogeneous) else 0


def cache_insert_slot(cfg: ModelConfig, cache: dict, sub: dict, slot) -> dict:
    """Install a single-request cache (``init_cache(cfg, 1, ..., per_slot=True)``
    filled by :func:`prefill`) into row ``slot`` of a shared per-slot cache.

    Overwrites the slot's K/V, position table, and recurrent state wholesale,
    so whatever the previous occupant (or an idle slot's garbage decode
    steps) left behind is evicted by construction.
    """
    ax = _cache_batch_axis(cfg)

    def ins(dst, src):
        if ax == 1:
            return dst.at[:, slot].set(src[:, 0])
        return dst.at[slot].set(src[0])

    cache = dict(cache)
    cache["layers"] = jax.tree.map(ins, cache["layers"], sub["layers"])
    cache["len"] = cache["len"].at[slot].set(sub["len"][0])
    return cache


def cache_evict_slot(cfg: ModelConfig, cache: dict, slot) -> dict:
    """Free row ``slot``: position tables go to -1 (attention masks every
    cache entry out) and the slot's length resets.  K/V and recurrent state
    are left in place — they are unreachable once the positions are cleared
    and are overwritten by the next :func:`cache_insert_slot`."""
    ax = _cache_batch_axis(cfg)

    def ev(layers):
        if not isinstance(layers, dict) or "pos" not in layers:
            return layers
        lc = dict(layers)
        lc["pos"] = (lc["pos"].at[:, slot].set(-1) if ax == 1
                     else lc["pos"].at[slot].set(-1))
        return lc

    cache = dict(cache)
    if isinstance(cache["layers"], list):
        cache["layers"] = [ev(lc) for lc in cache["layers"]]
    else:
        cache["layers"] = ev(cache["layers"])
    cache["len"] = cache["len"].at[slot].set(0)
    return cache


def _block_decode(cfg: ModelConfig, lp, kind: str, x, lc, *, q_pos, enc=None):
    """Single-token block step. x: [B,1,D]. Returns (x, new layer cache)."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "ssm":
        out, lc = mamba_decode_step(lp["ssm"], h, lc)
        return x + out, lc
    if kind == "rglru":
        mix, lc = rglru_decode_step(lp["rnn"], h, lc)
    else:
        ap = lp["attn"]
        B = x.shape[0]
        hd = cfg.resolved_head_dim
        q = jnp.einsum("bsd,de->bse", h, ap["wq"]).reshape(B, 1, cfg.n_heads, hd)
        k = jnp.einsum("bsd,de->bse", h, ap["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,de->bse", h, ap["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
        pos_arr = q_pos[:, None] if q_pos.ndim else q_pos[None]
        q = apply_rope(q, pos_arr, cfg.rope_theta)
        k = apply_rope(k, pos_arr, cfg.rope_theta)
        C = lc["k"].shape[1]
        lc = dict(lc)
        if q_pos.ndim:
            # continuous batching: each row writes at its own ring slot
            slots = q_pos % C
            rows = jnp.arange(B)
            lc["k"] = lc["k"].at[rows, slots].set(k[:, 0])
            lc["v"] = lc["v"].at[rows, slots].set(v[:, 0])
            lc["pos"] = lc["pos"].at[rows, slots].set(q_pos)
        else:
            slot = q_pos % C
            lc["k"] = jax.lax.dynamic_update_index_in_dim(lc["k"], k[:, 0], slot, 1)
            lc["v"] = jax.lax.dynamic_update_index_in_dim(lc["v"], v[:, 0], slot, 1)
            lc["pos"] = jax.lax.dynamic_update_index_in_dim(lc["pos"], q_pos, slot, 0)
        out = decode_attention(
            q, lc["k"], lc["v"], lc["pos"], q_pos, window=_window_for(cfg, kind)
        )
        mix = jnp.einsum("bse,ed->bsd", out.reshape(B, 1, -1), ap["wo"])
    if cfg.parallel_block:
        mlp_out, _ = _mlp_apply(cfg, lp["mlp"], h)
        return x + mix + mlp_out, lc
    x = x + mix
    if enc is not None:
        hx = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        xo, _ = _attn_apply(
            cfg, lp["xattn"], hx, positions=q_pos[None, None],
            causal=False, window=None, kv_override=(enc, None),
        )
        x = x + xo
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    mlp_out, _ = _mlp_apply(cfg, lp["mlp"], h2)
    return x + mlp_out, lc


def prefill(cfg: ModelConfig, params, batch: dict, cache: dict):
    """Run the full prompt, fill the cache, return last-position logits.

    ``batch["valid_len"]`` (optional scalar int32) marks the prompt as
    right-padded: only the first ``valid_len`` tokens are real.  Logits come
    from position ``valid_len - 1``, the cache length is ``valid_len``, and
    position-table entries past it are cleared to -1 so later decode steps
    mask the padded K/V out.  This is what lets the serving engines bucket
    prompt lengths to a handful of compiled shapes (attention-only archs:
    recurrent state and MoE capacity routing would absorb the pad tokens).
    """
    tokens = batch["tokens"]
    valid_len = batch.get("valid_len")
    if valid_len is not None and any(k != "attn" for k in cfg.layer_kinds()):
        raise ValueError("valid_len-masked prefill requires attention-only archs")
    x = _embed(cfg, params, tokens)
    enc = None
    if cfg.frontend == "vision" and "image_embeds" in batch:
        x = jnp.concatenate([batch["image_embeds"].astype(x.dtype), x], axis=1)
    if cfg.frontend == "audio":
        enc = _encode(cfg, params, batch["frames"])
        cache = dict(cache)
        cache["enc"] = enc

    S = x.shape[1]
    positions = jnp.arange(S)
    kinds = cfg.layer_kinds()
    x = shard(x, "batch", "seq", None)

    def run_block(x, lp, lc, kind):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if kind == "ssm":
            # full-seq scan, then regenerate the decode state via step-free
            # trailing state (mamba_block keeps h internal; recompute final
            # state with the chunked scan's carry):
            out, lc = _mamba_prefill(lp["ssm"], h, lc)
            return x + out, lc
        if kind == "rglru":
            out, lc = _rglru_prefill(lp["rnn"], h, lc)
            mix = out
        else:
            mix, (k, v) = _attn_apply(
                cfg, lp["attn"], h, positions=positions,
                causal=True, window=_window_for(cfg, kind),
            )
            lc = _write_prefill(lc, k, v)
        if cfg.parallel_block:
            mlp_out, _ = _mlp_apply(cfg, lp["mlp"], h)
            return x + mix + mlp_out, lc
        x = x + mix
        if enc is not None:
            hx = rms_norm(x, lp["ln_x"], cfg.norm_eps)
            xo, _ = _attn_apply(
                cfg, lp["xattn"], hx, positions=positions,
                causal=False, window=None, kv_override=(enc, None),
            )
            x = x + xo
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        mlp_out, _ = _mlp_apply(cfg, lp["mlp"], h2)
        return shard(x + mlp_out, "batch", "seq", None), lc

    if cfg.scan_layers and cfg.is_homogeneous:
        kind = kinds[0]

        def body(x, inp):
            lp, lc = inp
            x, lc = run_block(x, lp, lc, kind)
            return x, lc

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    else:
        new_layers = []
        for lp, lc, kind in zip(params["layers"], cache["layers"], kinds):
            x, lc = run_block(x, lp, lc, kind)
            new_layers.append(lc)

    if valid_len is not None:
        valid_len = jnp.asarray(valid_len, jnp.int32)

        def mask_tbl(lc):
            if isinstance(lc, dict) and "pos" in lc:
                lc = dict(lc)
                lc["pos"] = jnp.where(lc["pos"] < valid_len, lc["pos"], -1)
            return lc

        new_layers = ([mask_tbl(lc) for lc in new_layers]
                      if isinstance(new_layers, list) else mask_tbl(new_layers))

    cache = dict(cache)
    cache["layers"] = new_layers
    # scalar for the shared-position layout, [B] for per-slot caches
    if valid_len is None:
        cache["len"] = jnp.full_like(cache["len"], S)
        x_last = x[:, -1]
    else:
        cache["len"] = jnp.broadcast_to(valid_len, cache["len"].shape)
        x_last = jax.lax.dynamic_index_in_dim(x, valid_len - 1, axis=1,
                                              keepdims=False)
    x = rms_norm(x_last, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x), cache


def _mamba_prefill(mp, h, lc):
    """Mamba over the full prompt, returning output and final decode state."""
    from .layers import causal_conv1d
    from .mamba import ssm_scan_fused

    B, L, _ = h.shape
    xz = jnp.einsum("bld,de->ble", h, mp["in_proj"])
    xpart, res = jnp.split(xz, 2, axis=-1)
    xconv, _ = causal_conv1d(xpart, mp["conv_w"])
    xconv = jax.nn.silu(xconv + mp["conv_b"])
    di, st = mp["A_log"].shape
    y, h_last = ssm_scan_fused(mp, xconv, jnp.zeros((B, di, st), jnp.float32))
    y = y + mp["D"] * xconv.astype(jnp.float32)
    y = y * jax.nn.silu(res.astype(jnp.float32))
    out = jnp.einsum("bld,de->ble", y.astype(h.dtype), mp["out_proj"])
    K = mp["conv_w"].shape[0]
    new_cache = {"h": h_last, "conv": xpart[:, -(K - 1):, :]}
    return out, new_cache


def _rglru_prefill(rp, h, lc):
    from .griffin import _rglru_gates
    from .layers import causal_conv1d, linear_recurrence_chunked

    B = h.shape[0]
    y_branch = jax.nn.gelu(jnp.einsum("bld,dr->blr", h, rp["w_y"]))
    x_branch = jnp.einsum("bld,dr->blr", h, rp["w_x"])
    xc, _ = causal_conv1d(x_branch, rp["conv_w"])
    xc = xc + rp["conv_b"]
    a, b = _rglru_gates(rp, xc)
    hs, h_last = linear_recurrence_chunked(a, b, jnp.zeros((B, a.shape[-1]), jnp.float32))
    out = jnp.einsum("blr,rd->bld", (hs.astype(h.dtype) * y_branch), rp["w_o"])
    K = rp["conv_w"].shape[0]
    new_cache = {"h": h_last, "conv": x_branch[:, -(K - 1):, :]}
    return out, new_cache


def decode_step(cfg: ModelConfig, params, tokens, cache: dict):
    """One decode step. tokens: [B, 1]. Returns (logits [B, Vp], new cache)."""
    q_pos = cache["len"]
    x = _embed(cfg, params, tokens, pos_offset=q_pos)
    x = shard(x, "batch", None, None)
    enc = cache.get("enc")
    kinds = cfg.layer_kinds()

    if cfg.scan_layers and cfg.is_homogeneous:
        kind = kinds[0]

        def body(x, inp):
            lp, lc = inp
            x, lc = _block_decode(cfg, lp, kind, x, lc, q_pos=q_pos, enc=enc)
            return x, lc

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    else:
        new_layers = []
        for lp, lc, kind in zip(params["layers"], cache["layers"], kinds):
            x, lc = _block_decode(cfg, lp, kind, x, lc, q_pos=q_pos, enc=enc)
            new_layers.append(lc)

    cache = dict(cache)
    cache["layers"] = new_layers
    cache["len"] = cache["len"] + 1
    x = rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x), cache


# ---------------------------------------------------------------------------
# paged KV cache: global page pool + per-request page tables
# ---------------------------------------------------------------------------
#
# Layout: per layer a page pool {"k": [P, ps, Hkv, hd], "v": ...} (leading
# layer axis when the arch scans stacked layers), a page table [B, n_pt]
# mapping each slot's logical page j to a physical page id (-1 = unmapped),
# and per-slot lengths [B].  The logical KV position of table entry (j, t)
# is j*ps + t, so a request's pages reconstruct its linear cache without it
# ever existing contiguously — one short request pins ceil(len/ps) pages
# instead of a full max_len slot, and requests sharing a prompt prefix can
# map the *same* physical pages (serve/paged.py owns refcounts + CoW).
#
# The page table and lengths are host-managed (numpy in the serving engine,
# passed in as int32 arrays per step); only the pools are threaded through
# the captured decode graph functionally.

def paged_supported(cfg: ModelConfig) -> bool:
    """Paged serving covers decoder-only, attention-only, rope archs: SSM /
    RG-LRU carry recurrent state that has no paged analogue, and encoder
    frontends are not served continuously in the first place."""
    return (not cfg.frontend and not cfg.n_encoder_layers
            and cfg.rope_theta > 0
            and all(k == "attn" for k in cfg.layer_kinds()))


def _paged_stacked(cfg: ModelConfig) -> bool:
    return cfg.scan_layers and cfg.is_homogeneous


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                     n_pages: int, page_size: int) -> dict:
    """Paged KV cache for ``batch`` request slots over a ``n_pages``-page
    global pool.  ``table``/``len`` come back as numpy (host-managed by the
    allocator); ``pages`` are device arrays threaded through decode."""
    if not paged_supported(cfg):
        raise ValueError("paged KV cache requires a decoder-only "
                         "attention-only rope arch "
                         f"(got kinds={cfg.layer_kinds()}, frontend={cfg.frontend!r})")
    n_pt = -(-max_len // page_size)
    hd = cfg.resolved_head_dim
    dtype = cfg.dtype

    def pool():
        return {"k": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, hd), dtype)}

    if _paged_stacked(cfg):
        per = [pool() for _ in range(cfg.n_layers)]
        pages = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    else:
        pages = [pool() for _ in range(cfg.n_layers)]
    return {
        "len": np.zeros((batch,), np.int32),
        "table": np.full((batch, n_pt), -1, np.int32),
        "pages": pages,
    }


def alloc_page(cache: dict, slot: int, logical_idx: int, page: int) -> dict:
    """Map physical ``page`` at logical index ``logical_idx`` of ``slot``'s
    page table (host-side bookkeeping; the pool allocator picks ``page``)."""
    table = np.asarray(cache["table"]).copy()
    if table[slot, logical_idx] >= 0:
        raise ValueError(f"slot {slot} logical page {logical_idx} already "
                         f"mapped to {table[slot, logical_idx]}")
    table[slot, logical_idx] = page
    return {**cache, "table": table}


def free_pages(cache: dict, slot: int) -> tuple[dict, list[int]]:
    """Unmap every page of ``slot`` and reset its length.  Returns the new
    cache and the freed physical page ids (the allocator decides whether
    they return to the free list or stay as cold prefix cache)."""
    table = np.asarray(cache["table"]).copy()
    freed = [int(p) for p in table[slot] if p >= 0]
    table[slot] = -1
    length = np.asarray(cache["len"]).copy()
    length[slot] = 0
    return {**cache, "table": table, "len": length}, freed


def _paged_block_decode(cfg: ModelConfig, lp, x, pk, pv, table, q_pos, *,
                        page_size: int):
    """Single-token block step over the page pool.  x: [B,1,D];
    pk/pv: [P, ps, Hkv, hd]; table: [B, n_pt]; q_pos: [B]."""
    from repro.kernels.decode_attention import paged_decode_attention

    ap = lp["attn"]
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", h, ap["wq"]).reshape(B, 1, cfg.n_heads, hd)
    k = jnp.einsum("bsd,de->bse", h, ap["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", h, ap["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
    pos_arr = q_pos[:, None]
    q = apply_rope(q, pos_arr, cfg.rope_theta)
    k = apply_rope(k, pos_arr, cfg.rope_theta)
    P, ps = pk.shape[0], page_size
    # write this token's K/V at (table[b, len//ps], len%ps); rows whose tail
    # page is unmapped (idle slots) redirect to the out-of-bounds page P and
    # the scatter drops them — never a wrapped write into page P-1
    phys = jnp.take_along_axis(table, (q_pos // ps)[:, None], axis=1)[:, 0]
    phys = jnp.where(phys < 0, P, phys)
    off = q_pos % ps
    pk = pk.at[phys, off].set(k[:, 0], mode="drop")
    pv = pv.at[phys, off].set(v[:, 0], mode="drop")
    out = paged_decode_attention(q, pk, pv, table, q_pos,
                                 window=_window_for(cfg, "attn"))
    mix = jnp.einsum("bse,ed->bsd", out.reshape(B, 1, -1), ap["wo"])
    if cfg.parallel_block:
        mlp_out, _ = _mlp_apply(cfg, lp["mlp"], h)
        return x + mix + mlp_out, pk, pv
    x = x + mix
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    mlp_out, _ = _mlp_apply(cfg, lp["mlp"], h2)
    return x + mlp_out, pk, pv


def paged_decode_step(cfg: ModelConfig, params, tokens, cache: dict, *,
                      page_size: int):
    """One decode step over the paged cache.  tokens: [B, 1].
    Returns (logits [B, Vp], new cache with updated pools and len+1)."""
    q_pos = jnp.asarray(cache["len"], jnp.int32)
    table = jnp.asarray(cache["table"], jnp.int32)
    x = _embed(cfg, params, tokens, pos_offset=q_pos)

    if _paged_stacked(cfg):
        def body(x, inp):
            lp, pg = inp
            x, pk, pv = _paged_block_decode(cfg, lp, x, pg["k"], pg["v"],
                                            table, q_pos, page_size=page_size)
            return x, {"k": pk, "v": pv}

        x, new_pages = jax.lax.scan(body, x, (params["layers"], cache["pages"]))
    else:
        new_pages = []
        for lp, pg in zip(params["layers"], cache["pages"]):
            x, pk, pv = _paged_block_decode(cfg, lp, x, pg["k"], pg["v"],
                                            table, q_pos, page_size=page_size)
            new_pages.append({"k": pk, "v": pv})

    cache = dict(cache)
    cache["pages"] = new_pages
    cache["len"] = q_pos + 1
    x = rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x), cache


def paged_prefill_chunk(cfg: ModelConfig, params, tokens, pages, table_row,
                        start, valid_len, *, page_size: int):
    """One page-aligned prompt chunk for a single request (chunked prefill).

    tokens: [1, T] (right-padded; first ``valid_len`` real), table_row:
    [n_pt] — the request's page-table row, ``start`` — the absolute position
    of tokens[0].  Reads already-computed context K/V from the pools
    (entries at positions < start; the mask is *strict* so stale data in the
    partially-filled tail page never leaks in), computes the chunk's K/V and
    returns it **without writing**: the engine scatters it into the pools
    afterwards (paged_insert_chunk), which keeps this graph free of pool
    writes and lets it run concurrently with the decode step's.

    Returns (logits [1, Vp] at position start+valid_len-1,
    k_chunk, v_chunk — [L, T, Hkv, hd] stacked or per-layer lists).
    """
    T = tokens.shape[1]
    start = jnp.asarray(start, jnp.int32)
    valid_len = jnp.asarray(valid_len, jnp.int32)
    table_row = jnp.asarray(table_row, jnp.int32)
    pos = start + jnp.arange(T, dtype=jnp.int32)          # [T]
    x = _embed(cfg, params, tokens)                        # rope: positionless
    hd = cfg.resolved_head_dim
    window = _window_for(cfg, "attn")

    def run_block(x, lp, pg):
        ap = lp["attn"]
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,de->bse", h, ap["wq"]).reshape(1, T, cfg.n_heads, hd)
        k = jnp.einsum("bsd,de->bse", h, ap["wk"]).reshape(1, T, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,de->bse", h, ap["wv"]).reshape(1, T, cfg.n_kv_heads, hd)
        q = apply_rope(q, pos[None], cfg.rope_theta)
        k = apply_rope(k, pos[None], cfg.rope_theta)
        pk, pv = pg["k"], pg["v"]
        n_pt = table_row.shape[0]
        ps = page_size
        ctx_k = pk[jnp.maximum(table_row, 0)].reshape(1, n_pt * ps, *pk.shape[2:])
        ctx_v = pv[jnp.maximum(table_row, 0)].reshape(1, n_pt * ps, *pv.shape[2:])
        idx = jnp.arange(n_pt * ps, dtype=jnp.int32)
        mapped = jnp.repeat(table_row >= 0, ps)
        ctx_pos = jnp.where(mapped & (idx < start), idx, -1)
        k_all = jnp.concatenate([ctx_k, k], axis=1)
        v_all = jnp.concatenate([ctx_v, v], axis=1)
        kv_pos = jnp.concatenate([ctx_pos, pos])
        out = masked_attention(q, k_all, v_all, kv_pos, pos, window=window)
        mix = jnp.einsum("bse,ed->bsd", out.reshape(1, T, -1), ap["wo"])
        if cfg.parallel_block:
            mlp_out, _ = _mlp_apply(cfg, lp["mlp"], h)
            return x + mix + mlp_out, (k[0], v[0])
        x = x + mix
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        mlp_out, _ = _mlp_apply(cfg, lp["mlp"], h2)
        return x + mlp_out, (k[0], v[0])

    if _paged_stacked(cfg):
        def body(x, inp):
            lp, pg = inp
            x, kv = run_block(x, lp, pg)
            return x, kv

        x, (k_chunk, v_chunk) = jax.lax.scan(body, x, (params["layers"], pages))
    else:
        k_chunk, v_chunk = [], []
        for lp, pg in zip(params["layers"], pages):
            x, (kc, vc) = run_block(x, lp, pg)
            k_chunk.append(kc)
            v_chunk.append(vc)

    x_last = jax.lax.dynamic_index_in_dim(x, valid_len - 1, axis=1, keepdims=False)
    x_last = rms_norm(x_last, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x_last), k_chunk, v_chunk


def paged_insert_chunk(cfg: ModelConfig, pages, table_row, start, valid_len,
                       k_chunk, v_chunk, *, page_size: int):
    """Scatter a prefill chunk's K/V into the pools through the page table.
    Padded positions (>= valid_len) and unmapped pages redirect out of
    bounds and are dropped."""
    table_row = jnp.asarray(table_row, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    valid_len = jnp.asarray(valid_len, jnp.int32)
    stacked = _paged_stacked(cfg)
    T = (k_chunk.shape[1] if stacked else k_chunk[0].shape[0])
    P = (pages["k"].shape[1] if stacked else pages[0]["k"].shape[0])
    ps = page_size
    idx = start + jnp.arange(T, dtype=jnp.int32)
    phys = table_row[idx // ps]
    off = idx % ps
    drop = (jnp.arange(T) >= valid_len) | (phys < 0)
    phys = jnp.where(drop, P, phys)

    def ins(pool, upd):
        return pool.at[phys, off].set(upd, mode="drop")

    if stacked:
        return {"k": jax.vmap(ins)(pages["k"], k_chunk),
                "v": jax.vmap(ins)(pages["v"], v_chunk)}
    return [{"k": ins(pg["k"], kc), "v": ins(pg["v"], vc)}
            for pg, kc, vc in zip(pages, k_chunk, v_chunk)]


def paged_copy_page(cfg: ModelConfig, pages, src, dst):
    """Copy physical page ``src`` onto ``dst`` in every layer's pools
    (copy-on-write: a new request that shares only part of a registered
    page copies it and overwrites from its first divergent token)."""
    if _paged_stacked(cfg):
        return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), pages)
    return jax.tree.map(lambda a: a.at[dst].set(a[src]), pages)
