"""Griffin RG-LRU recurrent block (recurrentgemma-2b) — arXiv:2402.19427.

Structure (recurrent block): two input branches; the recurrent branch goes
linear -> causal conv1d(4) -> RG-LRU; output is the gated product through an
output projection. The RG-LRU recurrence:

    r_t = sigmoid(W_r x_t)          (recurrence gate)
    i_t = sigmoid(W_i x_t)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Uses the shared chunked linear-recurrence scan (Pallas: kernels/rglru_scan).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import causal_conv1d, linear_recurrence_chunked

__all__ = ["init_rglru_params", "rglru_block", "rglru_decode_step", "init_rglru_cache"]

_C = 8.0


def init_rglru_params(key, cfg, dtype):
    d, r, K = cfg.d_model, cfg.rnn_width, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    sr = r ** -0.5
    return {
        "w_y": (jax.random.normal(ks[0], (d, r)) * s).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (d, r)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (K, r)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((r,), dtype),
        "w_r": (jax.random.normal(ks[3], (r, r)) * sr).astype(dtype),
        "w_i": (jax.random.normal(ks[4], (r, r)) * sr).astype(dtype),
        # Lambda init so that a ~ uniform(0.9, 0.999) at r=0.5 (Griffin A.2-ish)
        "lam": jnp.log(jnp.expm1(jnp.linspace(0.3, 1.3, r))).astype(jnp.float32),
        "w_o": (jax.random.normal(ks[5], (r, d)) * sr).astype(dtype),
    }


def _rglru_gates(params, xc):
    """xc: [B, L, R] post-conv. Returns (a, b) fp32 for the recurrence."""
    r_gate = jax.nn.sigmoid(jnp.einsum("blr,rs->bls", xc, params["w_r"]).astype(jnp.float32))
    i_gate = jax.nn.sigmoid(jnp.einsum("blr,rs->bls", xc, params["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r_gate
    a = jnp.exp(log_a)
    gated_x = i_gate * xc.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated_x
    return a, b


def rglru_block(params, x: jax.Array, *, chunk: int = 128):
    """x: [B, L, D] -> [B, L, D] (train / prefill, h0 = 0)."""
    B, L, _ = x.shape
    y_branch = jax.nn.gelu(jnp.einsum("bld,dr->blr", x, params["w_y"]))
    x_branch = jnp.einsum("bld,dr->blr", x, params["w_x"])
    xc, _ = causal_conv1d(x_branch, params["conv_w"])
    xc = xc + params["conv_b"]

    a, b = _rglru_gates(params, xc)
    h0 = jnp.zeros((B, a.shape[-1]), jnp.float32)
    hs, _ = linear_recurrence_chunked(a, b, h0, chunk=chunk)  # [B, L, R]
    out = (hs.astype(x.dtype) * y_branch)
    return jnp.einsum("blr,rd->bld", out, params["w_o"])


def init_rglru_cache(cfg, batch: int, dtype):
    return {
        "h": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.rnn_width), dtype),
    }


def rglru_decode_step(params, x: jax.Array, cache):
    """x: [B, 1, D] -> ([B, 1, D], new cache)."""
    y_branch = jax.nn.gelu(jnp.einsum("bld,dr->blr", x, params["w_y"]))
    x_branch = jnp.einsum("bld,dr->blr", x, params["w_x"])
    xc, conv_cache = causal_conv1d(x_branch, params["conv_w"], cache["conv"])
    xc = xc + params["conv_b"]
    a, b = _rglru_gates(params, xc)
    h = a[:, 0] * cache["h"] + b[:, 0]  # [B, R]
    out = (h[:, None, :].astype(x.dtype) * y_branch)
    return jnp.einsum("blr,rd->bld", out, params["w_o"]), {"h": h, "conv": conv_cache}
