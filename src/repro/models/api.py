"""Model API: loss, batch construction (real + ShapeDtypeStruct specs),
and analytic FLOPs accounting for the roofline (MODEL_FLOPS = 6·N·D).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from . import transformer

__all__ = [
    "lm_loss",
    "make_batch",
    "input_specs",
    "model_train_flops",
    "model_decode_flops",
    "token_counts",
]

IGNORE = -1  # label id excluded from the loss (e.g. image positions)


def lm_loss(cfg: ModelConfig, params, batch: dict, *, remat: bool = False,
            aux_weight: float = 0.01):
    """Mean next-token cross-entropy (+ MoE aux). Labels = tokens shifted
    inside ``make_batch``; positions with label == IGNORE are masked."""
    logits, aux = transformer.forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    # frontends prepend non-text positions: align logits tail to labels
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1]:]
    mask = (labels != IGNORE) & (labels < cfg.vocab_size)
    safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


def token_counts(cfg: ModelConfig, shape: ShapeSpec) -> tuple[int, int, int]:
    """(batch, text_len, total_seq) honoring frontend stubs: vlm reserves
    n_image_tokens of the sequence budget for patch embeddings."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "vision" and shape.kind != "decode":
        n_img = min(cfg.n_image_tokens, S // 2)
        return B, S - n_img, S
    return B, S, S


def make_batch(cfg: ModelConfig, shape: ShapeSpec, key, *, kind: str | None = None) -> dict:
    """Concrete random batch (smoke tests / examples)."""
    kind = kind or shape.kind
    B, S_text, _ = token_counts(cfg, shape)
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "decode":
        return {"tokens": jax.random.randint(k1, (B, 1), 0, cfg.vocab_size)}
    batch: dict[str, Any] = {
        "tokens": jax.random.randint(k1, (B, S_text), 0, cfg.vocab_size)
    }
    if kind == "train":
        labels = jnp.roll(batch["tokens"], -1, axis=1).at[:, -1].set(IGNORE)
        batch["labels"] = labels
    if cfg.frontend == "vision":
        n_img = min(cfg.n_image_tokens, shape.seq_len // 2)
        batch["image_embeds"] = jax.random.normal(k2, (B, n_img, cfg.d_model), cfg.dtype)
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(k3, (B, cfg.encoder_len, cfg.d_model), cfg.dtype)
    return batch


def input_specs(cfg: ModelConfig, shape: ShapeSpec, *, kind: str | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run lowering —
    weak-type-correct, no device allocation)."""
    kind = kind or shape.kind
    B, S_text, _ = token_counts(cfg, shape)
    i32 = jnp.int32
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    specs: dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((B, S_text), i32)}
    if kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S_text), i32)
    if cfg.frontend == "vision":
        n_img = min(cfg.n_image_tokens, shape.seq_len // 2)
        specs["image_embeds"] = jax.ShapeDtypeStruct((B, n_img, cfg.d_model), cfg.dtype)
    if cfg.frontend == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_len, cfg.d_model), cfg.dtype)
    return specs


def model_train_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS for a train step: 6·N·D (N = active params, D = tokens).

    The standard accounting (Kaplan): 2ND forward + 4ND backward, attention
    excluded (reported separately in the roofline table's notes).
    """
    tokens = shape.global_batch * shape.seq_len
    return 6.0 * cfg.active_params() * tokens


def model_decode_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS for one decode step: 2·N_active·B (one token per seq)."""
    return 2.0 * cfg.active_params() * shape.global_batch


def model_prefill_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS for a prefill (forward only): 2·N_active·tokens."""
    tokens = shape.global_batch * shape.seq_len
    return 2.0 * cfg.active_params() * tokens


def model_flops(cfg: ModelConfig, shape: ShapeSpec, kind: str | None = None) -> float:
    kind = kind or shape.kind
    if kind == "train":
        return model_train_flops(cfg, shape)
    if kind == "prefill":
        return model_prefill_flops(cfg, shape)
    return model_decode_flops(cfg, shape)
