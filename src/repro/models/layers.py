"""Shared neural-net layers (pure JAX, functional).

Attention is *chunked* (online-softmax over KV chunks, flash-attention
semantics in pure jnp) so that 32k+ sequences never materialize an
[S, S] score matrix — this keeps the dry-run HLO's memory roofline honest
and matches the Pallas kernel's blocking (kernels/flash_attention).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "apply_rope",
    "sinusoidal_positions",
    "glu_ffn",
    "chunked_attention",
    "decode_attention",
    "masked_attention",
    "causal_conv1d",
    "linear_recurrence_chunked",
]

_NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, hd]; positions: [..., S]."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Whisper-style sinusoidal absolute position table [n, d]."""
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = jnp.arange(n, dtype=jnp.float32)[:, None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def glu_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array, act: str) -> jax.Array:
    """SwiGLU (act='silu') / GeGLU (act='gelu') feed-forward.

    Megatron-SP constraints made explicit (no-ops without a mesh): the
    hidden is column-sharded with the sequence *gathered*, the output
    returns to sequence-sharded.  This pins BOTH directions of the VJP:
    dY gathers over seq before the dW einsum (local column dW — no
    full-matrix gradient all-reduce) and dX reduce-scatters.  Without
    these, GSPMD picked partial-dW + f32 full all-reduce per layer per
    microbatch — 2.6 TB/step/device on command-r (EXPERIMENTS.md §Perf C1).
    """
    from repro.dist.sharding import shard

    a = jnp.einsum("...d,df->...f", x, w_gate)
    a = shard(a, "batch", None, "model")
    a = jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a)
    b = jnp.einsum("...d,df->...f", x, w_up)
    b = shard(b, "batch", None, "model")
    out = jnp.einsum("...f,fd->...d", a * b, w_down)
    return shard(out, "batch", "seq", None)


def _mask_chunk(
    q_pos: jax.Array,  # [Sq]
    kv_pos: jax.Array,  # [Ck]
    *,
    causal: bool,
    window: int | None,
    kv_len: jax.Array | None,
) -> jax.Array:
    """Boolean keep-mask [Sq, Ck]."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= kv_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        m &= kv_pos[None, :] < kv_len
    return m


def chunked_attention(
    q: jax.Array,  # [B, Sq, Hq, hd]
    k: jax.Array,  # [B, Skv, Hkv, hd]
    v: jax.Array,  # [B, Skv, Hkv, hd]
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    kv_len: jax.Array | None = None,
    chunk: int = 2048,
    q_chunk: int = 2048,
) -> jax.Array:
    """GQA attention, online-softmax over KV chunks AND blocked over Q chunks
    (flash semantics in both directions: peak temp is one
    [B, q_chunk, H, chunk] score tile, never [Sq, Skv]).

    Returns [B, Sq, Hq, hd]. ``kv_len``: optional valid KV length (decode
    against a longer cache). ``q_offset``: absolute position of q[0].
    """
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    if Hq % Hkv != 0:
        raise ValueError(f"n_heads {Hq} not a multiple of n_kv_heads {Hkv}")
    G = Hq // Hkv
    scale = hd ** -0.5

    if chunk <= 0 or Skv % chunk != 0:
        chunk = Skv  # small sequences: single chunk
    n_kv = Skv // chunk
    if q_chunk <= 0 or Sq % q_chunk != 0:
        q_chunk = Sq  # q_chunk=0: no q loop (q rows sharded over the mesh)
    n_q = Sq // q_chunk

    qg = q.reshape(B, n_q, q_chunk, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5) * scale
    kc = k.reshape(B, n_kv, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_kv, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)

    def one_q_block(inp):
        qi, qb = inp  # qb: [B, q_chunk, Hkv, G, hd]
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        acc0 = jnp.zeros((B, q_chunk, Hkv, G, hd), jnp.float32)
        m0 = jnp.full((B, q_chunk, Hkv, G), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, Hkv, G), jnp.float32)

        def step(carry, inp):
            acc, m, l = carry
            ci, kb, vb = inp
            kv_pos = ci * chunk + jnp.arange(chunk)
            keep = _mask_chunk(q_pos, kv_pos, causal=causal, window=window, kv_len=kv_len)
            # scores: [B, Cq, Hkv, G, Ck]
            s = jnp.einsum("bqhgd,bchd->bqhgc", qb, kb).astype(jnp.float32)
            s = jnp.where(keep[None, :, None, None, :], s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bqhgc,bchd->bqhgd", p.astype(kb.dtype), vb).astype(jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        (acc, m, l), _ = jax.lax.scan(
            step, (acc0, m0, l0), (jnp.arange(n_kv), kc, vc)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)  # [B, Cq, Hkv, G, hd]

    if n_q == 1:
        out = one_q_block((jnp.asarray(0), qg[0]))[:, None]
        out = out.reshape(B, 1, q_chunk, Hq, hd)
    else:
        out = jax.lax.map(one_q_block, (jnp.arange(n_q), qg))  # [n_q, B, Cq, Hkv, G, hd]
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, n_q, q_chunk, Hq, hd)
    return out.reshape(B, Sq, Hq, hd)


def decode_attention(
    q: jax.Array,        # [B, 1, Hq, hd]
    k_cache: jax.Array,  # [B, Smax, Hkv, hd] (linear or ring buffer)
    v_cache: jax.Array,
    kv_pos: jax.Array,   # [Smax] | [B, Smax] absolute position per slot; -1 = empty
    q_pos: jax.Array,    # [] | [B] absolute position of the query token
    *,
    window: int | None = None,
) -> jax.Array:
    """Single-token attention against a KV cache (memory-bound path).

    Slot-position masking handles both linear caches (kv_pos = 0..len-1,
    rest -1) and ring buffers for sliding-window archs (slot s holds absolute
    position kv_pos[s]).  A 2-D ``kv_pos`` (with ``q_pos`` per batch row)
    is the continuous-batching layout: every row is an independent request
    at its own decode position over its own slice of the shared cache.
    """
    B, _, Hq, hd = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd) * hd ** -0.5
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache).astype(jnp.float32)
    if kv_pos.ndim == 2:
        qp = q_pos[:, None]
        keep = (kv_pos >= 0) & (kv_pos <= qp)
        if window is not None:
            keep &= kv_pos > qp - window
        keep = keep[:, None, None, :]
    else:
        keep = (kv_pos >= 0) & (kv_pos <= q_pos)
        if window is not None:
            keep &= kv_pos > q_pos - window
        keep = keep[None, None, None, :]
    s = jnp.where(keep, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, Hq, hd).astype(q.dtype)


def masked_attention(
    q: jax.Array,        # [B, Sq, Hq, hd]
    k: jax.Array,        # [B, Skv, Hkv, hd]
    v: jax.Array,
    kv_pos: jax.Array,   # [Skv] | [B, Skv] absolute position per entry; -1 = empty
    q_pos: jax.Array,    # [Sq]  | [B, Sq] absolute position per query row
    *,
    window: int | None = None,
) -> jax.Array:
    """Position-table-masked GQA attention for ``Sq >= 1`` query rows.

    The multi-token generalization of :func:`decode_attention` (identical
    masking semantics and softmax math): every (query, entry) pair is kept
    iff the entry is occupied, causally visible, and inside the sliding
    window.  Used by the paged chunked-prefill path, where a prompt chunk
    attends to gathered context pages (arbitrary position tables) plus its
    own freshly-computed K/V.
    """
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd) * hd ** -0.5
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k).astype(jnp.float32)
    kvp = kv_pos if kv_pos.ndim == 2 else kv_pos[None]    # [B|1, Skv]
    qp = q_pos if q_pos.ndim == 2 else q_pos[None]        # [B|1, Sq]
    keep = (kvp[:, None, :] >= 0) & (kvp[:, None, :] <= qp[:, :, None])
    if window is not None:
        keep &= kvp[:, None, :] > qp[:, :, None] - window
    s = jnp.where(keep[:, None, None, :, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def causal_conv1d(x: jax.Array, w: jax.Array, cache: jax.Array | None = None):
    """Depthwise causal conv along the sequence axis.

    x: [B, S, C]; w: [K, C]. Returns ([B, S, C], new_cache [B, K-1, C]).
    ``cache`` carries the last K-1 positions for streaming decode.
    """
    K = w.shape[0]
    if cache is None:
        cache = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([cache, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    new_cache = xp[:, -(K - 1):, :] if K > 1 else cache
    return out.astype(x.dtype), new_cache


def linear_recurrence_chunked(
    a: jax.Array,  # [B, S, ...] decay
    b: jax.Array,  # [B, S, ...] input
    h0: jax.Array,  # [B, ...] initial state
    *,
    chunk: int = 128,
):
    """h_t = a_t * h_{t-1} + b_t along axis 1, returning (all h [B,S,...], h_S).

    Chunked: lax.scan over S/chunk chunks; inside a chunk, an associative
    scan. This bounds temporaries to O(chunk) (kernel-like blocking; the
    Pallas ssm_scan kernel implements the same schedule in VMEM).
    """
    B, S = a.shape[0], a.shape[1]
    if S % chunk != 0:
        chunk = S
    n = S // chunk

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    ac = jnp.moveaxis(a.reshape((B, n, chunk) + a.shape[2:]), 1, 0)
    bc = jnp.moveaxis(b.reshape((B, n, chunk) + b.shape[2:]), 1, 0)

    def step(h, inp):
        a_blk, b_blk = inp  # [B, chunk, ...]
        a_cum, b_scan = jax.lax.associative_scan(combine, (a_blk, b_blk), axis=1)
        h_blk = a_cum * h[:, None] + b_scan
        return h_blk[:, -1], h_blk

    h_last, hs = jax.lax.scan(step, h0, (ac, bc))
    hs = jnp.moveaxis(hs, 0, 1).reshape((B, S) + a.shape[2:])
    return hs, h_last
