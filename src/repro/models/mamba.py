"""Mamba-1 block (falcon-mamba-7b) — selective state-space model.

Train/prefill uses the chunked linear-recurrence scan
(layers.linear_recurrence_chunked; Pallas kernel: kernels/ssm_scan).
Decode is a single-step state update against an SSM state cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import causal_conv1d, linear_recurrence_chunked

__all__ = [
    "init_mamba_params",
    "mamba_block",
    "ssm_scan_fused",
    "mamba_decode_step",
    "init_mamba_cache",
]


def init_mamba_params(key, cfg, dtype):
    d, di, st, dr, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (K, di)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(ks[2], (di, dr + 2 * st)) * di ** -0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (dr, di)) * dr ** -0.5).astype(dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01))).astype(jnp.float32),
        # S4D-real init: A = -(1..state)
        "A_log": jnp.log(jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (di, d)) * di ** -0.5).astype(dtype),
    }


def _ssm_inputs(params, xconv, dtype):
    """Shared projection math. xconv: [B, L, di] post-conv post-silu."""
    dbc = jnp.einsum("bld,de->ble", xconv, params["x_proj"])
    dr = params["dt_proj"].shape[0]
    st = params["A_log"].shape[1]
    dt, B_ssm, C_ssm = jnp.split(dbc, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt, params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"]
    )  # [B, L, di]
    A = -jnp.exp(params["A_log"])  # [di, st]
    # discretize: a = exp(dt*A) [B,L,di,st]; b = dt*B*x
    a = jnp.exp(dt[..., None] * A)
    b = dt[..., None] * B_ssm[:, :, None, :].astype(jnp.float32) * xconv[..., None].astype(jnp.float32)
    return a, b, C_ssm


def ssm_scan_fused(params, xconv: jax.Array, h0: jax.Array, *, chunk: int = 128):
    """Chunk-fused selective scan: discretization (a = exp(dt*A), b = dt*B*x)
    is constructed INSIDE the chunk body and contracted with C immediately,
    so the [B, L, d_inner, state] f32 tensors never materialize — only one
    [B, chunk, d_inner, state] tile is live per step (the jnp mirror of the
    kernels/ssm_scan VMEM schedule).  The unfused formulation dominated
    falcon-mamba's memory roofline at ~1.4 TB/step/device
    (EXPERIMENTS.md §Perf D1).

    xconv: [B, L, di] post-conv/silu.  Returns (y [B, L, di] f32, h_last).
    """
    B, L, di = xconv.shape
    if L % chunk != 0:
        chunk = L
    n = L // chunk
    xc = jnp.moveaxis(xconv.reshape(B, n, chunk, di), 1, 0)  # [n, B, chunk, di]

    def combine(u, v):
        a1, b1 = u
        a2, b2 = v
        return a1 * a2, a2 * b1 + b2

    def body(h, x_blk):
        a, b, C_blk = _ssm_inputs(params, x_blk, xconv.dtype)   # [B,chunk,di,st]
        a_cum, b_scan = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = a_cum * h[:, None] + b_scan
        y = jnp.einsum("blds,bls->bld", hs, C_blk.astype(jnp.float32))
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(body, h0, xc)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, L, di)
    return y, h_last


def mamba_block(params, x: jax.Array, *, chunk: int = 128):
    """x: [B, L, D] -> [B, L, D] (training / prefill path, h0 = 0)."""
    B, L, D = x.shape
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"])
    xpart, res = jnp.split(xz, 2, axis=-1)  # [B, L, di] each
    xconv, _ = causal_conv1d(xpart, params["conv_w"])
    xconv = jax.nn.silu(xconv + params["conv_b"])

    di, st = params["A_log"].shape
    h0 = jnp.zeros((B, di, st), jnp.float32)
    y, _ = ssm_scan_fused(params, xconv, h0, chunk=chunk)
    y = y + params["D"] * xconv.astype(jnp.float32)
    y = y * jax.nn.silu(res.astype(jnp.float32))
    return jnp.einsum("bld,de->ble", y.astype(x.dtype), params["out_proj"])


def init_mamba_cache(cfg, batch: int, dtype):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
    }


def mamba_decode_step(params, x: jax.Array, cache):
    """Single-token step. x: [B, 1, D] -> ([B, 1, D], new cache)."""
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"])
    xpart, res = jnp.split(xz, 2, axis=-1)
    xconv, conv_cache = causal_conv1d(xpart, params["conv_w"], cache["conv"])
    xconv = jax.nn.silu(xconv + params["conv_b"])

    a, b, C_ssm = _ssm_inputs(params, xconv, x.dtype)  # L = 1
    h = a[:, 0] * cache["h"] + b[:, 0]  # [B, di, st]
    y = jnp.einsum("bds,bs->bd", h, C_ssm[:, 0].astype(jnp.float32))[:, None, :]
    y = y + params["D"] * xconv.astype(jnp.float32)
    y = y * jax.nn.silu(res.astype(jnp.float32))
    out = jnp.einsum("bld,de->ble", y.astype(x.dtype), params["out_proj"])
    return out, {"h": h, "conv": conv_cache}
