"""Model zoo: unified transformer/SSM/hybrid backbones + paper nets."""
from . import api, transformer
from .api import (
    input_specs,
    lm_loss,
    make_batch,
    model_decode_flops,
    model_train_flops,
)
from .transformer import decode_step, forward, init_cache, init_params, prefill

__all__ = [
    "api",
    "transformer",
    "input_specs",
    "lm_loss",
    "make_batch",
    "model_decode_flops",
    "model_train_flops",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "prefill",
]
