"""The paper's four evaluation networks as computation graphs (Table 1).

These drive every paper-table reproduction benchmark: the graphs carry the
roofline statistics (flops / bytes per op, fp32 as on KNL+MKL) that the cost
model turns into per-op durations, and the DAG structure the schedulers
exploit.  Sizes follow Table 1 exactly:

* LSTM / PhasedLSTM (1a): seq x neurons = 20x128 / 30x512 / 40x1024, batch 64,
  4 layers (§7.3), PTB-style V=10k softmax head ([65] / TF benchmark).
* PathNet (1b): image x neurons = 32x16 / 48x32 / 64x48, batch 64; 3 layers,
  6 active modules/layer, each module conv3x3 -> relu -> pool2x2 (§7.1).
* GoogleNet (1c): image x width = 128x1 / 192x2 / 256x4, batch 32; the
  standard 9-inception-module network [58] with every filter count x width.

``training_graph`` mirrors a forward graph with backward ops (reverse deps,
~2x flops — dX and dW each cost about one forward pass), reproducing the
paper's observation that backward doubles the available parallelism.
"""
from __future__ import annotations

from repro.core.graph import Graph, OpNode

__all__ = [
    "PAPER_NETS",
    "PAPER_SIZES",
    "paper_graph",
    "training_graph",
    "lstm_forward_graph",
    "pathnet_forward_graph",
    "googlenet_forward_graph",
]

F32 = 4  # bytes; the paper's MKL/LIBXSMM path is single precision

PAPER_NETS = ("lstm", "phased_lstm", "pathnet", "googlenet")

# Table 1 parameters: net -> size -> (primary, secondary)
PAPER_SIZES: dict[str, dict[str, tuple[int, int]]] = {
    "lstm": {"small": (20, 128), "medium": (30, 512), "large": (40, 1024)},
    "phased_lstm": {"small": (20, 128), "medium": (30, 512), "large": (40, 1024)},
    "pathnet": {"small": (32, 16), "medium": (48, 32), "large": (64, 48)},
    "googlenet": {"small": (128, 1), "medium": (192, 2), "large": (256, 4)},
}

PAPER_BATCH = {"lstm": 64, "phased_lstm": 64, "pathnet": 64, "googlenet": 32}

LSTM_LAYERS = 4
LSTM_VOCAB = 10_000       # PTB softmax head ([65])
PATHNET_LAYERS = 3
PATHNET_MODULES = 6
PATHNET_CLASSES = 10


# ---------------------------------------------------------------------------
# node helpers (fp32 roofline stats)
# ---------------------------------------------------------------------------

def _gemm(g: Graph, name: str, M: int, K: int, N: int, deps=()) -> OpNode:
    return g.add_op(
        name, kind="gemm",
        flops=2.0 * M * K * N,
        bytes_in=(M * K + K * N) * F32,
        bytes_out=M * N * F32,
        deps=tuple(deps),
        meta={"rows": M, "mnk": (M, N, K)},
    )


def _conv(
    g: Graph, name: str, B: int, H: int, W: int, Cin: int, Cout: int,
    k: int, stride: int = 1, deps=(),
) -> OpNode:
    Ho, Wo = H // stride, W // stride
    return g.add_op(
        name, kind="conv",
        flops=2.0 * B * Ho * Wo * Cout * Cin * k * k,
        bytes_in=(B * H * W * Cin + Cin * Cout * k * k) * F32,
        bytes_out=B * Ho * Wo * Cout * F32,
        deps=tuple(deps),
        meta={"out_hw": (Ho, Wo), "out_c": Cout},
    )


def _ew(g: Graph, name: str, numel: int, ops_per_elt: float = 1.0, deps=(), n_in: int = 1) -> OpNode:
    return g.add_op(
        name, kind="elementwise",
        flops=ops_per_elt * numel,
        bytes_in=n_in * numel * F32,
        bytes_out=numel * F32,
        deps=tuple(deps),
    )


def _pool(g: Graph, name: str, B: int, H: int, W: int, C: int, k: int, stride: int, deps=()) -> OpNode:
    Ho, Wo = H // stride, W // stride
    return g.add_op(
        name, kind="pool",
        flops=float(B * Ho * Wo * C * k * k),
        bytes_in=B * H * W * C * F32,
        bytes_out=B * Ho * Wo * C * F32,
        deps=tuple(deps),
        meta={"out_hw": (Ho, Wo), "out_c": C},
    )


# ---------------------------------------------------------------------------
# LSTM / PhasedLSTM
# ---------------------------------------------------------------------------

def lstm_forward_graph(size: str, *, phased: bool = False, batch: int | None = None) -> Graph:
    """4-layer (Phased)LSTM unrolled over the sequence.

    Per cell (l,t): two GEMMs [B,H]x[H,4H] (input & recurrent — independent,
    the paper's "2-3 parallel operators in each cell") feeding one fused
    gate/elementwise op.  PhasedLSTM adds the time-gate elementwise op (k/phi
    oscillation masks) per cell — same GEMMs, slightly wider graph.
    """
    T, H = PAPER_SIZES["phased_lstm" if phased else "lstm"][size]
    B = batch or PAPER_BATCH["lstm"]
    name = ("phased_lstm" if phased else "lstm") + f"_{size}"
    g = Graph(name)
    for t in range(T):
        g.add_op(f"x_T{t}", kind="input", bytes_out=B * H * F32)
    cell_out: dict[tuple[int, int], str] = {}
    for t in range(T):
        for l in range(LSTM_LAYERS):
            below = f"x_T{t}" if l == 0 else cell_out[(l - 1, t)]
            gx = _gemm(g, f"gx_L{l}_T{t}", B, H, 4 * H, deps=[below])
            hdeps = [cell_out[(l, t - 1)]] if t > 0 else []
            gh = _gemm(g, f"gh_L{l}_T{t}", B, H, 4 * H, deps=hdeps)
            # i,f,g,o sigmoid/tanh + cell update: ~8 transcendental-ish ops/elt
            ew = _ew(g, f"ew_L{l}_T{t}", B * 4 * H, 8.0, deps=[gx.name, gh.name], n_in=2)
            out = ew.name
            if phased:
                kg = _ew(g, f"kgate_L{l}_T{t}", B * H, 6.0, deps=[ew.name], n_in=2)
                out = kg.name
            cell_out[(l, t)] = out
            # annotate wavefront coordinates for the cuDNN-diagonal check
            names = {gx.name, gh.name, ew.name, out}
            for nm in names:
                node = g[nm]
                object.__setattr__(node, "meta", {**node.meta, "layer": l, "step": t, "diag": l + t})
    # [65]-style head: concat all top-layer states -> ONE [B*T, H] x [H, V]
    # softmax GEMM (per-step heads would add fake width the real net lacks)
    _ew(g, "concat_h", B * T * H, 0.0,
        deps=[cell_out[(LSTM_LAYERS - 1, t)] for t in range(T)], n_in=1)
    _gemm(g, "softmax", B * T, H, LSTM_VOCAB, deps=["concat_h"])
    _ew(g, "loss", B * T, 2.0, deps=["softmax"])
    return g


# ---------------------------------------------------------------------------
# PathNet
# ---------------------------------------------------------------------------

def pathnet_forward_graph(size: str, *, batch: int | None = None) -> Graph:
    """3 layers x 6 parallel modules; module = conv3x3 -> relu -> pool2x2;
    module outputs of a layer are summed before the next layer (§7.1)."""
    I, N = PAPER_SIZES["pathnet"][size]
    B = batch or PAPER_BATCH["pathnet"]
    g = Graph(f"pathnet_{size}")
    g.add_op("input", kind="input", bytes_out=B * I * I * 3 * F32)
    prev, hw, cin = "input", I, 3
    for l in range(PATHNET_LAYERS):
        outs = []
        for m in range(PATHNET_MODULES):
            c = _conv(g, f"conv_L{l}_M{m}", B, hw, hw, cin, N, 3, deps=[prev])
            r = _ew(g, f"relu_L{l}_M{m}", B * hw * hw * N, 1.0, deps=[c.name])
            p = _pool(g, f"pool_L{l}_M{m}", B, hw, hw, N, 2, 2, deps=[r.name])
            outs.append(p.name)
        hw //= 2
        agg = _ew(g, f"agg_L{l}", B * hw * hw * N, float(PATHNET_MODULES),
                  deps=outs, n_in=PATHNET_MODULES)
        prev, cin = agg.name, N
    _gemm(g, "fc", B, N * hw * hw, PATHNET_CLASSES, deps=[prev])
    _ew(g, "loss", B * PATHNET_CLASSES, 2.0, deps=["fc"])
    return g


# ---------------------------------------------------------------------------
# GoogleNet
# ---------------------------------------------------------------------------

# standard inception filter table [58]: (c1, c3r, c3, c5r, c5, pool_proj)
_INCEPTION = {
    "3a": (64, 96, 128, 16, 32, 32),
    "3b": (128, 128, 192, 32, 96, 64),
    "4a": (192, 96, 208, 16, 48, 64),
    "4b": (160, 112, 224, 24, 64, 64),
    "4c": (128, 128, 256, 24, 64, 64),
    "4d": (112, 144, 288, 32, 64, 64),
    "4e": (256, 160, 320, 32, 128, 128),
    "5a": (256, 160, 320, 32, 128, 128),
    "5b": (384, 192, 384, 48, 128, 128),
}


def googlenet_forward_graph(size: str, *, batch: int | None = None) -> Graph:
    """GoogleNet with every filter count scaled by the Table-1c width
    multiplier.  Each inception module has 4 parallel branches (the paper's
    "2-3 parallel convolution/pooling operations" plus the pool-proj)."""
    I, w = PAPER_SIZES["googlenet"][size]
    B = batch or PAPER_BATCH["googlenet"]
    g = Graph(f"googlenet_{size}")
    g.add_op("input", kind="input", bytes_out=B * I * I * 3 * F32)

    # stem
    c1 = _conv(g, "stem_conv7", B, I, I, 3, 64 * w, 7, 2, deps=["input"])
    hw = I // 2
    p1 = _pool(g, "stem_pool1", B, hw, hw, 64 * w, 3, 2, deps=[c1.name])
    hw //= 2
    c2 = _conv(g, "stem_conv1", B, hw, hw, 64 * w, 64 * w, 1, deps=[p1.name])
    c3 = _conv(g, "stem_conv3", B, hw, hw, 64 * w, 192 * w, 3, deps=[c2.name])
    p2 = _pool(g, "stem_pool2", B, hw, hw, 192 * w, 3, 2, deps=[c3.name])
    hw //= 2
    prev, cin = p2.name, 192 * w

    for mod, (c1f, c3r, c3f, c5r, c5f, pp) in _INCEPTION.items():
        c1f, c3r, c3f, c5r, c5f, pp = (x * w for x in (c1f, c3r, c3f, c5r, c5f, pp))
        b1 = _conv(g, f"i{mod}_1x1", B, hw, hw, cin, c1f, 1, deps=[prev])
        b2a = _conv(g, f"i{mod}_3x3r", B, hw, hw, cin, c3r, 1, deps=[prev])
        b2 = _conv(g, f"i{mod}_3x3", B, hw, hw, c3r, c3f, 3, deps=[b2a.name])
        b3a = _conv(g, f"i{mod}_5x5r", B, hw, hw, cin, c5r, 1, deps=[prev])
        b3 = _conv(g, f"i{mod}_5x5", B, hw, hw, c5r, c5f, 5, deps=[b3a.name])
        b4a = _pool(g, f"i{mod}_pool", B, hw, hw, cin, 3, 1, deps=[prev])
        b4 = _conv(g, f"i{mod}_poolproj", B, hw, hw, cin, pp, 1, deps=[b4a.name])
        cin = c1f + c3f + c5f + pp
        cat = _ew(g, f"i{mod}_concat", B * hw * hw * cin, 0.0,
                  deps=[b1.name, b2.name, b3.name, b4.name], n_in=1)
        prev = cat.name
        if mod in ("3b", "4e"):
            pl = _pool(g, f"pool_after_{mod}", B, hw, hw, cin, 3, 2, deps=[prev])
            hw //= 2
            prev = pl.name

    ap = _pool(g, "avgpool", B, hw, hw, cin, hw, hw, deps=[prev])
    _gemm(g, "fc", B, cin, 1000, deps=[ap.name])
    _ew(g, "loss", B * 1000, 2.0, deps=["fc"])
    return g


# ---------------------------------------------------------------------------
# forward -> training graph
# ---------------------------------------------------------------------------

def training_graph(fwd: Graph, *, bwd_flops_ratio: float = 2.0) -> Graph:
    """Mirror a forward graph with backward ops.

    d_<op> depends on every d_<successor> (reverse data flow) plus <op>
    itself (its saved activations).  Costs: backward of one op computes both
    dX and dW — about 2x the forward flops, same traffic class.  Sources
    (inputs) get no backward node; the loss's backward seeds the sweep.
    """
    g = Graph(fwd.name + "_train")
    for n in fwd.topo_order():
        node = fwd[n]
        g.add(OpNode(
            name=node.name, kind=node.kind, flops=node.flops,
            bytes_in=node.bytes_in, bytes_out=node.bytes_out,
            deps=node.deps, meta=dict(node.meta),
        ))
    for n in reversed(fwd.topo_order()):
        node = fwd[n]
        if node.kind == "input":
            continue
        succs = [s for s in fwd.successors(n) if fwd[s].kind != "input"]
        deps = [f"d_{s}" for s in succs if f"d_{s}" in g] + [n]
        g.add(OpNode(
            name=f"d_{n}", kind=node.kind,
            flops=node.flops * bwd_flops_ratio,
            bytes_in=node.bytes_in + node.bytes_out,
            bytes_out=node.bytes_in,
            deps=tuple(deps),
            meta={**dict(node.meta), "backward": True},
        ))
    return g


def paper_graph(net: str, size: str, *, training: bool = True, batch: int | None = None) -> Graph:
    """Registry entry: Table-1 network graph (training by default — one
    complete execution = one training iteration, §2)."""
    if net == "lstm":
        fwd = lstm_forward_graph(size, phased=False, batch=batch)
    elif net == "phased_lstm":
        fwd = lstm_forward_graph(size, phased=True, batch=batch)
    elif net == "pathnet":
        fwd = pathnet_forward_graph(size, batch=batch)
    elif net == "googlenet":
        fwd = googlenet_forward_graph(size, batch=batch)
    else:
        raise ValueError(f"unknown paper net {net!r} (one of {PAPER_NETS})")
    return training_graph(fwd) if training else fwd
