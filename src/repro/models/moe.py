"""Mixture-of-Experts FFN: grouped top-k routing with capacity-based
gather dispatch.

**Grouped routing** (t5x/GShard ``num_groups`` style): tokens are split into
G groups (G = the mesh's data-parallel extent, so each group is resident on
one data shard) and routed *independently* per group with per-group
capacity.  Every routing/cumsum/gather/combine op then has a leading
group axis sharded over 'data', so dispatch is **local** to the shard; the
only cross-device movement is the expert einsum's token<->expert exchange
(experts shard over the model axis).  With global (ungrouped) routing,
GSPMD lowers the cross-shard slot gather as masked-gather + giant
all-reduces — observed 3.4 TB/step/device on granite train_4k before this
change (EXPERIMENTS.md §Perf iteration 2).

Dropped tokens (over per-group capacity) contribute zero — standard Switch
behaviour.  Returns the load-balancing aux loss alongside the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import mesh_context, shard

__all__ = ["moe_ffn", "init_moe_params"]


def init_moe_params(key, d_model: int, d_ff: int, n_experts: int, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "router": (jax.random.normal(k1, (d_model, n_experts)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (n_experts, d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k3, (n_experts, d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k4, (n_experts, d_ff, d_model)) * s_out).astype(dtype),
    }


def _infer_groups(T: int) -> int:
    """Groups = data-parallel extent when it divides the tokens (each group
    lives on one data shard); 1 otherwise (single-device tests)."""
    ctx = mesh_context()
    if ctx is None:
        return 1
    dp = ctx.extent(ctx.resolve("batch"))
    return dp if dp > 1 and T % dp == 0 else 1


def moe_ffn(
    params,
    x: jax.Array,  # [B, S, D]
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
    n_groups: int | None = None,
):
    """Returns (out [B, S, D], aux_loss scalar)."""
    B, S, D = x.shape
    T = B * S
    E = params["router"].shape[-1]
    G = n_groups or _infer_groups(T)
    Tg = T // G
    # per-group slots per expert; multiple of 8 keeps lanes aligned
    capacity = max(top_k, int(round(Tg * top_k * capacity_factor / E)))
    if Tg >= 8:
        capacity = -(-capacity // 8) * 8

    xg = x.reshape(G, Tg, D)
    xg = shard(xg, "batch", None, None)

    # --- routing (fp32), all ops carry the leading G axis ---
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # [G, Tg, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing loss (Switch eq.4): E * sum_e f_e * p_e, averaged over groups
    me = probs.mean(axis=1)                               # [G, E]

    # --- per-group capacity assignment (cumsum within the group) ---
    g_iota = jnp.arange(G, dtype=jnp.int32)[:, None]      # [G, 1]
    counts = jnp.zeros((G, E), jnp.int32)
    frac = jnp.zeros((G, E), jnp.float32)
    slot_tok = jnp.zeros((G, E, capacity + 1), jnp.int32)  # last col = trash
    positions, keep_masks = [], []
    for r in range(top_k):
        e_r = expert_idx[..., r]                          # [G, Tg]
        onehot = jax.nn.one_hot(e_r, E, dtype=jnp.int32)  # [G, Tg, E]
        frac = frac + onehot.sum(1).astype(jnp.float32)
        pos_in_e = (jnp.cumsum(onehot, axis=1) - 1) * onehot
        pos_r = pos_in_e.sum(-1) + jnp.take_along_axis(counts, e_r, axis=1)
        counts = counts + onehot.sum(1)
        within = pos_r < capacity
        pos_r = jnp.where(within, pos_r, capacity)        # [G, Tg]
        positions.append(pos_r)
        keep_masks.append(within)
        slot_tok = slot_tok.at[g_iota, e_r, pos_r].set(
            jnp.broadcast_to(jnp.arange(Tg, dtype=jnp.int32)[None], (G, Tg))
        )

    aux_loss = E * jnp.mean(jnp.sum(me * (frac / (Tg * top_k)), axis=-1))

    # --- expert computation over locally gathered slots ---
    # experts = the Graphi "executor groups" (EP over the model axis);
    # groups shard over data, so the gather below is shard-local and only
    # the expert einsum moves tokens across the mesh.
    src = slot_tok[:, :, :capacity]                       # [G, E, C]
    xin = jax.vmap(lambda xr, sr: xr[sr.reshape(-1)])(xg, src)  # batched local gather
    xin = xin.reshape(G, E, capacity, D)
    xin = shard(xin, "batch", "model", None, None)

    h = jnp.einsum("gecd,edf->gecf", xin, params["w_gate"])
    h = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    u = jnp.einsum("gecd,edf->gecf", xin, params["w_up"])
    y = jnp.einsum("gecf,efd->gecd", h * u, params["w_down"])  # [G, E, C, D]
    y = shard(y, "batch", "model", None, None)

    # --- combine: token t pulls its slot output, weighted by its gate ---
    # (the cross-shard combine lowers to a masked gather + f32 tuple
    # all-reduce over the expert axis; attempts to steer it to bf16 via
    # dtype/constraint placement did not change the lowering — see
    # EXPERIMENTS.md §Perf iteration A2, refuted)
    out = jnp.zeros((G, Tg, D), jnp.float32)
    flat_y = y.reshape(G, E * capacity, D)
    for r in range(top_k):
        e_r = expert_idx[..., r]
        pos_r = jnp.minimum(positions[r], capacity - 1)
        idx = e_r * capacity + pos_r                      # [G, Tg]
        y_r = jax.vmap(lambda yr, ir: yr[ir])(flat_y, idx)  # [G, Tg, D]
        w = (gate_vals[..., r] * keep_masks[r]).astype(jnp.float32)
        out = out + w[..., None] * y_r.astype(jnp.float32)

    return out.reshape(B, S, D).astype(x.dtype), aux_loss
