"""yi-9b [dense] — arXiv:2403.04652. llama-arch GQA (kv=4), SwiGLU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64_000,
    act="silu",
    source="arXiv:2403.04652; hf",
)

SMOKE = CONFIG.reduced(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=512,
)
