"""whisper-medium [audio] — arXiv:2212.04356. Encoder-decoder transformer
backbone; the conv frontend is a STUB (input_specs() provides precomputed
frame embeddings for the encoder). Decoder has cross-attention."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,            # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    act="gelu",
    cross_attention=True,
    frontend="audio",
    encoder_len=1500,
    rope_theta=0.0,         # whisper uses learned/sinusoidal positions
    source="arXiv:2212.04356; unverified",
)

SMOKE = CONFIG.reduced(
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, encoder_len=16,
)
