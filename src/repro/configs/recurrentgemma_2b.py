"""recurrentgemma-2b [hybrid] — arXiv:2402.19427 (Griffin). RG-LRU recurrent
blocks + local (sliding-window) MQA, pattern 2 recurrent : 1 attention.
head_dim=256, GeGLU. The flagship wavefront-scheduling arch (DESIGN §6)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "attn"),
    sliding_window=2048,
    lru_width=2560,
    act="gelu",
    tie_embeddings=True,
    scan_layers=False,       # heterogeneous 1:2 pattern -> python loop
    source="arXiv:2402.19427; hf",
)

SMOKE = CONFIG.reduced(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512, sliding_window=16, lru_width=64,
)
