from .base import SHAPES, ModelConfig, ShapeSpec, get_config, list_archs, shape_for

__all__ = ["SHAPES", "ModelConfig", "ShapeSpec", "get_config", "list_archs", "shape_for"]
