"""Model configuration schema + registry.

Every assigned architecture ships as ``configs/<id>.py`` exposing ``CONFIG``
(the exact published hyper-parameters) and ``SMOKE`` (a reduced same-family
config for CPU tests). ``get_config(name, smoke=...)`` is the lookup.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, replace
from typing import Any

import jax.numpy as jnp

__all__ = ["ModelConfig", "get_config", "list_archs", "SHAPES", "shape_for"]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- attention ---
    sliding_window: int | None = None
    attn_chunk: int = 1024           # KV tile of the online-softmax attention
    attn_q_chunk: int = 512          # Q tile (peak temp ~ q_chunk x chunk)
    # per-layer block pattern for hybrid archs, cycled: e.g. ("rglru","rglru","attn")
    block_pattern: tuple[str, ...] = ("attn",)
    parallel_block: bool = False     # command-r: attn and FFN in parallel
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0             # 0 -> d_model // 16
    # --- RG-LRU (griffin) ---
    lru_width: int = 0               # 0 -> d_model
    # --- structure ---
    act: str = "silu"                # silu (SwiGLU) | gelu (GeGLU)
    cross_attention: bool = False    # whisper decoder
    n_encoder_layers: int = 0        # whisper
    encoder_len: int = 1500          # whisper frame positions (stub frontend)
    frontend: str | None = None      # audio | vision (stub: embeds provided)
    n_image_tokens: int = 2880       # llava anyres tile budget (stub)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    scan_layers: bool = True
    dtype: Any = jnp.bfloat16
    # --- bookkeeping ---
    source: str = ""                 # provenance note

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over 16-way axes
        (Megatron-style padding; labels never index the pad region)."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, self.d_model // 16)

    @property
    def rnn_width(self) -> int:
        return self.lru_width or self.d_model

    def layer_kinds(self) -> list[str]:
        """Block kind per layer (cycled pattern)."""
        p = self.block_pattern
        return [p[i % len(p)] for i in range(self.n_layers)]

    @property
    def is_homogeneous(self) -> bool:
        return len(set(self.layer_kinds())) == 1

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in self.layer_kinds():
            if kind == "attn":
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
                ffn = 3 * d * f if self.act in ("silu", "gelu") else 2 * d * f
                if self.n_experts:
                    ffn = self.n_experts * 3 * d * f + d * self.n_experts
                total += attn + ffn + 2 * d
            elif kind == "ssm":
                di, st, dr = self.d_inner, self.ssm_state, self.dt_rank
                total += d * 2 * di + di * self.ssm_conv + di * (dr + 2 * st) + dr * di + di * st + di + di * d + d
            elif kind == "rglru":
                r = self.rnn_width
                total += 2 * d * r + r * self.ssm_conv + 3 * r * r + r * d + d
            else:
                raise ValueError(kind)
        if self.cross_attention:
            total += self.n_encoder_layers * (
                d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d + 3 * d * f
            )
        return int(total)

    def active_params(self) -> int:
        """Active (per-token) parameters — MoE counts top_k experts only."""
        if not self.n_experts:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_moe = self.n_experts * 3 * d * f
        active_moe = self.top_k * 3 * d * f
        return int(self.n_params() - self.n_layers * (dense_moe - active_moe))

    def reduced(self, **overrides: Any) -> "ModelConfig":
        return replace(self, **overrides)


# ---------------------------------------------------------------------------
# Assigned input shapes (LM transformer shapes: seq_len x global_batch).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_for(name: str) -> ShapeSpec:
    return SHAPES[name]


_ARCHS = [
    "gemma_2b",
    "yi_9b",
    "h2o_danube3_4b",
    "command_r_plus_104b",
    "llava_next_34b",
    "olmoe_1b_7b",
    "granite_moe_1b_a400m",
    "whisper_medium",
    "falcon_mamba_7b",
    "recurrentgemma_2b",
]

_PAPER_NETS = ["paper_lstm", "paper_phased_lstm", "paper_pathnet", "paper_googlenet"]


def list_archs(include_paper: bool = False) -> list[str]:
    return list(_ARCHS) + (list(_PAPER_NETS) if include_paper else [])


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    key = name.replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.SMOKE if smoke else mod.CONFIG
