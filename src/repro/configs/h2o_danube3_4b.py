"""h2o-danube-3-4b [dense] — arXiv:2401.16818. llama+mistral mix, SWA.

head_dim = 3840/32 = 120 (not 128-aligned — noted in the roofline table).
Sliding-window attention makes the arch sub-quadratic -> long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32_000,
    sliding_window=4096,
    act="silu",
    source="arXiv:2401.16818; unverified",
)

SMOKE = CONFIG.reduced(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, sliding_window=16,
)
