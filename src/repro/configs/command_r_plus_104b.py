"""command-r-plus-104b [dense] — hf:CohereForAI. GQA kv=8, no-bias,
parallel attention+FFN blocks (the width-2 graph the scheduler exploits)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256_000,
    parallel_block=True,
    act="silu",
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)

SMOKE = CONFIG.reduced(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
)
