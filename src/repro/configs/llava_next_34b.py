"""llava-next-34b [vlm] — hf:llava-hf/llava-v1.6. Backbone only; the anyres
vision tower is a STUB: input_specs() provides precomputed patch embeddings
(n_image_tokens of them) that are concatenated ahead of the token embeds."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64_000,
    act="silu",
    frontend="vision",
    n_image_tokens=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)

SMOKE = CONFIG.reduced(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, n_image_tokens=8,
)
