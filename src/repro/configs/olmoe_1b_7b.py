"""olmoe-1b-7b [moe] — arXiv:2409.02060. 64 experts, top-8, MHA (kv=16)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50_304,
    n_experts=64,
    top_k=8,
    act="silu",
    source="arXiv:2409.02060; hf",
)

SMOKE = CONFIG.reduced(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab_size=512, n_experts=8, top_k=2,
)
