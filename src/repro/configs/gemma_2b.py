"""gemma-2b [dense] — arXiv:2403.08295. GeGLU, head_dim=256, MQA (kv=1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256_000,
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2403.08295; hf",
)

SMOKE = CONFIG.reduced(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512,
)
