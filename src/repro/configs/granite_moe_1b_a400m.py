"""granite-moe-1b-a400m [moe] — hf:ibm-granite/granite-3.0-1b-a400m-base.
32 experts, top-8, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    n_experts=32,
    top_k=8,
    act="silu",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)

SMOKE = CONFIG.reduced(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
    vocab_size=512, n_experts=8, top_k=2,
)
