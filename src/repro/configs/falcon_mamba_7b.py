"""falcon-mamba-7b [ssm] — arXiv:2410.05355. Mamba-1 architecture, attn-free.
d_inner = 2*d_model = 8192, ssm_state=16, conv kernel 4, dt_rank = d/16."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65_024,
    block_pattern=("ssm",),
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    source="arXiv:2410.05355; unverified",
)

SMOKE = CONFIG.reduced(
    n_layers=2, d_model=64, vocab_size=512, ssm_state=4,
)
