"""Cell builder: (architecture x input-shape x mesh) -> lowerable jit fn.

A *cell* is one entry of the 40-cell dry-run matrix. ``build_cell`` returns
everything needed to ``.lower().compile()`` it with ShapeDtypeStruct inputs —
no device allocation ever happens here.

Per-cell execution plans (microbatching, sequence-parallel activations)
live in ``plan_for``; the perf pass overrides them via ``PlanOverrides``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, get_config
from repro.dist.sharding import (
    MeshCtx,
    batch_axes,
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
    state_pspecs,
    use_mesh,
)
from repro.models import api as model_api
from repro.models import transformer
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.step import TrainStepConfig, init_train_state, make_train_step

__all__ = ["CellPlan", "Cell", "plan_for", "build_cell", "cell_matrix", "skip_reason"]

# archs whose every block attends over the full context: long_500k (524k
# decode) is quadratic-cost / unbounded-cache for them -> skipped, per the
# assignment ("skip for pure full-attention archs").
FULL_ATTENTION_ARCHS = {
    "gemma-2b",
    "yi-9b",
    "command-r-plus-104b",
    "llava-next-34b",
    "olmoe-1b-7b",
    "granite-moe-1b-a400m",
    "whisper-medium",
}


@dataclass(frozen=True)
class CellPlan:
    """Tunable execution plan for one cell (the perf-pass knobs)."""

    microbatches: int = 1
    seq_shard: bool = False       # Megatron-SP residual-stream seq sharding
    remat: bool = True
    donate: bool = True
    fsdp: bool = False            # ZeRO-3 param/moment sharding over 'data'
    extra: dict = field(default_factory=dict)

    def override(self, **kw: Any) -> "CellPlan":
        return replace(self, **kw)


def _pow2_at_least(x: float) -> int:
    return 1 << max(0, math.ceil(math.log2(max(x, 1.0))))


def plan_for(
    cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, *, hbm_budget: float = 12e9
) -> CellPlan:
    """Baseline plan: fewest microbatches whose estimated footprint fits HBM
    (budget < 16 GB leaves headroom for fragmentation + XLA temps; the
    dry-run verifies with ``memory_analysis`` and auto-bumps on overflow).

    Footprint model (per device):
      fixed  = params x (2 bf16 + 8 fp32 moments + 4 fp32 grads) / tp
      per-mb = tokens_mb x [ 3 dtype-bytes x Vp/tp   (logits + its grad)
                           + L x D x 2 / sp          (remat layer carries)
                           + ~12 x D x 2 / sp ]      (within-layer working set)
    """
    if shape.kind == "decode":
        return CellPlan(microbatches=1, seq_shard=False, remat=False)
    n_dev = mesh.devices.size
    tp = mesh.shape.get("model", 1)
    dp = 1
    for a in batch_axes(mesh, shape.global_batch):
        dp *= mesh.shape[a]
    seq_shard = shape.seq_len >= 2048 and shape.seq_len % tp == 0
    sp = tp if seq_shard else 1

    params = cfg.n_params()
    state_bytes = 2 + 8 + 4 if shape.kind == "train" else 2
    fixed = params * state_bytes / tp
    # ZeRO-3 when the parameter/optimizer footprint alone would crowd HBM
    fsdp = fixed > hbm_budget * 0.5
    if fsdp:
        fixed /= max(dp, 1)
    B, S, D, L = shape.global_batch, shape.seq_len, cfg.d_model, cfg.n_layers
    Vp = cfg.padded_vocab

    def per_mb_bytes(mb: int) -> float:
        tokens = (B // mb // dp) * S
        logits = tokens * (Vp / tp) * 4 * 3
        carries = tokens * L * D * 2 / sp
        working = tokens * 12 * D * 2 / sp
        return logits + carries + working

    mb = 1
    # cap: each microbatch must still divide the dp axes, or activations
    # silently replicate over 'data' and memory goes UP
    mb_cap = max(1, B // max(dp, 1))
    if shape.kind == "train":
        while (
            mb * 2 <= mb_cap
            and B % (mb * 2) == 0
            and fixed + per_mb_bytes(mb) > hbm_budget
        ):
            mb *= 2
    return CellPlan(
        microbatches=mb, seq_shard=seq_shard, remat=shape.kind == "train", fsdp=fsdp
    )


def skip_reason(arch: str, shape_name: str) -> str | None:
    canon = arch.replace("_", "-")
    if shape_name == "long_500k" and canon in FULL_ATTENTION_ARCHS:
        return "full-attention arch: 524k decode is unbounded-cache/quadratic (DESIGN.md §6)"
    return None


@dataclass
class Cell:
    arch: str
    shape_name: str
    cfg: ModelConfig
    shape: ShapeSpec
    plan: CellPlan
    kind: str                      # train | prefill | decode
    fn: Callable                   # the step function (unjitted)
    args: tuple                    # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    ctx: MeshCtx

    def lower(self):
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        with use_mesh(self.ctx):
            return jitted.lower(*self.args)


def _sds(tree: Any) -> Any:
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def _named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def build_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    *,
    plan: CellPlan | None = None,
    smoke: bool = False,
) -> Cell:
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    plan = plan or plan_for(cfg, shape, mesh)
    if plan.extra:
        cfg = cfg.reduced(**plan.extra)   # e.g. attn_q_chunk for the perf pass
    kind = shape.kind
    bt = batch_axes(mesh, shape.global_batch)
    ctx = MeshCtx(mesh, bt, seq="model" if plan.seq_shard else None)
    key = jax.random.key(0)

    if kind == "train":
        tcfg = TrainStepConfig(microbatches=plan.microbatches, remat=plan.remat)
        state_shape = jax.eval_shape(lambda k: init_train_state(cfg, k), key)
        batch_shape = model_api.input_specs(cfg, shape, kind="train")
        state_sh = _named(mesh, state_pspecs(cfg, state_shape, mesh, fsdp=plan.fsdp))
        batch_sh = _named(mesh, batch_pspecs(batch_shape, mesh, shape.global_batch))
        fn = make_train_step(cfg, tcfg)
        return Cell(
            arch, shape_name, cfg, shape, plan, kind, fn,
            args=(_sds(state_shape), batch_shape),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,) if plan.donate else (),
            ctx=ctx,
        )

    params_shape = jax.eval_shape(lambda k: transformer.init_params(cfg, k), key)
    params_sh = _named(mesh, param_pspecs(cfg, params_shape, mesh, fsdp=plan.fsdp))
    B = shape.global_batch
    cache_shape = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, shape.seq_len)
    )
    cache_sh = _named(mesh, cache_pspecs(cfg, cache_shape, mesh, B))

    if kind == "prefill":
        batch_shape = model_api.input_specs(cfg, shape, kind="prefill")
        batch_sh = _named(mesh, batch_pspecs(batch_shape, mesh, B))
        fn = make_prefill_step(cfg)
        return Cell(
            arch, shape_name, cfg, shape, plan, kind, fn,
            args=(_sds(params_shape), _sds(cache_shape), batch_shape),
            in_shardings=(params_sh, cache_sh, batch_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,) if plan.donate else (),
            ctx=ctx,
        )

    # decode: one new token against a seq_len-deep cache
    tokens_shape = model_api.input_specs(cfg, shape, kind="decode")
    tokens_sh = _named(mesh, batch_pspecs(tokens_shape, mesh, B))
    fn = make_decode_step(cfg)
    return Cell(
        arch, shape_name, cfg, shape, plan, kind, fn,
        args=(_sds(params_shape), _sds(cache_shape), tokens_shape["tokens"]),
        in_shardings=(params_sh, cache_sh, tokens_sh["tokens"]),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,) if plan.donate else (),
        ctx=ctx,
    )


def cell_matrix(archs: list[str] | None = None) -> list[tuple[str, str]]:
    """The full 40-cell (arch x shape) matrix, including skipped cells."""
    from repro.configs.base import list_archs

    archs = archs or list_archs()
    return [(a, s) for a in archs for s in SHAPES]
