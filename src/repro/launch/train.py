"""Training CLI: ``python -m repro.launch.train --arch gemma-2b [--smoke]``.

Wires the full stack: config -> synthetic data pipeline -> sharded train
step (pjit) -> fault-tolerant Trainer (checkpoint/restart, straggler
watchdog).  On this CPU box, ``--smoke`` (reduced config, 1 device) is the
runnable path; the full configs are exercised via ``launch.dryrun``.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.base import ShapeSpec, get_config
from repro.data import DataConfig, SyntheticTokens
from repro.dist.sharding import MeshCtx, batch_axes, state_pspecs, use_mesh
from repro.train.step import TrainStepConfig, init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true", help="reduced config (CPU-sized)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--data", default="bigram", choices=("bigram", "uniform", "copy"))
    p.add_argument("--mesh", default=None, help="e.g. '2x4' => data=2, model=4")
    p.add_argument("--no-graphi", action="store_true",
                   help="skip the Graphi capture/schedule of the loss graph")
    p.add_argument("--calibration-store", default=None,
                   help="JSON path backing the process Runtime's calibration "
                        "store (shared with any serve engine in this process)")
    p.add_argument("--schedule-search", choices=("off", "auto", "force"),
                   default="auto",
                   help="simulator-guided schedule search for the Graphi "
                        "loss-graph schedule: 'auto' searches when measured "
                        "costs back the graph, 'force' always, 'off' plain "
                        "CPF")
    p.add_argument("--pinning", choices=("off", "auto", "on"), default="off",
                   help="pin the Runtime's executor threads to disjoint "
                        "core sets (repro.hwperf): 'auto' where supported, "
                        "'on' warns once where it isn't")
    p.add_argument("--dump-trace", choices=("ascii", "csv"), default=None,
                   help="print the Graphi loss graph's execution timeline "
                        "(simulated on this sim-backend path)")
    args = p.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")

    # the process-wide Runtime: the Graphi view of the loss graph compiles
    # through it (shared schedule caches + persistent calibration), and any
    # host-backend execution in this process leases its executors
    import repro
    runtime = repro.Runtime(calibration_path=args.calibration_store,
                            pinning=args.pinning)
    repro.set_default_runtime(runtime)
    scheduled_makespan = None
    if not args.no_graphi:
        from repro.train.step import compile_lm_loss

        exe = compile_lm_loss(cfg, shape, backend="sim", runtime=runtime,
                              schedule_search=args.schedule_search)
        scheduled_makespan = exe.schedule.makespan
        print(f"graphi: loss graph {len(exe.graph)} nodes, width "
              f"{exe.graph.width()}, {exe.schedule.n_executors}x"
              f"{exe.schedule.team_size} executors ({exe.schedule.policy}), "
              f"scheduled makespan "
              f"{scheduled_makespan * 1e3:.2f} ms ({runtime.describe()})")
        if args.dump_trace:
            print(exe.render_trace(fmt=args.dump_trace))

    from repro.optim.adamw import AdamWConfig

    tcfg = TrainStepConfig(
        microbatches=args.microbatches,
        remat=not args.smoke,
        adamw=AdamWConfig(lr=args.lr),
        total_steps=args.steps,
        warmup_steps=max(1, args.steps // 20),
    )
    key = jax.random.key(0)
    state = init_train_state(cfg, key, tcfg.adamw)
    step = make_train_step(cfg, tcfg)

    mesh = None
    if args.mesh:
        dims = tuple(int(d) for d in args.mesh.split("x"))
        names = ("data", "model")[: len(dims)]
        mesh = jax.make_mesh(dims, names)
        specs = state_pspecs(cfg, jax.eval_shape(lambda: state), mesh)
        shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), specs,
            is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec),
        )
        state = jax.device_put(state, shardings)
        ctx = MeshCtx(mesh, batch_axes(mesh, args.batch))
        step_jit = jax.jit(step, donate_argnums=(0,))

        def run_step(s, b):
            with use_mesh(ctx):
                return step_jit(s, b)
    else:
        run_step = jax.jit(step, donate_argnums=(0,))

    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, kind=args.data,
    ))

    ckpt = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    trainer = Trainer(
        run_step, state, data.batch,
        TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=args.ckpt_every,
            log_every=args.log_every,
        ),
        checkpoint=ckpt,
        scheduled_makespan=scheduled_makespan,
    )
    report = trainer.run()
    for rec in report.history:
        if "loss" in rec:
            print(f"step {rec['step']:6d}  loss {rec['loss']:.4f}  "
                  f"({rec['time_s']*1e3:.0f} ms/step)")
    print(f"done: {report.steps_run} steps, {report.restarts} restarts, "
          f"final loss {report.final_loss:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
