"""Serving CLI: ``python -m repro.launch.serve --arch gemma-2b --smoke``.

Builds a (randomly initialized) model, submits a batch of synthetic
requests to the wave-batching engine, and reports decode throughput.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import transformer
from repro.serve.engine import Request, ServeConfig, ServeEngine


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--temperature", type=float, default=0.0)
    args = p.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = transformer.init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=args.max_batch,
        max_len=args.prompt_len + args.max_new + 1,
        temperature=args.temperature,
    ))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            request_id=i,
            prompt=rng.integers(1, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    n_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens/dt:.1f} tok/s incl. prefill+compile)")
    for r in done[:3]:
        print(f"  req {r.request_id}: {len(r.output)} tokens, first 8 = {r.output[:8]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
