"""Serving CLI: ``python -m repro.launch.serve --arch gemma-2b --smoke``.

Builds a (randomly initialized) model, submits synthetic requests, and
reports decode throughput + per-request latency.  ``--continuous`` routes
through the graphi-scheduled :class:`ContinuousEngine` (prefill/decode
captured via ``repro.compile``, profiler-chosen executor config, slot
admission between decode steps, decode replayed through a compiled static
host plan unless ``--decode-host-mode dynamic``); the default is the wave
batcher.
``--arrival-rate`` staggers request arrivals (Poisson, requests/second)
instead of submitting everything up front.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import transformer
from repro.serve.engine import ContinuousEngine, Request, ServeConfig, ServeEngine
from repro.serve.paged import PagedConfig, PagedEngine


def build_requests(cfg, *, n_requests, prompt_lens, max_new,
                   arrival_rate=0.0, seed=0) -> list[tuple[float, Request]]:
    """(arrival_time, request) pairs: Poisson arrivals (all at t=0 when
    ``arrival_rate`` is 0), prompt lengths cycled from ``prompt_lens``.
    Shared by the CLI and ``scripts/bench_serve.py``."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        if arrival_rate > 0:
            t += float(rng.exponential(1.0 / arrival_rate))
        prompt = rng.integers(
            1, cfg.vocab_size, size=prompt_lens[i % len(prompt_lens)]
        ).astype(np.int32)
        out.append((t, Request(request_id=i, prompt=prompt, max_new_tokens=max_new)))
    return out


def percentile(xs, q: float) -> float:
    """Index-based percentile of a sequence (0.0 when empty)."""
    xs = sorted(xs)
    if not xs:
        return 0.0
    return xs[min(len(xs) - 1, int(len(xs) * q))]


def drive(engine, arrivals: list[tuple[float, Request]], *, continuous: bool):
    """Feed requests at their arrival times; returns (done, latency, wall).

    The wave engine drains its queue whenever it is idle and work has
    arrived (its own granularity — one ``run()`` per busy period); the
    continuous engine steps, admitting arrivals between decode steps.
    """
    t0 = time.perf_counter()
    todo = list(arrivals)
    done: list[Request] = []
    finish: dict[int, float] = {}
    while True:
        now = time.perf_counter() - t0
        while todo and todo[0][0] <= now:
            engine.submit(todo.pop(0)[1])
        busy = engine.has_work if continuous else bool(engine.queue)
        if busy:
            if continuous:
                engine.step()
                for r in engine.completed:
                    if r.request_id not in finish:
                        finish[r.request_id] = time.perf_counter() - t0
            else:
                batch = engine.run()
                stamp = time.perf_counter() - t0
                for r in batch:
                    finish[r.request_id] = stamp
                    done.append(r)
        elif todo:
            time.sleep(max(0.0, todo[0][0] - (time.perf_counter() - t0)))
        else:
            break
    if continuous:
        done = engine.run()
    arrive = {r.request_id: t for t, r in arrivals}
    lat = {r.request_id: finish[r.request_id] - arrive[r.request_id] for r in done}
    return done, lat, time.perf_counter() - t0


def serve_fleet(args) -> int:
    """``--replicas N``: the supervised multi-replica tier.

    Spawns N worker processes under a :class:`repro.fleet.Fleet` —
    heartbeat liveness, crash/wedge failover with bit-exact replay, prefix-
    affinity routing — and drives the same Poisson workload through it.
    Workers default to real engines of the requested kind (sharing one JSON
    calibration store so replica 2..N skip the schedule search);
    ``--replica-engine toy`` swaps in the deterministic service-time worker
    the fleet tests/bench use.
    """
    import numpy as np

    from repro.fleet import Fleet, FleetConfig

    kind = args.replica_engine
    if kind == "auto":
        kind = "paged" if args.paged else "continuous"
    if kind == "toy":
        vocab = 256
        engine = {"kind": "toy", "vocab_size": vocab, "service_time_s": 0.004}
    else:
        cfg = get_config(args.arch, smoke=args.smoke)
        vocab = cfg.vocab_size
        engine = {"kind": kind, "arch": args.arch, "smoke": args.smoke,
                  "max_batch": args.max_batch,
                  "max_len": max(int(x) for x in
                                 str(args.prompt_len).split(",")) + args.max_new + 1,
                  "calibration_store": args.calibration_store}
    prompt_lens = [int(x) for x in str(args.prompt_len).split(",")]
    rng = np.random.default_rng(0)
    t, work = 0.0, []
    for i in range(args.requests):
        if args.arrival_rate > 0:
            t += float(rng.exponential(1.0 / args.arrival_rate))
        prompt = [int(x) for x in rng.integers(
            1, vocab, size=prompt_lens[i % len(prompt_lens)])]
        work.append((t, prompt, args.max_new))

    # real engines jit-compile their prefill/decode graphs on the *first*
    # steps after ready, and heartbeats ride the serve loop — the liveness
    # window must cover a compile-length step or the supervisor declares
    # every healthy replica wedged and burns the restart budget
    if kind == "toy":
        fcfg = FleetConfig(n_workers=args.replicas, engine=engine,
                           max_inflight_per_worker=args.max_batch)
    else:
        fcfg = FleetConfig(n_workers=args.replicas, engine=engine,
                           max_inflight_per_worker=args.max_batch,
                           heartbeat_s=0.5, liveness_s=120.0,
                           startup_grace_s=600.0)
    with Fleet(fcfg) as fleet:
        fleet.wait_ready()
        t0 = time.perf_counter()
        todo, arrive, finish = list(work), {}, {}
        while todo or fleet.has_work:
            now = time.perf_counter() - t0
            while todo and todo[0][0] <= now:
                at, prompt, max_new = todo.pop(0)
                arrive[fleet.submit(prompt, max_new)] = at
            fleet.pump()
            for req in fleet.completed:
                finish.setdefault(req.rid, time.perf_counter() - t0)
        done = sorted(fleet.completed, key=lambda r: r._order)
        wall = time.perf_counter() - t0
        stats = fleet.stats()
    n_tokens = sum(len(r.tokens) for r in done)
    lat = [finish[r.rid] - arrive[r.rid] for r in done]
    print(f"[fleet:{kind} x{args.replicas}] served {len(done)} requests, "
          f"{n_tokens} tokens in {wall:.2f}s ({n_tokens / wall:.1f} tok/s); "
          f"latency p50={percentile(lat, 0.5) * 1e3:.0f}ms "
          f"p95={percentile(lat, 0.95) * 1e3:.0f}ms")
    print(f"  failovers={stats['n_failovers']} requeued={stats['n_requeued']} "
          f"affinity_hits={stats['router_affinity_hits']}/"
          f"{stats['router_routed']}")
    bad = [t for r in done for t in r.tokens if t >= vocab]
    if bad:
        raise SystemExit(f"emitted out-of-vocab ids: {bad[:5]}")
    return 0


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--continuous", action="store_true",
                   help="continuous batching on the graphi runtime")
    p.add_argument("--paged", action="store_true",
                   help="block-paged KV cache with prefix sharing and "
                        "chunked prefill (implies continuous batching)")
    p.add_argument("--page-size", type=int, default=16,
                   help="tokens per physical KV page (--paged)")
    p.add_argument("--n-pages", type=int, default=None,
                   help="physical pages in the pool (--paged; default "
                        "max_batch * ceil(max_len/page_size))")
    p.add_argument("--prefill-chunk", type=int, default=64,
                   help="tokens prefilled per engine step per prompt "
                        "(--paged; rounded up to a page multiple)")
    p.add_argument("--arrival-rate", type=float, default=0.0,
                   help="Poisson arrival rate (req/s); 0 = all at once")
    def _positive(v):
        n = int(v)
        if n < 1:
            raise argparse.ArgumentTypeError("need at least 1 request")
        return n

    p.add_argument("--requests", type=_positive, default=8)
    p.add_argument("--prompt-len", default="32",
                   help="prompt length, or comma list for mixed lengths")
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--max-executors", type=int, default=None,
                   help="bound the profiler's executor-config search")
    p.add_argument("--decode-host-mode", choices=("static", "dynamic"),
                   default="static",
                   help="decode-graph runtime: compiled static host plan "
                        "(default) or the per-op dynamic scheduler")
    p.add_argument("--runtime-workers", type=int, default=None,
                   help="executor-thread count of the process Runtime "
                        "(default: machine core count)")
    p.add_argument("--calibration-store", default=None,
                   help="JSON path backing the Runtime's calibration store "
                        "(measured op costs survive restarts)")
    p.add_argument("--pinning", choices=("off", "auto", "on"), default="off",
                   help="pin executor threads to disjoint core sets "
                        "(repro.hwperf): 'auto' pins where the platform "
                        "supports affinity, 'on' warns once where it "
                        "doesn't (continuous/paged only)")
    p.add_argument("--dump-trace", choices=("ascii", "csv"), default=None,
                   help="print the decode executable's last execution "
                        "timeline (measured if available, else simulated) "
                        "after serving (continuous/paged only)")
    p.add_argument("--schedule-search", choices=("off", "auto", "force"),
                   default="auto",
                   help="simulator-guided schedule search over registered "
                        "policies: 'auto' (default) searches once the decode "
                        "graph is calibrated, 'force' always, 'off' plain "
                        "CPF; winners persist in the calibration store "
                        "(continuous/paged only)")
    p.add_argument("--check", choices=("off", "basic", "strict"),
                   default="off",
                   help="static verification (repro.checks) of the engine's "
                        "captured graphs/schedules/plans after build: "
                        "'basic' reports, 'strict' additionally refuses to "
                        "serve on error findings (continuous/paged only)")
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--replicas", type=int, default=1,
                   help="serve through a supervised multi-replica fleet "
                        "(worker processes, heartbeat failover, bit-exact "
                        "requeue) instead of one in-process engine")
    p.add_argument("--replica-engine", choices=("auto", "toy", "continuous",
                                                "paged"), default="auto",
                   help="fleet worker engine (--replicas > 1): 'auto' "
                        "follows --paged/--continuous, 'toy' is the "
                        "deterministic service-time worker")
    args = p.parse_args()

    if args.replicas > 1:
        return serve_fleet(args)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = transformer.init_params(cfg, jax.random.key(0))
    prompt_lens = [int(x) for x in str(args.prompt_len).split(",")]
    scfg = ServeConfig(
        max_batch=args.max_batch,
        max_len=max(prompt_lens) + args.max_new + 1,
        temperature=args.temperature,
    )
    continuous = args.continuous or args.paged
    if continuous:
        # one process-wide Runtime: the engine leases its calibrated
        # executor width from it per step instead of owning a pool
        import repro
        runtime = repro.Runtime(args.runtime_workers,
                                calibration_path=args.calibration_store,
                                pinning=args.pinning)
        repro.set_default_runtime(runtime)
        if args.paged:
            pcfg = PagedConfig(page_size=args.page_size, n_pages=args.n_pages,
                               prefill_chunk=args.prefill_chunk)
            engine = PagedEngine(cfg, params, scfg, paged=pcfg,
                                 max_executors=args.max_executors,
                                 runtime=runtime,
                                 decode_host_mode=args.decode_host_mode,
                                 schedule_search=args.schedule_search)
            print(f"paged engine: {engine.n_executors} executors leased of "
                  f"{runtime.n_workers}, {engine.capacity} slots, "
                  f"{engine.page_pool.n_pages} pages x {pcfg.page_size} tok, "
                  f"chunk={engine.chunk}, decode={engine.decode_host_mode}")
        else:
            engine = ContinuousEngine(cfg, params, scfg,
                                      max_executors=args.max_executors,
                                      runtime=runtime,
                                      decode_host_mode=args.decode_host_mode,
                                      schedule_search=args.schedule_search)
            print(f"continuous engine: {engine.n_executors} executors leased of "
                  f"{runtime.n_workers} (profiled best {engine.profile.best_config}), "
                  f"{engine.capacity} slots, decode={engine.decode_host_mode}")
    else:
        engine = ServeEngine(cfg, params, scfg)

    if continuous and args.check != "off":
        # verify the engine's captured executables before serving a single
        # request; strict mode refuses to serve over a bad artifact
        import jax.numpy as jnp

        from repro.checks import (Report, cross_graph_hazards, infer_effects,
                                  shared_buffers)

        rep = Report()
        exes = [engine._decode_exe]
        chunk_exe = getattr(engine, "_chunk_exe", None)
        if chunk_exe is not None:
            exes.append(chunk_exe)
        for exe in exes:
            rep.extend(exe.verify(hazards=True))
        if chunk_exe is not None:
            # the decode step scatters into the page pools the chunk graph
            # reads — both bind the engine's one ``_pages`` object, so alias
            # discovery is by array identity over the two bound input maps
            cache_spec = {
                "len": jnp.zeros((engine.capacity,), jnp.int32),
                "table": jnp.full((engine.capacity, engine.n_pt), -1,
                                  jnp.int32),
                "pages": engine._pages,
            }
            tok = jax.ShapeDtypeStruct((engine.capacity, 1), jnp.int32)
            bind_d = engine._decode_exe.captured.bind(
                (params, cache_spec, tok))
            bind_c = chunk_exe.captured.bind(
                (params, engine._pages,
                 jnp.full((engine.n_pt,), -1, jnp.int32),
                 {"tokens": jax.ShapeDtypeStruct((1, engine.chunk),
                                                 jnp.int32)},
                 jnp.int32(0), jnp.int32(engine.chunk)))
            rep.extend(cross_graph_hazards(
                infer_effects(engine._decode_exe.graph),
                infer_effects(chunk_exe.graph),
                shared_buffers(bind_d, bind_c)))
        print(f"check[{args.check}]: {rep.summary()}")
        body = rep.render(min_severity="warning")
        if body != "clean: no findings":
            print(body)
        if args.check == "strict":
            rep.raise_if_errors()

    arrivals = build_requests(cfg, n_requests=args.requests, prompt_lens=prompt_lens,
                              max_new=args.max_new, arrival_rate=args.arrival_rate)
    done, lat, wall = drive(engine, arrivals, continuous=continuous)
    n_tokens = sum(len(r.output) for r in done)
    p50 = percentile(lat.values(), 0.50)
    p95 = percentile(lat.values(), 0.95)
    mode = "paged" if args.paged else ("continuous" if continuous else "wave")
    print(f"[{mode}] served {len(done)} requests, {n_tokens} tokens in {wall:.2f}s "
          f"({n_tokens / wall:.1f} tok/s incl. prefill+compile); "
          f"latency p50={p50 * 1e3:.0f}ms p95={p95 * 1e3:.0f}ms")
    if continuous and args.dump_trace:
        # measured-vs-simulated timeline of the decode graph (paper §5.2)
        print(engine._decode_exe.render_trace(fmt=args.dump_trace))
    if args.paged:
        print("  " + " ".join(f"{k}={v}" for k, v in engine.stats().items()))
        engine.close()
    elif continuous:
        print(f"  steps={engine.n_steps} decode_steps={engine.n_decode_steps} "
              f"overlapped_prefills={engine.n_overlapped_prefills}")
        engine.close()
    bad = [t for r in done for t in r.output if t >= cfg.vocab_size]
    if bad:   # not an assert: the check must survive python -O
        raise SystemExit(f"emitted out-of-vocab ids: {bad[:5]}")
    for r in done[:3]:
        print(f"  req {r.request_id}: {len(r.output)} tokens, first 8 = {r.output[:8]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
