"""Production meshes (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init;
everything else sees the real single CPU device.

Topology: a v5e pod is a 16x16 ICI torus (256 chips). ``data`` x ``model``
maps onto it so that the model axis is ICI-contiguous (TP collectives stay
on-pod); the ``pod`` axis crosses DCN and only carries gradient
all-reduces. The same constructor scales to any pod count — 1000+ chips is
``multi_pod`` with more pods (e.g. (8, 16, 16) = 2048 chips); nothing in the
sharding rules depends on the pod count.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "describe_mesh"]


def make_production_mesh(*, multi_pod: bool = False, n_pods: int = 2):
    shape = (n_pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """General constructor for experiments (perf pass tries other splits)."""
    return jax.make_mesh(shape, axes)


def describe_mesh(mesh) -> str:
    return " x ".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
