import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (device count locks at first init).  The 512
# host devices exist ONLY in this process — smoke tests / benches see 1.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, verify HBM fit, and extract the roofline
numerators (per-device HLO flops / bytes / collective traffic).

    PYTHONPATH=src python -m repro.launch.dryrun                # full sweep
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multipod
    PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun.json

Single-pod (16,16) carries the roofline table; the multi-pod (2,16,16) pass
proves the ``pod`` axis shards (gradient all-reduce crosses DCN) for every
cell.  Train cells whose compiled footprint exceeds HBM are auto-bumped to
more microbatches and recompiled (the paper's profiler feedback loop, Fig 4,
applied to memory instead of makespan).
"""
import argparse
import json
import time
import traceback

from repro.analysis.roofline import TPU_V5E, roofline_report
from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch.cells import build_cell, skip_reason
from repro.launch.mesh import describe_mesh, make_production_mesh
from repro.models.api import model_flops

MAX_MEMORY_BUMPS = 4


def graphi_record(cell, arch: str, shape_name: str, runtime=None) -> dict:
    """Capture the cell's step fn into a scheduled ``Executable`` (abstract
    specs — no allocation) and report the Graphi planning artifacts: node
    count, DAG width, best executor config, modelled makespan, critical path.
    ``runtime`` is the sweep-wide :class:`repro.Runtime` so every cell lands
    its planning artifacts in one session's caches (sim-only: the runtime
    never spawns its pool here).
    """
    from repro import api as graphi
    from repro.core import TPUV5E
    from repro.dist.sharding import use_mesh

    with use_mesh(cell.ctx):
        exe = graphi.compile(
            cell.fn, *cell.args, hw=TPUV5E, backend="sim", runtime=runtime,
            name=f"{arch}.{shape_name}",
        )
    g = exe.graph
    prof = exe.profile
    cp_len, cp = exe.critical_path
    return {
        "n_nodes": len(g),
        "width": g.width(),
        "n_executors": prof.best_n_executors,
        "team_size": prof.best_team_size,
        # the frozen schedule's registry policy (a searched executable may
        # freeze a non-CPF winner; sim-only cells stay "cpf")
        "policy": exe.schedule.policy,
        "sim_makespan_s": prof.best_makespan,
        "critical_path_s": cp_len,
        "critical_path_ops": len(cp),
    }


def run_cell(arch: str, shape_name: str, mesh, *, want_roofline: bool,
             want_graphi: bool = True, verbose: bool = False,
             runtime=None) -> dict:
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": describe_mesh(mesh)}
    reason = skip_reason(arch, shape_name)
    if reason:
        rec["status"] = "skip"
        rec["reason"] = reason
        return rec
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_name, mesh)
        for _bump in range(MAX_MEMORY_BUMPS + 1):
            compiled = cell.lower().compile()
            ma = compiled.memory_analysis()
            bpd = ma.temp_size_in_bytes + ma.argument_size_in_bytes
            # bump until the bf16-native estimate fits (raw CPU bytes carry
            # the f32-dot-promotion artifact — see record fields below)
            bf16_est_loop = ma.argument_size_in_bytes + ma.temp_size_in_bytes / 2
            if bf16_est_loop <= TPU_V5E.hbm_bytes or cell.kind != "train":
                break
            mb = cell.plan.microbatches * 2
            from repro.dist.sharding import batch_axes
            dp = 1
            for a in batch_axes(mesh, cell.shape.global_batch):
                dp *= mesh.shape[a]
            if mb > cell.shape.global_batch // max(dp, 1) or cell.shape.global_batch % mb:
                break
            if verbose:
                print(f"    bump: {bpd/1e9:.1f} GB/dev > HBM; microbatches -> {mb}")
            cell = build_cell(arch, shape_name, mesh, plan=cell.plan.override(microbatches=mb))
        rec["status"] = "ok"
        rec["kind"] = cell.kind
        rec["microbatches"] = cell.plan.microbatches
        rec["seq_shard"] = cell.plan.seq_shard
        rec["fsdp"] = cell.plan.fsdp
        rec["compile_s"] = round(time.time() - t0, 1)
        rec["bytes_per_device"] = int(bpd)
        rec["arg_bytes"] = int(ma.argument_size_in_bytes)
        rec["temp_bytes"] = int(ma.temp_size_in_bytes)
        rec["fits_hbm"] = bool(bpd <= TPU_V5E.hbm_bytes)
        # XLA:CPU promotes bf16 dots to f32, so big temps (gathered weights,
        # activations around matmuls) are ~2x their TPU size; report the
        # bf16-native band [args + temp/2, raw] (EXPERIMENTS.md §Dry-run)
        bf16_est = int(ma.argument_size_in_bytes + ma.temp_size_in_bytes / 2)
        rec["bytes_per_device_bf16_est"] = bf16_est
        rec["fits_hbm_bf16_est"] = bool(bf16_est <= TPU_V5E.hbm_bytes)
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["xla_flops_per_dev"] = float(ca.get("flops", 0.0))
        if want_roofline:
            cfg = get_config(arch)
            rep = roofline_report(
                arch=arch,
                shape=shape_name,
                mesh_desc=rec["mesh"],
                n_chips=n_chips,
                hlo_text=compiled.as_text(),
                model_flops_total=model_flops(cfg, SHAPES[shape_name]),
                bytes_per_device=bpd,
            )
            rec["roofline"] = {
                "hlo_flops": rep.hlo_flops,
                "hlo_bytes": rep.hlo_bytes,
                "collective_bytes": rep.collective_bytes,
                "collectives": {k: [int(c), float(b)] for k, (c, b) in rep.collectives.items()},
                "compute_s": rep.compute_s,
                "memory_s": rep.memory_s,
                "collective_s": rep.collective_s,
                "dominant": rep.dominant,
                "model_flops_total": rep.model_flops_total,
                "useful_ratio": rep.useful_ratio,
                "roofline_fraction": rep.roofline_fraction,
                "mfu_bound": rep.mfu_bound(),
                "note": rep.note,
            }
    except Exception as e:  # noqa: BLE001
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        rec["compile_s"] = round(time.time() - t0, 1)
        return rec
    if want_graphi:
        # independent of the XLA compile result: a capture failure degrades
        # to a per-cell note, never a failed cell
        try:
            rec["graphi"] = graphi_record(cell, arch, shape_name, runtime=runtime)
        except Exception as e:  # noqa: BLE001
            rec["graphi_error"] = f"{type(e).__name__}: {e}"
    return rec


def summarize(records: list[dict]) -> str:
    rows = []
    for r in records:
        if r["status"] == "skip":
            rows.append(f"SKIP {r['arch']:22s} {r['shape']:12s} {r['mesh']:28s} ({r['reason'][:40]}...)")
        elif r["status"] == "fail":
            rows.append(f"FAIL {r['arch']:22s} {r['shape']:12s} {r['mesh']:28s} {r['error'][:60]}")
        else:
            fit = "fits" if r.get("fits_hbm_bf16_est", r["fits_hbm"]) else "OVER"
            extra = ""
            if "roofline" in r:
                rf = r["roofline"]
                extra = (f" dom={rf['dominant'][:4]} c={rf['compute_s']*1e3:8.2f}ms"
                         f" m={rf['memory_s']*1e3:8.2f}ms x={rf['collective_s']*1e3:8.2f}ms"
                         f" useful={rf['useful_ratio']:.2f}")
            if "graphi" in r:
                gr = r["graphi"]
                extra += (f" graphi={gr['n_nodes']}n/w{gr['width']}"
                          f"/{gr['n_executors']}x{gr['team_size']}")
            rows.append(
                f"OK   {r['arch']:22s} {r['shape']:12s} {r['mesh']:28s} "
                f"{r['bytes_per_device']/1e9:6.1f}GB/dev {fit} mb={r['microbatches']}"
                f" {r['compile_s']:6.1f}s{extra}"
            )
    n_ok = sum(1 for r in records if r["status"] == "ok")
    n_skip = sum(1 for r in records if r["status"] == "skip")
    n_fail = sum(1 for r in records if r["status"] == "fail")
    rows.append(f"-- {n_ok} ok / {n_skip} skip / {n_fail} fail --")
    return "\n".join(rows)


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None, help="one architecture id (default: all)")
    p.add_argument("--shape", default=None, help="one shape name (default: all)")
    p.add_argument("--mesh", choices=("pod", "multipod", "both"), default="both")
    p.add_argument("--out", default="results/dryrun.json")
    p.add_argument("--verbose", action="store_true")
    p.add_argument("--no-graphi", action="store_true",
                   help="skip the Graphi capture/schedule record per cell")
    args = p.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append((make_production_mesh(), True))
    if args.mesh in ("multipod", "both"):
        meshes.append((make_production_mesh(multi_pod=True), False))

    # one Runtime for the whole sweep: every cell's Graphi record shares its
    # planning caches (sim backend — the executor pool stays lazy/unspawned)
    import repro
    runtime = repro.Runtime()
    repro.set_default_runtime(runtime)

    records = []
    for mesh, want_roofline in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh, want_roofline=want_roofline,
                               want_graphi=not args.no_graphi, verbose=args.verbose,
                               runtime=runtime)
                records.append(rec)
                line = summarize([rec]).splitlines()[0]
                print(line, flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    print(summarize(records).splitlines()[-1])
    return 1 if any(r["status"] == "fail" for r in records) else 0


if __name__ == "__main__":
    raise SystemExit(main())
