"""Cell planner invariants (no devices needed — pure plan logic)."""
import pytest

from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch.cells import FULL_ATTENTION_ARCHS, cell_matrix, plan_for, skip_reason


class FakeMesh:
    """Just enough of a Mesh for plan_for: shape mapping + device count."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)

        class _D:
            size = 1

        self._d = _D()
        self._d.size = 1
        for v in shape.values():
            self._d.size *= v

    @property
    def devices(self):
        return self._d


MESH = FakeMesh({"data": 16, "model": 16})


def test_cell_matrix_is_40_cells():
    cells = cell_matrix()
    assert len(cells) == 40
    assert len({a for a, _ in cells}) == 10


def test_long_500k_skips_exactly_the_full_attention_archs():
    skipped = {a for a, s in cell_matrix() if skip_reason(a, s)}
    assert {a.replace("_", "-") for a in skipped} == FULL_ATTENTION_ARCHS
    # and never for other shapes
    for a, s in cell_matrix():
        if s != "long_500k":
            assert skip_reason(a, s) is None


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_plans_respect_divisibility(arch, shape):
    cfg = get_config(arch)
    plan = plan_for(cfg, SHAPES[shape], MESH)
    B = SHAPES[shape].global_batch
    assert B % plan.microbatches == 0
    # cap: per-microbatch batch still divides the dp axis
    assert plan.microbatches <= max(1, B // 16)
    if SHAPES[shape].kind == "decode":
        assert plan.microbatches == 1 and not plan.remat


def test_fsdp_triggers_for_large_models_only():
    big = plan_for(get_config("command-r-plus-104b"), SHAPES["train_4k"], MESH)
    small = plan_for(get_config("granite-moe-1b-a400m"), SHAPES["train_4k"], MESH)
    assert big.fsdp and not small.fsdp


def test_plan_extra_overrides_config():
    from repro.launch.cells import CellPlan

    plan = CellPlan(extra={"attn_q_chunk": 256})
    # build_cell applies extra via cfg.reduced — verify the field exists
    cfg = get_config("gemma-2b").reduced(**plan.extra)
    assert cfg.attn_q_chunk == 256
