"""Capture parity suite: ``compile(fn).graph.execute()`` must match ``fn``
numerically for every model family, plus node-count / flops sanity checks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.capture import capture
from repro.models import api as model_api
from repro.models import transformer
from repro.train.step import compile_lm_loss, lm_loss_fn

SHAPE = ShapeSpec("cap", 16, 2, "train")

_BASE = dict(
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
    vocab_size=128, act="silu", scan_layers=False, dtype=jnp.float32,
)

TINY = {
    "transformer": ModelConfig(name="cap-dense", family="dense", **_BASE),
    "moe": ModelConfig(name="cap-moe", family="moe", n_experts=4, top_k=2, **_BASE),
    "mamba": ModelConfig(name="cap-ssm", family="ssm", block_pattern=("ssm",),
                         ssm_state=8, **_BASE),
    "griffin": ModelConfig(name="cap-hybrid", family="hybrid",
                           block_pattern=("rglru", "rglru", "attn"),
                           lru_width=32, **{**_BASE, "n_layers": 3}),
}


def _setup(family):
    cfg = TINY[family]
    params = transformer.init_params(cfg, jax.random.key(0))
    batch = model_api.make_batch(cfg, SHAPE, jax.random.key(1))
    return cfg, params, batch


# ---------------------------------------------------------------------------
# parity: captured graph execution == uncompiled JAX, per model family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(TINY))
def test_capture_parity_sequential(family):
    cfg, params, batch = _setup(family)
    fn = lm_loss_fn(cfg)
    exe = repro.compile(fn, params, batch)
    ref = fn(params, batch)
    got = exe.captured.run(params, batch)       # Graph.execute oracle
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert len(exe.graph) >= 20, f"{family}: graph too coarse ({len(exe.graph)})"
    assert exe.graph.total_flops() > 0
    assert exe.graph.width() >= 2


@pytest.mark.parametrize("family", ["transformer", "moe"])
def test_capture_parity_host_runtime(family):
    cfg, params, batch = _setup(family)
    fn = lm_loss_fn(cfg)
    exe = repro.compile(fn, params, batch, backend="host")
    got = exe(params, batch)
    ref = fn(params, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert len({e.executor for e in exe.last_run.trace}) >= 2


# ---------------------------------------------------------------------------
# acceptance: the compile_lm_loss entry point (ISSUE 2)
# ---------------------------------------------------------------------------

def test_compile_lm_loss_entry_point():
    cfg, params, batch = _setup("transformer")
    exe = compile_lm_loss(cfg, SHAPE, backend="host")
    g = exe.graph
    assert len(g) >= 20
    assert g.width() >= 2
    # non-trivial host schedule on the real inputs
    out = exe(params, batch)
    ref = lm_loss_fn(cfg)(params, batch)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert len({e.executor for e in exe.last_run.trace}) >= 2
    assert exe.last_run.makespan > 0


def test_compile_lm_loss_grad_graph_is_larger():
    cfg = TINY["transformer"]
    fwd = compile_lm_loss(cfg, SHAPE, backend="sim")
    both = compile_lm_loss(cfg, SHAPE, backend="sim", grad=True)
    # the paper: backward roughly doubles nodes and available parallelism
    assert len(both.graph) > 1.5 * len(fwd.graph)
    assert both.graph.total_flops() > 2 * fwd.graph.total_flops()


# ---------------------------------------------------------------------------
# structural sanity of the capture itself
# ---------------------------------------------------------------------------

def test_matmul_flops_exact():
    cg = capture(lambda a, b: a @ b, jnp.ones((8, 32)), jnp.ones((32, 4)))
    gemms = [n for n in cg.graph.nodes if n.kind == "gemm"]
    assert len(gemms) == 1
    assert gemms[0].flops == 2 * 8 * 32 * 4
    assert gemms[0].meta["rows"] == 8


def test_scatter_flops_priced_by_update_size():
    # a paged-KV decode graph writes one token row into a pool thousands of
    # times larger; pricing the scatter by its output buffer would dwarf the
    # real work and skew partitioning
    pool = jnp.zeros((1024, 64))
    upd = jnp.ones((64,))
    cg = capture(lambda p, u: p.at[0].set(u), pool, upd)
    work = sum(n.flops for n in cg.graph.nodes if n.kind != "input")
    assert work < pool.size


def test_elementwise_chain_fuses_into_consumer():
    def f(x, w):
        return jnp.sum(jnp.tanh(x @ w) * 2.0 + 1.0)

    cg = capture(f, jnp.ones((4, 4)), jnp.ones((4, 4)))
    # tanh/mul/add collapse into the gemm or the reduce; only inputs +
    # gemm + reduce survive
    kinds = [n.kind for n in cg.graph.nodes]
    assert kinds.count("gemm") == 1
    assert len(cg.graph) <= 4
    assert cg.n_eqns > len([n for n in cg.graph.nodes if n.kind != "input"])


def test_shared_layer_jaxprs_get_fresh_identities():
    # two call sites of one jitted fn share a traced jaxpr; capture must
    # alpha-rename or the second call aliases the first's values
    @jax.jit
    def layer(x, w):
        return jnp.tanh(x @ w)

    def f(x, w1, w2):
        return jnp.sum(layer(layer(x, w1), w2))

    x, w1, w2 = (jnp.asarray(np.random.default_rng(i).normal(size=(8, 8)),
                             jnp.float32) for i in range(3))
    cg = capture(f, x, w1, w2)
    got, ref = cg.run(x, w1, w2), f(x, w1, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)
    assert len([n for n in cg.graph.nodes if n.kind == "gemm"]) == 2


def test_scan_costs_scale_with_trip_count():
    def body(c, x):
        return c @ x, c.sum()

    def f(c, xs):
        out, ys = jax.lax.scan(body, c, xs)
        return out.sum() + ys.sum()

    c = jnp.ones((4, 4))
    xs8 = jnp.ones((8, 4, 4))
    xs2 = jnp.ones((2, 4, 4))
    g8 = capture(f, c, xs8).graph
    g2 = capture(f, c, xs2).graph
    s8 = sum(n.flops for n in g8.nodes if n.kind == "scan")
    s2 = sum(n.flops for n in g2.nodes if n.kind == "scan")
    assert s8 == pytest.approx(4 * s2)
    got = capture(f, c, xs8).run(c, xs8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(f(c, xs8)), rtol=1e-6)


def test_capture_multi_output_pytree():
    def f(x):
        return {"a": x * 2, "b": (x.sum(), x - 1)}

    x = jnp.arange(6.0).reshape(2, 3)
    cg = capture(f, x)
    got, ref = cg.run(x), f(x)
    assert jax.tree_util.tree_structure(got) == jax.tree_util.tree_structure(ref)
    for a, b in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_capture_rejects_wrong_arg_structure():
    cg = capture(lambda x: x * 2, jnp.ones((3,)))
    with pytest.raises(TypeError):
        cg.bind((jnp.ones((3,)), jnp.ones((3,))))


def test_capture_from_shape_structs_runs_on_concrete():
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    cg = capture(lambda a, b: jnp.sum(a @ b), spec, spec)
    a = jnp.asarray(np.random.default_rng(0).normal(size=(4, 4)), jnp.float32)
    b = jnp.asarray(np.random.default_rng(1).normal(size=(4, 4)), jnp.float32)
    np.testing.assert_allclose(np.asarray(cg.run(a, b)),
                               np.asarray(jnp.sum(a @ b)), rtol=1e-6)
