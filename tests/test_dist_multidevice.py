"""Multi-device distribution tests — run in a subprocess so the
``xla_force_host_platform_device_count`` flag can be set before jax init
without polluting the single-device test session."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidevice  # subprocess-based: each test re-inits jax

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.abspath(REPO_SRC)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_ring_collective_matmuls_match_reference():
    out = _run("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.dist.overlap import ring_allgather_matmul, ring_reducescatter_matmul
        mesh = jax.make_mesh((8,), ("model",))
        x = jax.random.normal(jax.random.key(0), (64, 32), jnp.float32)
        w = jax.random.normal(jax.random.key(1), (32, 48), jnp.float32)
        f = shard_map(partial(ring_allgather_matmul, axis_name="model"), mesh=mesh,
                      in_specs=(P("model", None), P(None, "model")), out_specs=P(None, "model"))
        g = shard_map(partial(ring_reducescatter_matmul, axis_name="model"), mesh=mesh,
                      in_specs=(P(None, "model"), P("model", None)), out_specs=P("model", None))
        e1 = float(jnp.abs(jax.jit(f)(x, w) - x @ w).max())
        e2 = float(jnp.abs(jax.jit(g)(x, w) - x @ w).max())
        assert e1 < 1e-4 and e2 < 1e-4, (e1, e2)
        print("OK", e1, e2)
    """)
    assert "OK" in out


def test_compressed_psum_and_error_feedback():
    out = _run("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.dist.compress import compressed_psum
        mesh = jax.make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.key(2), (8, 256), jnp.float32)
        err = jnp.zeros((8, 256))
        h = shard_map(partial(compressed_psum, axis_name="pod"), mesh=mesh,
                      in_specs=(P("pod", None), P("pod", None)),
                      out_specs=(P("pod", None), P("pod", None)))
        gm, ne = jax.jit(h)(g, err)
        rel = float(jnp.abs(gm[0] - g.mean(0)).max() / jnp.abs(g.mean(0)).max())
        assert rel < 0.05, rel
        # error feedback: accumulated mean over repeats converges
        gm2, ne2 = jax.jit(h)(g, ne)
        acc = (gm[0] + gm2[0]) / 2
        rel2 = float(jnp.abs(acc - g.mean(0)).max() / jnp.abs(g.mean(0)).max())
        assert rel2 < rel + 0.01
        print("OK", rel, rel2)
    """)
    assert "OK" in out


def test_smoke_cell_compiles_on_small_mesh_and_has_collectives():
    """A reduced-config train cell lowers+compiles on a 2x4 mesh and the
    compiled module contains the expected collective kinds."""
    out = _run("""
        import jax
        from repro.launch.cells import build_cell, CellPlan
        from repro.analysis.hlo_collectives import collective_summary
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cell = build_cell("yi_9b", "train_4k", mesh, smoke=True,
                          plan=CellPlan(microbatches=2, seq_shard=False, remat=True))
        c = cell.lower().compile()
        stats = collective_summary(c.as_text())
        assert "all-reduce" in stats.per_kind, stats.per_kind
        assert stats.total_bytes > 0
        print("OK", sorted(stats.per_kind))
    """, devices=8)
    assert "OK" in out


def test_elastic_restore_onto_different_mesh():
    """Checkpoint saved unsharded restores onto a 2x2 mesh with shardings."""
    out = _run("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        state = {"w": jnp.arange(64.0).reshape(8, 8), "step": jnp.asarray(3)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            mgr.save(3, state)
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            sh = {"w": NamedSharding(mesh, P("data", "model")),
                  "step": NamedSharding(mesh, P())}
            step, out = mgr.restore(state, shardings=sh)
            assert step == 3
            assert out["w"].sharding.is_equivalent_to(sh["w"], 2)
            assert np.array_equal(np.asarray(out["w"]), np.arange(64.0).reshape(8, 8))
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_fsdp_param_specs_shard_over_data():
    out = _run("""
        import jax
        from repro.configs.base import get_config
        from repro.dist.sharding import param_pspecs
        from repro.models import transformer
        cfg = get_config("yi_9b")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        shapes = jax.eval_shape(lambda k: transformer.init_params(cfg, k), jax.random.key(0))
        specs = param_pspecs(cfg, shapes, mesh, fsdp=True)
        flat = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
        n_data = sum(1 for s in flat if "data" in jax.tree.leaves(tuple(s)))
        assert n_data > 4, n_data
        print("OK", n_data)
    """, devices=8)
    assert "OK" in out
