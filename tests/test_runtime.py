"""The process-wide :class:`repro.Runtime` (ISSUE 5): executor leasing with
FIFO admission, the persistent calibration store, runtime-owned plan caches,
concurrent executables bounded by one pool, and the idempotent
segment-safe ``ExecutorPool.close``."""
import json
import threading
import time

import numpy as np
import pytest

import repro
from repro.core import KNL7250, Graph, compile_host_plan, make_schedule
from repro.core.engine import ExecutorPool
from repro.core.static_host import layered_graph as layered
from repro.runtime import (
    CalibrationStore,
    Runtime,
    default_runtime,
    graph_signature,
    set_default_runtime,
)


def _executor_threads():
    return {t for t in threading.enumerate()
            if t.name.startswith("graphi-executor") and t.is_alive()}


# ---------------------------------------------------------------------------
# graph signatures + calibration store
# ---------------------------------------------------------------------------

def test_graph_signature_is_structural():
    assert graph_signature(layered()) == graph_signature(layered())
    assert graph_signature(layered(L=5)) != graph_signature(layered())
    # jitted node fns time differently at identical structure: the variant
    # salt keeps their measured tables apart
    assert graph_signature(layered(), variant="jit") != graph_signature(layered())


def test_calibration_store_save_load(tmp_path):
    path = str(tmp_path / "cal.json")
    store = CalibrationStore(path)
    store.put("sig-a", {"op1": 1e-3, "op2": 2e-3})
    assert "sig-a" in store                       # autosaved on put
    fresh = CalibrationStore(path)
    assert fresh.get("sig-a") == {"op1": 1e-3, "op2": 2e-3}
    assert fresh.get("sig-b") is None


def test_calibration_store_rejects_unknown_format(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"format": 99, "entries": {}}))
    with pytest.raises(ValueError, match="format"):
        CalibrationStore(str(p))


def test_calibrate_round_trips_through_a_fresh_runtime(tmp_path, monkeypatch):
    """calibrate() -> save -> fresh Runtime load -> identical schedule and
    host-plan placements without re-measuring (satellite 6)."""
    path = str(tmp_path / "cal.json")
    with Runtime(n_workers=2, calibration_path=path) as rt1:
        exe = rt1.compile(layered(), backend="host", host_mode="static")
        exe.calibrate(inputs={"x": 1.0})
        placements = dict(exe.schedule.placements)
        programs = exe.host_plan().programs
        width = exe.host_plan().n_executors
        measured = dict(exe._measured(exe.schedule.team_size))

    # the fresh runtime must seed from the store, never measure again
    monkeypatch.setattr(
        "repro.api.measure_op_costs",
        lambda *a, **k: pytest.fail("second runtime re-measured op costs"))
    with Runtime(n_workers=2, calibration_path=path) as rt2:
        exe2 = rt2.compile(layered(), backend="host", host_mode="static")
        assert exe2.calibrated
        assert dict(exe2._measured(exe2.schedule.team_size)) == measured
        assert dict(exe2.schedule.placements) == placements
        assert exe2.host_plan(width).programs == programs
        # and the seeded executable still runs correctly on its leases
        assert exe2.execute_host({"x": 2.0}).outputs == layered().execute({"x": 2.0})


# ---------------------------------------------------------------------------
# admission: FIFO leases over one pool
# ---------------------------------------------------------------------------

def test_lease_clamps_reuses_and_releases():
    with Runtime(n_workers=2) as rt:
        lease = rt.lease(100)                     # clamped to the pool
        assert lease.n_executors == 2
        assert rt.leased_executors == 2
        lease.release()
        lease.release()                           # idempotent
        assert rt.leased_executors == 0
        with rt.lease(1):
            assert rt.leased_executors == 1
        assert rt.leased_executors == 0


def test_lease_timeout_raises():
    with Runtime(n_workers=2) as rt:
        with rt.lease(2):
            with pytest.raises(TimeoutError):
                rt.lease(1, timeout=0.05)


def test_admission_is_fifo_no_barging():
    with Runtime(n_workers=2) as rt:
        order: list[str] = []
        first = rt.lease(2)

        def want(width, tag):
            with rt.lease(width):
                order.append(tag)
                time.sleep(0.02)

        wide = threading.Thread(target=want, args=(2, "wide"))
        wide.start()
        while rt._admission.n_waiting != 1:       # wide is queued
            time.sleep(0.001)
        narrow = threading.Thread(target=want, args=(1, "narrow"))
        narrow.start()
        while rt._admission.n_waiting != 2:       # narrow queued behind it
            time.sleep(0.001)
        first.release()
        wide.join(timeout=5)
        narrow.join(timeout=5)
        # narrow would fit the moment one executor frees, but FIFO means the
        # wide request at the head is served first — no starvation
        assert order == ["wide", "narrow"]


def test_lease_remaps_executor_indices():
    g = layered(L=3, W=2)
    plan = compile_host_plan(
        g, make_schedule(g, KNL7250, n_executors=1, team_size=1))
    with Runtime(n_workers=2) as rt:
        low = rt.lease(1)                         # pins global executor 0
        high = rt.lease(1)                        # the plan runs on global 1
        assert low.executor_ids != high.executor_ids
        try:
            res = plan.run({"x": 4.0}, pool=high)
            assert res.outputs == g.execute({"x": 4.0})
        finally:
            high.release()
            low.release()


def test_admission_survives_exception_mid_wait():
    """An exception out of the condition wait (e.g. KeyboardInterrupt) must
    not leave an orphaned ticket wedging strict-FIFO admission."""
    with Runtime(n_workers=2) as rt:
        holder = rt.lease(2)
        adm = rt._admission
        real_wait_for = adm._cond.wait_for
        adm._cond.wait_for = lambda *a, **k: (_ for _ in ()).throw(
            KeyboardInterrupt())
        try:
            with pytest.raises(KeyboardInterrupt):
                adm.acquire(1)
        finally:
            adm._cond.wait_for = real_wait_for
        assert adm.n_waiting == 0                 # no dead ticket at the head
        holder.release()
        with rt.lease(2):                         # admission still serves
            pass


def test_calibration_store_concurrent_puts_stay_loadable(tmp_path):
    path = str(tmp_path / "cal.json")
    store = CalibrationStore(path)

    def put_many(tag):
        for i in range(20):
            store.put(f"{tag}-{i}", {"op": float(i)})

    ths = [threading.Thread(target=put_many, args=(t,)) for t in ("a", "b")]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    fresh = CalibrationStore(path)                # the file is valid JSON
    assert len(fresh) == 40


def test_oversized_explicit_plan_fails_with_remedy():
    g = layered()
    with Runtime(n_workers=2) as rt:
        exe = rt.compile(g, backend="host")
        wide = compile_host_plan(
            g, make_schedule(g, KNL7250, n_executors=4, team_size=1))
        with pytest.raises(ValueError, match="recompile the plan"):
            exe.execute_host({"x": 1.0}, plan=wide)


def test_dropped_graph_releases_its_cached_plans():
    import weakref

    with Runtime(n_workers=2) as rt:
        g = layered()
        exe = rt.compile(g, backend="host", host_mode="static",
                         n_executors=2, team_size=1)
        exe.execute_host({"x": 1.0})
        ref = weakref.ref(g)
        del exe, g
        import gc

        gc.collect()
        assert ref() is None                      # no runtime-side pin


def test_closed_runtime_rejects_new_work():
    rt = Runtime(n_workers=2)
    _ = rt.pool
    rt.close()
    rt.close()                                    # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        rt.lease(1)


# ---------------------------------------------------------------------------
# the default runtime behind bare repro.compile
# ---------------------------------------------------------------------------

def test_default_runtime_is_a_recreated_singleton():
    prev = set_default_runtime(None)
    try:
        rt = default_runtime()
        assert default_runtime() is rt
        rt.close()
        fresh = default_runtime()                 # closed default is replaced
        assert fresh is not rt and not fresh.closed
        fresh.close()
    finally:
        set_default_runtime(prev)


def test_bare_compile_binds_the_default_runtime():
    import jax.numpy as jnp

    exe = repro.compile(lambda v: jnp.tanh(v) + v * 2, jnp.ones((8,)))
    assert exe.runtime is repro.default_runtime()
    out = exe(jnp.ones((8,)))
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(jnp.tanh(jnp.ones((8,))) + 2.0))
    assert exe.runtime.leased_executors == 0      # returned after the run


def test_runtime_shares_plans_across_executables():
    g = layered()
    with Runtime(n_workers=2) as rt:
        e1 = rt.compile(g, backend="host", host_mode="static",
                        n_executors=2, team_size=1)
        e2 = rt.compile(g, backend="host", host_mode="static",
                        n_executors=2, team_size=1)
        assert e1.host_plan() is e2.host_plan()   # frozen once per (graph, width)
        e1.profile_with()                         # invalidates the graph's entry
        assert e1.host_plan() is not None


# ---------------------------------------------------------------------------
# concurrent executables on one Runtime (satellite: thread bound + parity)
# ---------------------------------------------------------------------------

def test_concurrent_decode_and_train_stay_bounded_and_bitexact():
    """A decode-shaped plan replaying statically while a captured train-step
    graph runs dynamically: total executor threads never exceed the
    runtime's ``n_workers`` and both produce bit-exact outputs vs isolated
    runs."""
    import jax
    import jax.numpy as jnp

    def loss(params, x):
        h = jnp.tanh(x @ params["w1"])
        return jnp.sum(jnp.tanh(h @ params["w2"]) ** 2)

    rng = np.random.default_rng(0)
    params = {"w1": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32),
              "w2": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)

    baseline = _executor_threads()
    with Runtime(n_workers=3) as rt:
        dec_g = layered(L=8, W=3)
        dec = rt.compile(dec_g, backend="host", host_mode="static",
                         n_executors=2, team_size=1)
        train = rt.compile(jax.value_and_grad(loss), params, x,
                           backend="host")        # dynamic scheduler
        # isolated references first (also warms captures/plans)
        dec_ref = [dec.execute_host({"x": float(k)}).outputs["out"]
                   for k in range(6)]
        train_ref = jax.tree.leaves(train(params, x))

        peak = {"threads": 0, "leased": 0}
        stop = threading.Event()

        def sampler():
            while not stop.is_set():
                peak["threads"] = max(
                    peak["threads"], len(_executor_threads() - baseline))
                peak["leased"] = max(peak["leased"], rt.leased_executors)
                time.sleep(0.001)

        outs: dict = {}

        def run_dec():
            outs["dec"] = [dec.execute_host({"x": float(k)}).outputs["out"]
                           for k in range(6)]

        def run_train():
            outs["train"] = [jax.tree.leaves(train(params, x))
                             for _ in range(4)]

        ths = [threading.Thread(target=f) for f in (run_dec, run_train)]
        smp = threading.Thread(target=sampler)
        smp.start()
        for t in ths:
            t.start()
        for t in ths:
            t.join(timeout=60)
        stop.set()
        smp.join(timeout=5)
        assert all(not t.is_alive() for t in ths)

        assert peak["threads"] <= rt.n_workers == 3
        assert peak["leased"] <= rt.n_workers
        assert outs["dec"] == dec_ref
        for got in outs["train"]:
            for a, b in zip(got, train_ref):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# ExecutorPool.close: idempotent, safe with segments in flight (satellite)
# ---------------------------------------------------------------------------

def test_pool_close_is_idempotent_and_race_free():
    pool = ExecutorPool(2)
    pool.close()
    pool.close()                                  # second close: no-op
    pool2 = ExecutorPool(2)
    errs: list[BaseException] = []

    def closer():
        try:
            pool2.close()
        except BaseException as e:  # noqa: BLE001 — the test is "no raise"
            errs.append(e)

    ths = [threading.Thread(target=closer) for _ in range(4)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(timeout=10)
    assert not errs
    assert all(not t.is_alive() for t in pool2._threads)
    with pytest.raises(RuntimeError, match="closed"):
        pool2.submit(0, "late", lambda: 1, None, 0.0)


def test_pool_close_with_segments_in_flight_completes_the_run():
    """close() while a static plan's segments are executing must neither
    hang nor raise from worker threads: queued work precedes the shutdown
    sentinel, so the in-flight run completes and close returns."""
    g = Graph("slowplan")
    g.add_op("x", kind="input")
    prev = "x"
    for i in range(6):
        for w in range(2):
            g.add_op(f"l{i}w{w}", deps=(prev,), flops=1.0,
                     fn=lambda v, w=w: (time.sleep(0.005), v + w)[1])
        g.add_op(f"j{i}", deps=(f"l{i}w0", f"l{i}w1"), flops=1.0,
                 fn=lambda a, b: a + b)
        prev = f"j{i}"
    plan = compile_host_plan(
        g, make_schedule(g, KNL7250, n_executors=2, team_size=1))
    oracle = g.execute({"x": 1.0})

    pool = ExecutorPool(2)
    box: dict = {}

    def run():
        try:
            box["res"] = plan.run({"x": 1.0}, pool=pool)
        except BaseException as e:  # noqa: BLE001 — inspected below
            box["err"] = e

    th = threading.Thread(target=run)
    th.start()
    time.sleep(0.01)                              # segments are mid-flight
    pool.close()                                  # must not split the batch
    th.join(timeout=15)
    assert not th.is_alive(), "plan.run hung across pool.close()"
    assert "err" not in box, box.get("err")
    assert box["res"].outputs == oracle
    pool.close()                                  # and again, idempotent


# ---------------------------------------------------------------------------
# robustness (ISSUE 9): deadlines, quarantine + healing, shedding, leak
# reclaim, stuck-close diagnostics, admission exception paths under load
# ---------------------------------------------------------------------------

def _slow_graph(name="wedge", sleep_s=5.0):
    g = Graph(name)
    g.add_op("x", kind="input")
    g.add_op("slow", deps=("x",), flops=1.0,
             fn=lambda v: (time.sleep(sleep_s), v)[1])
    g.add_op("out", deps=("slow",), flops=1.0, fn=lambda v: v + 1)
    return g


def test_pool_close_stuck_thread_raises_and_names_op():
    """A thread stuck in an op past the close timeout must not be silently
    abandoned: close() raises, naming the executor and the op."""
    import queue as _queue

    pool = ExecutorPool(2)
    release = threading.Event()
    pool.submit(0, "wedged_op", lambda: release.wait(30), _queue.SimpleQueue(),
                time.monotonic())
    time.sleep(0.05)
    try:
        with pytest.raises(RuntimeError, match="wedged_op"):
            pool.close(timeout=0.2)
        assert pool.stuck_executors
        assert pool.stuck_executors[0][1] == "wedged_op"
    finally:
        release.set()
        pool.close(timeout=5.0)


def test_pool_close_stuck_warns_without_raise_when_asked(caplog):
    import logging
    import queue as _queue

    pool = ExecutorPool(2)
    release = threading.Event()
    pool.submit(1, "hung", lambda: release.wait(30), _queue.SimpleQueue(),
                time.monotonic())
    time.sleep(0.05)
    try:
        with caplog.at_level(logging.WARNING, logger="repro.core.engine"):
            pool.close(timeout=0.2, raise_on_stuck=False)
        assert any("hung" in r.message for r in caplog.records)
    finally:
        release.set()
        pool.close(timeout=5.0)


def test_host_run_deadline_raises_and_frees_the_lease():
    """A hung op overshoots the deadline: the run raises DeadlineExceeded
    naming the in-flight op, the busy executor is quarantined (not
    returned to circulation), and it heals once the op finally returns."""
    from repro.core.engine import HostScheduler

    rt = Runtime(2)
    try:
        g = _slow_graph(sleep_s=1.0)
        lease = rt.lease(2)
        sched = HostScheduler(g, 2, pool=lease)
        with pytest.raises(repro.DeadlineExceeded, match="slow"):
            sched.run({"x": 1.0}, deadline=time.monotonic() + 0.15)
        lease.release(quarantine_busy=True)
        assert rt.health()["quarantined"] >= 1
        # while quarantined, full-width leases are not grantable
        with pytest.raises(TimeoutError):
            rt.lease(2, timeout=0.1)
        # the op returns -> the executor heals -> full width grantable again
        time.sleep(1.1)
        lease2 = rt.lease(2, timeout=5.0)
        assert rt.health()["quarantined"] == 0
        lease2.release()
    finally:
        rt.close()


def test_execute_host_deadline_quarantines_via_api():
    rt = Runtime(2)
    try:
        exe = repro.compile(_slow_graph("deadline_graph", sleep_s=0.8),
                            backend="host", n_executors=2,
                            host_mode="dynamic", runtime=rt)
        with pytest.raises(repro.DeadlineExceeded):
            exe.execute_host({"x": 2.0}, deadline=time.monotonic() + 0.1)
        assert rt.health()["quarantined"] >= 1
        time.sleep(1.0)
        lease = rt.lease(rt.n_workers, timeout=5.0)  # healed: full width
        lease.release()
    finally:
        rt.close()


def test_lease_shedding_rejects_with_jittered_retry_after():
    rt = Runtime(1, shed_after_s=0.05, seed=7)
    try:
        hold = rt.lease(1)
        # prime the hold-time estimate so estimated_wait() is meaningful
        rt._admission._hold_ewma = 0.5
        # an explicit per-call budget overrides the runtime default: this
        # waiter queues instead of shedding
        waiter = threading.Thread(
            target=lambda: rt.lease(1, timeout=2.0,
                                    shed_after_s=1e9).release())
        waiter.start()
        time.sleep(0.05)          # ensure the queue is non-empty
        with pytest.raises(repro.AdmissionRejected) as ei:
            rt.lease(1)
        assert ei.value.retry_after > 0.0
        assert rt.health()["shed"] == 1
        hold.release()
        waiter.join(timeout=5)
        assert not waiter.is_alive()
    finally:
        rt.close()


def test_dropped_lease_is_reclaimed_not_leaked():
    """A lease object that is dropped without release() (the corrupt-client
    case) must not shrink capacity forever: reclaim_leaks recovers the ids
    after the grace period."""
    rt = Runtime(2)
    try:
        rt.lease(2)               # dropped on the floor: no release()
        import gc

        gc.collect()              # the WeakSet entry dies with the object
        time.sleep(0.3)           # past the reclaim grace window
        assert rt.reclaim_leaks() == 2 or rt._admission.n_free == 2
        lease = rt.lease(2, timeout=1.0)
        lease.release()
        assert rt.health()["leaks_reclaimed"] >= 2
    finally:
        rt.close()


@pytest.mark.stress
def test_admission_hammered_with_exceptions_stays_consistent():
    """Many threads acquire/release concurrently while some abort with
    exceptions mid-wait and some double-release: afterwards the admission
    state must show every executor free and nobody waiting."""
    rt = Runtime(3, seed=1)
    try:
        stop = time.monotonic() + 1.5
        errs: list[BaseException] = []

        def worker(i):
            rng = np.random.default_rng(i)
            try:
                while time.monotonic() < stop:
                    w = int(rng.integers(1, 4))
                    try:
                        lease = rt.lease(w, timeout=0.05)
                    except TimeoutError:
                        continue
                    if rng.random() < 0.2:
                        raise RuntimeError("simulated client crash")
                    time.sleep(float(rng.random()) * 0.004)
                    lease.release()
                    if rng.random() < 0.3:
                        lease.release()          # double release: no-op
            except RuntimeError:
                # crashed client: lease dropped without release
                pass
            except BaseException as e:  # noqa: BLE001 — collected for assert
                errs.append(e)

        ths = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
        for t in ths:
            t.start()
        for t in ths:
            t.join(timeout=30)
        assert not errs, errs
        assert all(not t.is_alive() for t in ths)
        import gc

        gc.collect()
        time.sleep(0.3)
        rt.reclaim_leaks()
        h = rt.health()
        assert h["free"] == 3, h                 # no stranded lease width
        assert h["waiting"] == 0, h              # no stale tickets
        lease = rt.lease(3, timeout=1.0)         # full width still grantable
        lease.release()
    finally:
        rt.close()
