"""Fleet tier: routing, supervision, failover, bit-exact replay.

The fast tests drive toy-engine fleets (worker processes spawn in ~100ms,
no jax in the children); the ``stress``-marked drills inject seeded faults
— SIGKILL mid-decode, a wedged serve loop, a live-but-muted replica — and
assert the tentpole contract: zero lost requests and bit-identical token
streams across failover.  CI runs the stress set in a dedicated job under
a hard wall-clock timeout.
"""
import time

import pytest

from repro.fleet import (Fleet, FleetConfig, FaultInjector, FaultSpec, Router,
                         corrupt_lease_release)
from repro.fleet.worker import ToyEngine, toy_next_token

VOCAB = 101


def toy_cfg(n_workers, *, service=0.002, hb=0.05, inflight=3, **kw):
    return FleetConfig(
        n_workers=n_workers,
        engine={"kind": "toy", "vocab_size": VOCAB, "service_time_s": service},
        heartbeat_s=hb, max_inflight_per_worker=inflight, term_grace_s=0.3,
        **kw)


def reference(prompt, n):
    out = []
    for _ in range(n):
        out.append(toy_next_token(prompt, out, VOCAB, seed=0))
    return out


def assert_exact(done):
    for r in done:
        assert list(r.tokens) == reference(r.prompt, r.max_new), \
            f"request {r.rid} diverged after {r.n_requeues} requeue(s)"


# ---------------------------------------------------------------------------
# components
# ---------------------------------------------------------------------------

def test_toy_engine_resume_is_bit_exact():
    """Decoding 10 tokens straight == decoding 4, then resuming a fresh
    engine with those 4 as ``emitted`` — the replay contract in miniature."""
    e1 = ToyEngine(vocab_size=VOCAB)
    e1.submit(0, (3, 1, 4), 10)
    full = []
    while e1.has_work:
        full += [t for _, t, _, _ in e1.step()]

    e2 = ToyEngine(vocab_size=VOCAB)
    e2.submit(0, (3, 1, 4), 10, emitted=full[:4])
    resumed = list(full[:4])
    while e2.has_work:
        for _, tok, idx, _ in e2.step():
            assert idx == len(resumed)
            resumed.append(tok)
    assert resumed == full == reference((3, 1, 4), 10)


def test_router_prefers_affinity_then_load():
    r = Router(affinity_len=4, max_load_gap=2)
    for w in (0, 1, 2):
        r.add_worker(w)
    cap = {0: 4, 1: 4, 2: 4}
    prompt = (7, 7, 7, 7, 9)
    first = r.pick(prompt, capacity=cap)
    assert first == 0                      # all empty: lowest id wins
    # same prefix routes back to the same worker (affinity hit)
    assert r.pick((7, 7, 7, 7, 1), capacity=cap) == first
    assert r.n_affinity_hits == 1
    # a different prefix goes to the least-loaded worker, not worker 0
    assert r.pick((8, 8, 8, 8), capacity=cap) == 1
    # affinity yields once the load gap exceeds max_load_gap
    for _ in range(3):
        r.pick(prompt, capacity=cap)       # pile onto worker 0 (load 5)
    assert r.pick((7, 7, 7, 7, 2), capacity={0: 1, 1: 4, 2: 4}) != 0


def test_router_full_fleet_returns_none_and_forgets_dead_workers():
    r = Router()
    r.add_worker(0)
    assert r.pick((1, 2), capacity={0: 0}) is None
    assert r.pick((1, 2), capacity={0: 1}) == 0
    r.remove_worker(0)
    assert r.pick((1, 2), capacity={0: 3}) is None   # dead: not routable


# ---------------------------------------------------------------------------
# healthy-fleet behaviour
# ---------------------------------------------------------------------------

def test_fleet_drains_bit_exact_and_in_submit_order():
    reqs = [([i, i + 1], 8) for i in range(7)]
    with Fleet(toy_cfg(2)) as fleet:
        done = fleet.run(reqs, timeout_s=60)
        stats = fleet.stats()
    assert [r.rid for r in done] == sorted(r.rid for r in done)
    assert len(done) == 7
    assert_exact(done)
    assert stats["n_failovers"] == 0
    assert stats["router_routed"] == 7


def test_fleet_streams_tokens_in_order():
    seen: dict[int, list] = {}
    with Fleet(toy_cfg(2)) as fleet:
        fleet.on_token = lambda rid, tok, idx: seen.setdefault(rid, []).append(
            (idx, tok))
        done = fleet.run([([1, 2, 3], 6), ([4, 5], 6)], timeout_s=60)
    for r in done:
        assert [i for i, _ in seen[r.rid]] == list(range(r.max_new))
        assert [t for _, t in seen[r.rid]] == list(r.tokens)


def test_fleet_same_prompt_hits_same_replica():
    prompt = [9] * 20
    with Fleet(toy_cfg(2, inflight=8)) as fleet:
        fleet.run([(prompt, 4) for _ in range(6)], timeout_s=60)
        stats = fleet.stats()
    assert stats["router_affinity_hits"] >= 5


# ---------------------------------------------------------------------------
# fault drills (stress: dedicated CI job, hard timeout)
# ---------------------------------------------------------------------------

@pytest.mark.stress
@pytest.mark.parametrize("kind", ["kill", "die", "stall", "mute"])
def test_fleet_failover_zero_loss_bit_exact(kind):
    """The tentpole drill: kill/wedge/mute a replica mid-decode; every
    request still completes with a bit-identical stream."""
    reqs = [([i, i + 2], 16) for i in range(8)]
    with Fleet(toy_cfg(4, inflight=2)) as fleet:
        inj = FaultInjector(
            [FaultSpec(kind=kind, at_tokens=12, duration_s=5.0)], seed=3)
        done = fleet.run(reqs, injector=inj, timeout_s=120)
        stats = fleet.stats()
    assert len(done) == len(reqs), "lost requests across failover"
    assert_exact(done)
    assert inj.all_fired
    assert stats["n_failovers"] >= 1
    assert stats["n_requeued"] >= 1
    assert stats["n_restarts"] >= 1


@pytest.mark.stress
def test_fleet_survives_two_sequential_kills():
    reqs = [([i], 20) for i in range(8)]
    with Fleet(toy_cfg(3, inflight=3, max_restarts=4)) as fleet:
        inj = FaultInjector([FaultSpec(kind="kill", at_tokens=20),
                             FaultSpec(kind="kill", at_tokens=80)], seed=11)
        done = fleet.run(reqs, injector=inj, timeout_s=120)
        stats = fleet.stats()
    assert len(done) == len(reqs)
    assert_exact(done)
    assert stats["n_failovers"] == 2
    # the killed slots respawned with bumped generations
    assert sum(stats["generations"].values()) == 2


@pytest.mark.stress
def test_fleet_short_mute_flushes_buffered_stream():
    """A mute shorter than the liveness deadline must NOT fail the worker:
    the buffered tokens flush in order and indices stay contiguous."""
    cfg = toy_cfg(1, inflight=4, liveness_s=2.0)
    with Fleet(cfg) as fleet:
        inj = FaultInjector(
            [FaultSpec(kind="mute", at_tokens=4, duration_s=0.3)], seed=0)
        done = fleet.run([([1, 2], 24), ([3, 4], 24)], injector=inj,
                         timeout_s=60)
        stats = fleet.stats()
    assert stats["n_failovers"] == 0
    assert len(done) == 2
    assert_exact(done)


@pytest.mark.stress
def test_fleet_wedge_is_detected_by_silence():
    """A stalled serve loop sends no heartbeats; the liveness deadline —
    not a crash — must trigger the failover."""
    reqs = [([i, i], 16) for i in range(4)]
    with Fleet(toy_cfg(2, inflight=2)) as fleet:
        inj = FaultInjector(
            [FaultSpec(kind="stall", at_tokens=8, duration_s=10.0)], seed=5)
        t0 = time.monotonic()
        done = fleet.run(reqs, injector=inj, timeout_s=120)
        wall = time.monotonic() - t0
        events = list(fleet.events)
    assert len(done) == len(reqs)
    assert_exact(done)
    fails = [(t, why) for t, kind, _, why in events if kind == "fail"]
    assert fails and "silent" in fails[0][1]
    assert wall < 10.0, "drain waited for the stall instead of failing over"


def test_fleet_restart_budget_exhaustion_raises():
    with pytest.raises(RuntimeError, match="restart budget"):
        with Fleet(toy_cfg(1, max_restarts=0)) as fleet:
            fleet.submit([1, 2], 50)
            inj = FaultInjector([FaultSpec(kind="kill", at_tokens=2)], seed=0)
            fleet.run(timeout_s=60, injector=inj)


# ---------------------------------------------------------------------------
# runtime-level fault: corrupted lease release
# ---------------------------------------------------------------------------

def test_corrupt_lease_release_is_absorbed():
    import repro

    rt = repro.Runtime(3)
    try:
        health = corrupt_lease_release(rt, width=2)
        assert health["bad_releases"] >= 2        # double + stale release
        assert health["free"] == 3                # free list intact
        lease = rt.lease(3)                       # full width still grantable
        lease.release()
    finally:
        rt.close()
