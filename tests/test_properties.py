"""Hypothesis property tests on system invariants: scheduling bounds,
simulator conservation laws, slot legality, optimizer sanity."""
import jax
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    KNL7250,
    Graph,
    OpNode,
    SimConfig,
    make_schedule,
    simulate,
    slot_assignment,
)


@st.composite
def random_dag(draw):
    n = draw(st.integers(3, 24))
    g = Graph("prop")
    for i in range(n):
        deps = []
        if i:
            k = draw(st.integers(0, min(i, 3)))
            deps = sorted({draw(st.integers(0, i - 1)) for _ in range(k)})
        g.add(OpNode(
            f"op{i}", kind=draw(st.sampled_from(["gemm", "elementwise"])),
            flops=draw(st.floats(1e4, 1e9)),
            bytes_in=draw(st.floats(1e3, 1e7)),
            bytes_out=draw(st.floats(1e3, 1e6)),
            deps=tuple(f"op{d}" for d in deps),
        ))
    return g


@settings(max_examples=40, deadline=None)
@given(random_dag(), st.integers(1, 8), st.sampled_from(["cpf", "fifo", "random"]))
def test_simulator_invariants(g, n_exec, policy):
    cfg = SimConfig(n_executors=n_exec, team_size=8, policy=policy)
    res = simulate(g, KNL7250, cfg)
    # every op exactly once
    assert sorted(e.op for e in res.trace) == sorted(g.names)
    # dependency causality
    end = {e.op: e.end for e in res.trace}
    start = {e.op: e.start for e in res.trace}
    for n in g.names:
        for d in g.predecessors(n):
            assert end[d] <= start[n] + 1e-12
    # executor exclusivity
    per = {}
    for e in res.trace:
        per.setdefault(e.executor, []).append((e.start, e.end))
    for iv in per.values():
        iv.sort()
        for (_s0, e0), (s1, _e1) in zip(iv, iv[1:]):
            assert e0 <= s1 + 1e-12
    # makespan lower bounds: critical path and total-work/n
    costs = res.op_costs
    cp, _ = g.critical_path(costs)
    assert res.makespan >= cp - 1e-9
    assert res.makespan >= sum(costs.values()) / n_exec - 1e-9


@settings(max_examples=30, deadline=None)
@given(random_dag(), st.integers(1, 6))
def test_schedule_validates_and_slots_are_antichains(g, n_exec):
    sched = make_schedule(g, KNL7250, n_executors=n_exec, team_size=8)
    sched.validate(g)
    slots = slot_assignment(g, sched)
    assert sorted(n for s in slots for n in s) == sorted(g.names)
    seen_slot = {}
    for i, slot in enumerate(slots):
        assert len(slot) <= n_exec
        for n in slot:
            seen_slot[n] = i
    # deps live in strictly earlier slots (barrier semantics)
    for n in g.names:
        for d in g.predecessors(n):
            assert seen_slot[d] < seen_slot[n]


@settings(max_examples=30, deadline=None)
@given(random_dag())
def test_cpf_never_loses_badly_to_random(g):
    """CPF (noise-free) is within 1.5x of the naive policy — list scheduling
    guarantees 2-1/m of optimal, so a catastrophic gap means a bug."""
    a = simulate(g, KNL7250, SimConfig(n_executors=4, team_size=8, policy="cpf"))
    b = simulate(g, KNL7250, SimConfig(n_executors=4, team_size=8, policy="random"))
    assert a.makespan <= b.makespan * 1.5 + 1e-9


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 64))
def test_op_time_monotone_in_team_size(k):
    from repro.core import op_time

    op = OpNode("g", kind="gemm", flops=1e8, bytes_in=1e6, bytes_out=1e5,
                meta={"rows": 512})
    t_k = op_time(KNL7250, op, k)
    t_1 = op_time(KNL7250, op, 1)
    assert t_k <= t_1 * 1.001  # more workers never slower (alpha grows, capped)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_adamw_decreases_quadratic(seed):
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

    key = jax.random.key(seed)
    target = jax.random.normal(key, (16,))
    params = {"w": jnp.zeros(16)}
    opt = adamw_init(params, AdamWConfig(lr=0.05, weight_decay=0.0))
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, params, opt, cfg)
    assert float(loss(params)) < l0 * 0.5


def test_cache_affinity_speeds_matched_elementwise_only():
    """§6 mechanism: affinity-matched elementwise ops run faster; GEMMs and
    unmatched ops are unchanged; all invariants still hold."""
    g = Graph("aff")
    g.add(OpNode("src", kind="gemm", flops=1e8, bytes_in=1e6, bytes_out=1e6))
    g.add(OpNode("ew", kind="elementwise", flops=1e5, bytes_in=1e6, bytes_out=1e6,
                 deps=("src",)))
    g.add(OpNode("gm", kind="gemm", flops=1e8, bytes_in=1e6, bytes_out=1e6,
                 deps=("src",)))
    off = simulate(g, KNL7250, SimConfig(n_executors=1, team_size=8))
    on = simulate(g, KNL7250, SimConfig(n_executors=1, team_size=8, cache_affinity=True))
    dur = lambda res, op: next(e.end - e.start for e in res.trace if e.op == op)
    # one executor: every dep is produced on the same executor -> matched
    assert dur(on, "ew") < dur(off, "ew") * 0.97
    assert abs(dur(on, "gm") - dur(off, "gm")) < 1e-12
    assert dur(on, "src") == dur(off, "src")  # sources have no producer
