"""Per-architecture smoke tests: reduced same-family config, one forward /
train / prefill+decode step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ShapeSpec, get_config, list_archs
from repro.models import api as model_api
from repro.models import transformer
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.step import TrainStepConfig, init_train_state, make_train_step

ARCHS = list_archs()
TRAIN_SHAPE = ShapeSpec("smoke_train", 32, 4, "train")
PREFILL_SHAPE = ShapeSpec("smoke_prefill", 24, 2, "prefill")


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = transformer.init_params(cfg, jax.random.key(0))
    batch = model_api.make_batch(cfg, TRAIN_SHAPE, jax.random.key(1), kind="train")
    logits, aux = transformer.forward(cfg, params, batch)
    B, S_text, S_total = model_api.token_counts(cfg, TRAIN_SHAPE)
    assert logits.shape == (B, S_total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(jnp.asarray(aux))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_loss_finite_and_params_update(arch):
    cfg = get_config(arch, smoke=True)
    state = init_train_state(cfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, TrainStepConfig(microbatches=2, remat=True)))
    batch = model_api.make_batch(cfg, TRAIN_SHAPE, jax.random.key(1), kind="train")
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # at least one parameter leaf actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)),
                     state["params"], new_state["params"]),
    )
    assert moved, arch
    assert int(new_state["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = transformer.init_params(cfg, jax.random.key(0))
    B = PREFILL_SHAPE.global_batch
    cache = transformer.init_cache(cfg, B, 48)
    batch = model_api.make_batch(cfg, PREFILL_SHAPE, jax.random.key(1), kind="prefill")
    logits, cache = jax.jit(make_prefill_step(cfg))(params, cache, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(make_decode_step(cfg))(params, cache, tok)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits2).all()), arch
    assert int(cache2["len"]) == int(cache["len"]) + 1


@pytest.mark.parametrize("arch", ["gemma_2b", "falcon_mamba_7b", "recurrentgemma_2b"])
def test_decode_matches_forward_teacher_forcing(arch):
    """Step-by-step decode logits == full forward logits (same positions).
    f32 so accumulation-order noise doesn't mask semantic mismatches."""
    cfg = get_config(arch, smoke=True).reduced(dtype=jnp.float32)
    params = transformer.init_params(cfg, jax.random.key(0))
    S = 8
    toks = jax.random.randint(jax.random.key(2), (1, S), 0, cfg.vocab_size)
    full, _ = transformer.forward(cfg, params, {"tokens": toks})
    cache = transformer.init_cache(cfg, 1, S + 1)
    dec = []
    for t in range(S):
        logits, cache = transformer.decode_step(cfg, params, toks[:, t:t + 1], cache)
        dec.append(logits)
    import numpy as np

    dec = jnp.stack(dec, axis=1)  # [1, S, Vp]
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_exact_published_configs():
    """The full configs carry the exact published hyper-parameters."""
    g = get_config("gemma-2b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff, g.vocab_size) == \
        (18, 2048, 8, 1, 16384, 256000)
    y = get_config("yi-9b")
    assert (y.n_layers, y.d_model, y.n_heads, y.n_kv_heads, y.d_ff, y.vocab_size) == \
        (48, 4096, 32, 4, 11008, 64000)
    c = get_config("command-r-plus-104b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == \
        (64, 12288, 96, 8, 33792, 256000)
    assert c.parallel_block
    o = get_config("olmoe-1b-7b")
    assert (o.n_experts, o.top_k) == (64, 8)
    m = get_config("falcon-mamba-7b")
    assert (m.n_layers, m.d_model, m.ssm_state) == (64, 4096, 16)
    r = get_config("recurrentgemma-2b")
    assert (r.n_layers, r.d_model, r.block_pattern) == (26, 2560, ("rglru", "rglru", "attn"))
    w = get_config("whisper-medium")
    assert (w.n_layers, w.n_encoder_layers, w.cross_attention) == (24, 24, True)
