"""Policy registry + simulator-guided schedule search (PR 8).

Covers: the SchedulePolicy registry (registration rules, resolution,
executor-assignment hook), deterministic tie-breaking in the simulator
(equal-priority ops pop in stable node-id order — satellite 1 regression),
core.search (winner <= CPF, CPF-preferring ties, S-rule verification),
CalibrationStore schedule sections + format-1 migration, and the
api schedule_search knob (auto/force semantics, store-hit replay without
re-searching — the PR 5 monkeypatch pattern).
"""
import json
import os
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checks import check_schedule
from repro.core import (
    KNL7250,
    Graph,
    OpNode,
    PolicyContext,
    SimConfig,
    get_policy,
    list_policies,
    make_schedule,
    register_policy,
    search_schedule,
    simulate,
    unregister_policy,
)
from repro.core.policies import LevelPack, PerturbedCPF
from repro.core.static_host import layered_graph
from repro.runtime import CalibrationStore, Runtime

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "data")


def random_dag(seed: int, n: int = 18, tie_costs: bool = False) -> Graph:
    """Deterministic random DAG; ``tie_costs`` gives every op identical
    stats so priorities tie heavily (the tie-break stress case)."""
    rng = random.Random(seed)
    g = Graph(f"rand{seed}")
    for i in range(n):
        deps = []
        if i:
            k = rng.randint(0, min(i, 3))
            deps = sorted({rng.randrange(i) for _ in range(k)})
        g.add(OpNode(
            f"op{i}",
            kind=rng.choice(["gemm", "elementwise"]),
            flops=1e6 if tie_costs else rng.uniform(1e4, 1e9),
            bytes_in=1e4 if tie_costs else rng.uniform(1e3, 1e7),
            bytes_out=1e3 if tie_costs else rng.uniform(1e3, 1e6),
            deps=tuple(f"op{d}" for d in deps),
        ))
    return g


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_lists_cpf_first_and_all_builtins():
    names = list_policies()
    assert names[0] == "cpf"
    assert {"cpf", "level-pack", "lpt", "cpf-perturb"} <= set(names)


def test_get_policy_unknown_name_lists_registered():
    with pytest.raises(ValueError, match="cpf"):
        get_policy("does-not-exist")
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_schedule(random_dag(0), KNL7250, n_executors=2, team_size=8,
                      policy="does-not-exist")


def test_register_rejects_duplicates_naive_names_and_non_policies():
    with pytest.raises(ValueError, match="already registered"):
        register_policy(LevelPack())

    class Fifo:
        name = "fifo"
        randomized = False

        def priorities(self, ctx):
            return {}

        def assign_executor(self, ctx, op, free):
            return None

    with pytest.raises(ValueError, match="reserved"):
        register_policy(Fifo())
    with pytest.raises(TypeError):
        register_policy(object())


def test_register_replace_and_unregister_roundtrip():
    class Custom:
        name = "test-custom"
        randomized = False

        def priorities(self, ctx):
            return {n: 0.0 for n in ctx.graph.names}

        def assign_executor(self, ctx, op, free):
            return None

    try:
        register_policy(Custom())
        assert "test-custom" in list_policies()
        register_policy(Custom(), replace=True)   # shadowing is explicit
    finally:
        unregister_policy("test-custom")
    assert "test-custom" not in list_policies()


def test_adhoc_policy_instance_passes_through_without_registration():
    class Reversed:
        name = "reversed-ids"
        randomized = False

        def priorities(self, ctx):
            return {n: float(i) for i, n in enumerate(ctx.graph.names)}

        def assign_executor(self, ctx, op, free):
            return None

    g = random_dag(3)
    sched = make_schedule(g, KNL7250, n_executors=3, team_size=8,
                          policy=Reversed())
    sched.validate(g)
    assert sched.policy == "reversed-ids"


def test_perturbed_cpf_validates_epsilon():
    with pytest.raises(ValueError, match="epsilon"):
        PerturbedCPF(epsilon=1.5)


# ---------------------------------------------------------------------------
# assignment hook + determinism (satellite 1)
# ---------------------------------------------------------------------------

def test_level_pack_hook_steers_ops_to_wave_positions():
    # two independent chains: a0->a1->a2, b0->b1->b2.  Wave position pins
    # chain a to executor 0 and chain b to executor 1 throughout.
    g = Graph("chains")
    for c in ("a", "b"):
        for i in range(3):
            g.add(OpNode(f"{c}{i}", kind="gemm", flops=1e6, bytes_in=1e3,
                         bytes_out=1e3,
                         deps=(f"{c}{i - 1}",) if i else ()))
    sched = make_schedule(g, KNL7250, n_executors=2, team_size=8,
                          policy="level-pack")
    execs_a = {sched.placements[f"a{i}"][0] for i in range(3)}
    execs_b = {sched.placements[f"b{i}"][0] for i in range(3)}
    assert len(execs_a) == 1 and len(execs_b) == 1
    assert execs_a != execs_b


def test_assignment_hook_none_keeps_default_placement():
    g = random_dag(5)
    a = make_schedule(g, KNL7250, n_executors=3, team_size=8, policy="cpf")
    ctx_free: list = []

    class Passive:
        name = "passive"
        randomized = False

        def priorities(self, ctx):
            return ctx.levels

        def assign_executor(self, ctx, op, free):
            ctx_free.append(free)
            return None

    b = make_schedule(g, KNL7250, n_executors=3, team_size=8, policy=Passive())
    assert a.placements == b.placements   # None defers to engine placement
    assert ctx_free and all(f == tuple(sorted(f)) for f in ctx_free)


@pytest.mark.parametrize("policy", ["cpf", "level-pack", "lpt", "cpf-perturb"])
def test_simulation_start_order_is_reproducible(policy):
    """Satellite 1: equal-priority ready ops pop in stable node-id order —
    two simulations of one graph give identical traces."""
    g = random_dag(11, tie_costs=True)   # identical costs => heavy ties
    cfg = SimConfig(n_executors=4, team_size=8, policy=policy)
    a = simulate(g, KNL7250, cfg, seed=7)
    b = simulate(g, KNL7250, cfg, seed=7)
    assert a.start_order() == b.start_order()
    assert [(e.op, e.executor, e.start) for e in a.trace] == \
           [(e.op, e.executor, e.start) for e in b.trace]


def test_perturbed_cpf_replays_by_seed():
    g = random_dag(13)
    mk = lambda seed: make_schedule(g, KNL7250, n_executors=4, team_size=8,
                                    policy="cpf-perturb", seed=seed)
    assert mk(3).placements == mk(3).placements
    assert mk(3).seed == 3
    # different seeds draw different priorities (the restart mechanism);
    # makespans may coincide but the noise sequences must not be identical
    ctx = PolicyContext(graph=g, costs={n: 1.0 for n in g.names},
                        levels={n: 1.0 for n in g.names}, depths={},
                        n_executors=4, seed=0)
    ctx2 = PolicyContext(graph=g, costs=ctx.costs, levels=ctx.levels,
                         depths={}, n_executors=4, seed=1)
    pol = get_policy("cpf-perturb")
    assert pol.priorities(ctx) != pol.priorities(ctx2)


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

def test_search_winner_never_worse_than_cpf_and_covers_all_policies():
    for seed in range(6):
        g = random_dag(seed)
        res = search_schedule(g, KNL7250, n_executors=4, team_size=8)
        assert res.makespan_sim <= res.cpf_makespan + 1e-12
        assert res.runner_up_gap >= 0.0
        assert set(res.by_policy()) == set(list_policies())
        assert res.record() == {
            "policy": res.policy, "seed": res.seed,
            "makespan_sim": res.makespan_sim,
            "runner_up_gap": res.runner_up_gap,
        }
        # the winner replays exactly from its (policy, seed) record
        replay = make_schedule(g, KNL7250, n_executors=4, team_size=8,
                               policy=res.policy, seed=res.seed)
        assert replay.placements == res.schedule.placements


def test_search_ties_prefer_cpf():
    # a pure chain: every policy produces the same (only) schedule, so the
    # tie must resolve to the first candidate — CPF
    g = Graph("chain")
    for i in range(5):
        g.add(OpNode(f"c{i}", kind="gemm", flops=1e6, bytes_in=1e3,
                     bytes_out=1e3, deps=(f"c{i - 1}",) if i else ()))
    res = search_schedule(g, KNL7250, n_executors=2, team_size=8)
    assert res.policy == "cpf"
    assert res.runner_up_gap == 0.0


def test_search_winner_passes_schedule_rules():
    for seed in (1, 4, 9):
        g = random_dag(seed)
        res = search_schedule(g, KNL7250, n_executors=3, team_size=8)
        rep = check_schedule(res.schedule, g)
        assert rep.ok, rep.render()


def test_search_respects_restricted_candidates_and_restarts():
    g = random_dag(2)
    res = search_schedule(g, KNL7250, n_executors=4, team_size=8,
                          policies=["lpt"], n_restarts=1)
    assert res.policy == "lpt"
    assert len(res.candidates) == 1
    res2 = search_schedule(g, KNL7250, n_executors=4, team_size=8,
                           policies=["cpf-perturb"], n_restarts=5)
    assert len(res2.candidates) == 5
    assert [c.seed for c in res2.candidates] == [0, 1, 2, 3, 4]
    with pytest.raises(ValueError, match="n_restarts"):
        search_schedule(g, KNL7250, n_executors=4, team_size=8, n_restarts=0)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 6))
def test_property_every_policy_feasible_and_winner_beats_cpf(seed, n_exec):
    """Satellite 3: on random DAGs every registered policy's schedule passes
    the repro.checks S-rules, and the searched winner <= CPF."""
    g = random_dag(seed)
    for name in list_policies():
        sched = make_schedule(g, KNL7250, n_executors=n_exec, team_size=8,
                              policy=name)
        rep = check_schedule(sched, g)
        assert rep.ok, f"{name}: {rep.render()}"
    res = search_schedule(g, KNL7250, n_executors=n_exec, team_size=8,
                          n_restarts=3)
    assert res.makespan_sim <= res.cpf_makespan + 1e-12


# ---------------------------------------------------------------------------
# store format 2 (satellite 2)
# ---------------------------------------------------------------------------

def test_store_loads_checked_in_format1_fixture(tmp_path):
    fixture = os.path.join(FIXTURE_DIR, "calibration_format1.json")
    store = CalibrationStore()    # no path: the checked-in fixture stays 1
    store.load(fixture)
    sig = "1111aaaa2222bbbb3333cccc4444dddd5555eeee6666ffff7777000088889999"
    assert store.get(sig) == {"l0w0": 0.00013, "l0w1": 0.00027, "out": 4.2e-05}
    assert len(store) == 2
    # round trip: rewrite as format 3, costs intact, schedules now storable
    out = str(tmp_path / "migrated.json")
    store.put_schedule(sig, "4x8|analytic",
                       {"policy": "lpt", "seed": 0, "makespan_sim": 1e-3,
                        "runner_up_gap": 0.02})
    store.save(out)
    payload = json.loads(open(out).read())
    assert payload["format"] == 3
    fresh = CalibrationStore(out)
    assert fresh.get(sig) == store.get(sig)
    assert fresh.get_schedule(sig, "4x8|analytic")["policy"] == "lpt"


def test_store_schedule_sections_round_trip(tmp_path):
    path = str(tmp_path / "cal.json")
    store = CalibrationStore(path)
    store.put("sig-x", {"op": 1e-3})
    rec = {"policy": "cpf-perturb", "seed": 4,
           "makespan_sim": 2.5e-4, "runner_up_gap": 0.01}
    store.put_schedule("sig-x", "8x4|deadbeef00112233", rec)
    store.put_schedule("sig-y", "2x2|analytic", {"policy": "cpf", "seed": 0,
                                                 "makespan_sim": 1.0,
                                                 "runner_up_gap": 0.0})
    fresh = CalibrationStore(path)
    assert fresh.get_schedule("sig-x", "8x4|deadbeef00112233") == rec
    assert fresh.get_schedule("sig-x", "other-config") is None
    assert fresh.get_schedule("sig-y", "2x2|analytic")["policy"] == "cpf"
    # schedule-only signatures don't fabricate cost tables
    assert fresh.get("sig-y") is None
    assert fresh.get("sig-x") == {"op": 1e-3}


def test_store_loads_checked_in_format2_fixture(tmp_path):
    """Format-2 files (pre-hwperf: no interference section) migrate
    losslessly — costs and searched schedules preserved, interference
    section empty — and rewrite as format 3 (ISSUE 10 satellite)."""
    fixture = os.path.join(FIXTURE_DIR, "calibration_format2.json")
    store = CalibrationStore()    # no path: the checked-in fixture stays 2
    store.load(fixture)
    sig = "1111aaaa2222bbbb3333cccc4444dddd5555eeee6666ffff7777000088889999"
    sig2 = "abcdef0123456789abcdef0123456789abcdef0123456789abcdef0123456789"
    assert store.get(sig) == {"l0w0": 0.00013, "l0w1": 0.00027, "out": 4.2e-05}
    assert store.get(sig2) == {"gemm0": 0.0031, "gemm1": 0.0029}
    assert store.get_schedule(sig, "4x8|analytic")["policy"] == "lpt"
    # the section format 2 never had starts empty, not fabricated
    assert store.get_interference() is None
    out = str(tmp_path / "migrated.json")
    store.save(out)
    payload = json.loads(open(out).read())
    assert payload["format"] == 3
    assert payload["interference"] == {}
    fresh = CalibrationStore(out)
    assert fresh.get(sig) == store.get(sig)
    assert fresh.get(sig2) == store.get(sig2)
    assert fresh.get_schedule(sig, "4x8|analytic") == \
        store.get_schedule(sig, "4x8|analytic")
    assert fresh.get_interference() is None


def test_store_interference_section_round_trip(tmp_path):
    path = str(tmp_path / "cal.json")
    store = CalibrationStore(path)
    section = {"solo": {"gemm": 1e-3}, "pairs": {"gemm|gemm": 1.4},
               "hot_threshold": 1.25, "pinned": True}
    store.put_interference(section)
    fresh = CalibrationStore(path)
    assert fresh.get_interference() == section
    # replacement is wholesale: two measurement runs must not interleave
    store.put_interference({"solo": {}, "pairs": {}, "hot_threshold": 1.1,
                            "pinned": False})
    assert CalibrationStore(path).get_interference()["pinned"] is False


def test_store_unknown_future_format_names_the_file(tmp_path):
    p = tmp_path / "future.json"
    p.write_text(json.dumps({"format": 99, "entries": {}}))
    with pytest.raises(ValueError, match="future.json"):
        CalibrationStore(str(p))


# ---------------------------------------------------------------------------
# api knob + store-hit replay (acceptance criterion 4)
# ---------------------------------------------------------------------------

def test_schedule_search_knob_validated():
    with Runtime(n_workers=2) as rt:
        with pytest.raises(ValueError, match="schedule_search"):
            rt.compile(layered_graph(3, 2), backend="sim",
                       schedule_search="bogus")


def test_auto_searches_only_once_calibrated(monkeypatch):
    g = layered_graph(3, 2)
    with Runtime(n_workers=2) as rt:
        exe = rt.compile(g, backend="sim", n_executors=2, team_size=4)
        # analytic costs, auto mode: no search
        monkeypatch.setattr(
            "repro.api.search_schedule",
            lambda *a, **k: pytest.fail("searched on analytic costs"))
        assert not exe.search_active
        assert exe.schedule.policy == "cpf"
        monkeypatch.undo()
        # a measured table flips auto on
        costs = dict(exe.schedule.op_costs)
        exe.profile_with(measured_costs=lambda _team: costs)
        assert exe.search_active
        sched = exe.schedule
        assert sched.policy in list_policies()
        assert "schedule search: winner=" in exe.describe()


def test_off_never_searches_force_always_does(monkeypatch):
    g = layered_graph(3, 2)
    with Runtime(n_workers=2) as rt:
        exe = rt.compile(g, backend="sim", n_executors=2, team_size=4,
                         schedule_search="off")
        costs = dict(exe.schedule.op_costs)
        exe.profile_with(measured_costs=lambda _team: costs)
        monkeypatch.setattr(
            "repro.api.search_schedule",
            lambda *a, **k: pytest.fail("schedule_search='off' searched"))
        assert exe.schedule.policy == "cpf"
        monkeypatch.undo()
        exe2 = rt.compile(g, backend="sim", n_executors=2, team_size=4,
                          schedule_search="force")
        called = []
        real = search_schedule
        monkeypatch.setattr(
            "repro.api.search_schedule",
            lambda *a, **k: called.append(1) or real(*a, **k))
        exe2.schedule
        assert called   # force searches even on analytic costs


def test_second_compile_replays_stored_winner_without_search(tmp_path, monkeypatch):
    """Acceptance: a second compile() of the same graph signature replays
    the persisted winner without re-running the search (PR 5 pattern)."""
    g = layered_graph(4, 3)
    path = str(tmp_path / "cal.json")
    with Runtime(n_workers=2, calibration_path=path) as rt1:
        exe = rt1.compile(layered_graph(4, 3), backend="sim",
                          n_executors=3, team_size=4, schedule_search="force")
        sched1 = exe.schedule
        placements = dict(sched1.placements)
        assert exe._search is not None          # a live search ran

    monkeypatch.setattr(
        "repro.api.search_schedule",
        lambda *a, **k: pytest.fail("second compile re-ran the search"))
    with Runtime(n_workers=2, calibration_path=path) as rt2:
        exe2 = rt2.compile(layered_graph(4, 3), backend="sim",
                           n_executors=3, team_size=4, schedule_search="force")
        sched2 = exe2.schedule                  # replayed from the store
        assert exe2._search is None
        assert exe2._search_hit is not None
        assert sched2.policy == sched1.policy
        assert sched2.seed == sched1.seed
        assert dict(sched2.placements) == placements
        assert "replayed from store" in exe2.describe()


def test_stored_winner_with_unknown_policy_falls_back_to_search(tmp_path):
    g = layered_graph(3, 2)
    path = str(tmp_path / "cal.json")
    with Runtime(n_workers=2, calibration_path=path) as rt:
        exe = rt.compile(g, backend="sim", n_executors=2, team_size=4,
                         schedule_search="force")
        exe.schedule
        sig = exe.signature
        ck = next(iter(rt.calibration._schedules[sig]))
        rt.calibration.put_schedule(
            sig, ck, {"policy": "retired-policy", "seed": 0,
                      "makespan_sim": 1.0, "runner_up_gap": 0.0})
    with Runtime(n_workers=2, calibration_path=path) as rt2:
        exe2 = rt2.compile(g, backend="sim", n_executors=2, team_size=4,
                           schedule_search="force")
        sched = exe2.schedule                   # re-searched, not an error
        assert sched.policy in list_policies()
        assert exe2._search is not None
