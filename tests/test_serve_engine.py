"""Wave-batching serving engine: batching-invariance, stop conditions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import transformer
from repro.serve.engine import Request, ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def model():
    cfg = get_config("gemma-2b", smoke=True)
    params = transformer.init_params(cfg, jax.random.key(3))
    return cfg, params


def _reference_decode(cfg, params, prompt, n_new):
    """Unbatched greedy reference."""
    cache = transformer.init_cache(cfg, 1, len(prompt) + n_new + 1)
    logits, cache = transformer.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt)[None]}, cache)
    out = []
    for _ in range(n_new):
        t = int(jnp.argmax(logits, -1)[0])
        out.append(t)
        logits, cache = transformer.decode_step(
            cfg, params, jnp.asarray([[t]], jnp.int32), cache)
    return out


def test_batched_equals_unbatched_same_lengths(model):
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=12).astype(np.int32) for _ in range(3)]
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=3, max_len=48))
    for i, pr in enumerate(prompts):
        eng.submit(Request(request_id=i, prompt=pr, max_new_tokens=6))
    done = eng.run()
    for r in done:
        ref = _reference_decode(cfg, params, r.prompt, 6)
        assert r.output == ref, (r.request_id, r.output, ref)


def test_mixed_lengths_wave_left_padding(model):
    cfg, params = model
    rng = np.random.default_rng(1)
    lens = [5, 11, 17]
    prompts = [rng.integers(1, cfg.vocab_size, size=l).astype(np.int32) for l in lens]
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=3, max_len=64))
    for i, pr in enumerate(prompts):
        eng.submit(Request(request_id=i, prompt=pr, max_new_tokens=4))
    done = eng.run()
    for r in done:
        ref = _reference_decode(cfg, params, r.prompt, 4)
        assert r.output == ref, (len(r.prompt), r.output, ref)


def test_eos_stops_early(model):
    cfg, params = model
    rng = np.random.default_rng(2)
    pr = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
    ref = _reference_decode(cfg, params, pr, 8)
    eos = ref[2]  # force a stop at the 3rd emitted token
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=1, max_len=32))
    eng.submit(Request(request_id=0, prompt=pr, max_new_tokens=8, eos_id=eos))
    (r,) = eng.run()
    assert r.done and r.output[-1] == eos and len(r.output) <= 3 + ref[:3].count(eos)


def test_budget_respected_and_queue_drains(model):
    cfg, params = model
    rng = np.random.default_rng(3)
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=2, max_len=40))
    for i in range(5):  # 5 requests, waves of 2
        eng.submit(Request(request_id=i,
                           prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 3 and r.done for r in done)
    assert not eng.queue
