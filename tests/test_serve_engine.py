"""Wave-batching serving engine: batching-invariance, stop conditions,
pad-vocab sampling mask, submit-order contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import transformer
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.step import mask_pad_vocab


@pytest.fixture(scope="module")
def model():
    cfg = get_config("gemma-2b", smoke=True)
    params = transformer.init_params(cfg, jax.random.key(3))
    return cfg, params


def _reference_decode(cfg, params, prompt, n_new):
    """Unbatched greedy reference."""
    cache = transformer.init_cache(cfg, 1, len(prompt) + n_new + 1)
    logits, cache = transformer.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt)[None]}, cache)
    out = []
    for _ in range(n_new):
        t = int(jnp.argmax(logits, -1)[0])
        out.append(t)
        logits, cache = transformer.decode_step(
            cfg, params, jnp.asarray([[t]], jnp.int32), cache)
    return out


def test_batched_equals_unbatched_same_lengths(model):
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=12).astype(np.int32) for _ in range(3)]
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=3, max_len=48))
    for i, pr in enumerate(prompts):
        eng.submit(Request(request_id=i, prompt=pr, max_new_tokens=6))
    done = eng.run()
    for r in done:
        ref = _reference_decode(cfg, params, r.prompt, 6)
        assert r.output == ref, (r.request_id, r.output, ref)


def test_mixed_lengths_wave_left_padding(model):
    cfg, params = model
    rng = np.random.default_rng(1)
    lens = [5, 11, 17]
    prompts = [rng.integers(1, cfg.vocab_size, size=l).astype(np.int32) for l in lens]
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=3, max_len=64))
    for i, pr in enumerate(prompts):
        eng.submit(Request(request_id=i, prompt=pr, max_new_tokens=4))
    done = eng.run()
    for r in done:
        ref = _reference_decode(cfg, params, r.prompt, 4)
        assert r.output == ref, (len(r.prompt), r.output, ref)


def test_eos_stops_early(model):
    cfg, params = model
    rng = np.random.default_rng(2)
    pr = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
    ref = _reference_decode(cfg, params, pr, 8)
    eos = ref[2]  # force a stop at the 3rd emitted token
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=1, max_len=32))
    eng.submit(Request(request_id=0, prompt=pr, max_new_tokens=8, eos_id=eos))
    (r,) = eng.run()
    assert r.done and r.output[-1] == eos and len(r.output) <= 3 + ref[:3].count(eos)


def test_run_returns_true_submit_order(model):
    """Docstring promises submit order — request_ids need not be monotone."""
    cfg, params = model
    rng = np.random.default_rng(5)
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=2, max_len=48))
    ids, lens = [7, 2, 9], [12, 5, 12]   # mixed lengths: bucketing reorders
    for rid, ln in zip(ids, lens):
        eng.submit(Request(request_id=rid,
                           prompt=rng.integers(1, cfg.vocab_size, size=ln).astype(np.int32),
                           max_new_tokens=3))
    done = eng.run()
    assert [r.request_id for r in done] == ids


def test_submit_over_budget_raises_valueerror(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=1, max_len=16))
    req = Request(request_id=0, prompt=np.ones(10, np.int32), max_new_tokens=10)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(req)


# ---------------------------------------------------------------------------
# pad-vocab regression: padded_vocab > vocab_size carries random weight
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def padded_model():
    cfg = get_config("gemma-2b", smoke=True).reduced(vocab_size=260)
    assert cfg.padded_vocab > cfg.vocab_size     # 260 -> 512: 252 junk columns
    params = transformer.init_params(cfg, jax.random.key(7))
    return cfg, params


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_emitted_ids_stay_in_vocab(padded_model, temperature):
    """Greedy and temperature sampling must never emit ids >= vocab_size,
    even though ~half the unembedding columns are pad junk."""
    cfg, params = padded_model
    rng = np.random.default_rng(11)
    eng = ServeEngine(cfg, params,
                      ServeConfig(max_batch=3, max_len=32, temperature=temperature))
    for i in range(3):
        eng.submit(Request(request_id=i,
                           prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                           max_new_tokens=12))
    done = eng.run()
    emitted = [t for r in done for t in r.output]
    assert emitted and all(0 <= t < cfg.vocab_size for t in emitted), emitted


def test_greedy_matches_masked_reference(padded_model):
    """The mask must only remove pad columns — in-vocab argmax is untouched."""
    cfg, params = padded_model
    rng = np.random.default_rng(12)
    pr = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
    cache = transformer.init_cache(cfg, 1, 24)
    logits, cache = transformer.prefill(cfg, params, {"tokens": jnp.asarray(pr)[None]}, cache)
    ref = []
    for _ in range(4):
        t = int(jnp.argmax(mask_pad_vocab(logits, cfg.vocab_size), -1)[0])
        ref.append(t)
        logits, cache = transformer.decode_step(cfg, params, jnp.asarray([[t]], jnp.int32), cache)
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=1, max_len=24))
    eng.submit(Request(request_id=0, prompt=pr, max_new_tokens=4))
    (r,) = eng.run()
    assert r.output == ref


def test_budget_respected_and_queue_drains(model):
    cfg, params = model
    rng = np.random.default_rng(3)
    eng = ServeEngine(cfg, params, ServeConfig(max_batch=2, max_len=40))
    for i in range(5):  # 5 requests, waves of 2
        eng.submit(Request(request_id=i,
                           prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 3 and r.done for r in done)
    assert not eng.queue
