"""Paper-network graph builders: structure, sizes, wavefront metadata."""
import pytest

from repro.core import KNL7250, simulate, SimConfig
from repro.models.paper_nets import (
    PAPER_NETS,
    PAPER_SIZES,
    googlenet_forward_graph,
    lstm_forward_graph,
    paper_graph,
    pathnet_forward_graph,
    training_graph,
)


@pytest.mark.parametrize("net", PAPER_NETS)
@pytest.mark.parametrize("size", ["small", "medium", "large"])
def test_graphs_valid_dags(net, size):
    g = paper_graph(net, size)
    g.validate()
    assert g.total_flops() > 0


def test_lstm_structure():
    g = lstm_forward_graph("small")
    T, H = PAPER_SIZES["lstm"]["small"]
    # per cell: gx, gh, ew; plus inputs, concat, softmax, loss
    assert len(g) == T + 4 * T * 3 + 3
    # recurrent dep: gh(l,t) depends on ew(l,t-1)
    assert "ew_L0_T0" in g["gh_L0_T1"].deps
    # stacking dep: gx(l,t) on ew(l-1,t)
    assert "ew_L0_T0" in g["gx_L1_T0"].deps


def test_phased_adds_time_gates():
    g = lstm_forward_graph("small", phased=True)
    assert "kgate_L0_T0" in g
    assert "kgate_L0_T0" in g["gh_L0_T1"].deps


def test_pathnet_six_parallel_modules():
    g = pathnet_forward_graph("small")
    assert g.width() >= 6
    aggs = [n for n in g.names if n.startswith("agg_")]
    assert len(aggs) == 3
    assert len(g["agg_L0"].deps) == 6


def test_googlenet_inception_branches():
    g = googlenet_forward_graph("small")
    cat = g["i3a_concat"]
    assert len(cat.deps) == 4  # 1x1 | 3x3 | 5x5 | pool-proj
    # width multiplier scales flops ~w^2 on inception convs
    g1 = googlenet_forward_graph("small")
    g4 = googlenet_forward_graph("large")
    assert g4["i3a_3x3"].flops > 10 * g1["i3a_3x3"].flops


def test_training_graph_mirrors_and_doubles_width():
    fwd = pathnet_forward_graph("small")
    tg = training_graph(fwd)
    assert len(tg) > 2 * len(fwd) - 10
    # backward deps reverse the forward edge conv -> relu
    assert "d_relu_L0_M0" in tg["d_conv_L0_M0"].deps
    # backward node also needs its forward activation
    assert "conv_L0_M0" in tg["d_conv_L0_M0"].deps
    tg.validate()


def test_lstm_cells_carry_diag_metadata_and_cpf_wavefronts():
    g = lstm_forward_graph("small")
    cells = [n for n in g.nodes if "diag" in n.meta]
    assert cells
    res = simulate(g, KNL7250, SimConfig(n_executors=8, team_size=8))
    # CPF recovers the diagonal macroscopically: mean start time per
    # anti-diagonal is strictly increasing (op-level pipelining may overlap
    # adjacent diagonals, so per-op strict ordering is not required)
    starts: dict[int, list[float]] = {}
    for ev in res.trace:
        meta = g[ev.op].meta
        if "diag" in meta:
            starts.setdefault(meta["diag"], []).append(ev.start)
    means = [sum(v) / len(v) for _, v in sorted(starts.items())]
    assert all(a < b for a, b in zip(means, means[1:])), means[:6]


def test_batch_scaling_scales_flops():
    g64 = paper_graph("lstm", "small", batch=64)
    g32 = paper_graph("lstm", "small", batch=32)
    assert g64.total_flops() == pytest.approx(2 * g32.total_flops(), rel=1e-6)
