"""repro.hwperf beyond topology: pinning (and its graceful no-op on
platforms without affinity — ISSUE 10 acceptance criterion), the co-location
harness, the contention model, its CalibrationStore/Runtime integration, and
the ``cpf-contention`` placement policy."""
import os
import warnings

import pytest

from repro.core import KNL7250, Graph, SimConfig, simulate
from repro.core.engine import ExecutorPool
from repro.core.policies import get_policy, list_policies, unregister_policy
from repro.hwperf import (
    NO_AFFINITY_ENV,
    ContentionModel,
    InterferenceMatrix,
    Workload,
    affinity_supported,
    classify,
    default_workloads,
    install_contention_policy,
    measure_interference,
    pin_current_thread,
    pin_pool,
    plan_pinning,
    synthetic_topology,
)
from repro.hwperf import pinning as hwpin
from repro.hwperf.model import ContentionAwareCPF


@pytest.fixture(autouse=True)
def _fresh_warning_state():
    """Each test sees the one-shot pinning warning as if freshly imported."""
    hwpin._reset_warning_for_tests()
    yield
    hwpin._reset_warning_for_tests()


@pytest.fixture
def no_affinity(monkeypatch):
    """Simulate a platform without sched_setaffinity (the CI smoke leg)."""
    monkeypatch.setenv(NO_AFFINITY_ENV, "1")


def _cleanup_policy(name="cpf-contention"):
    if name in list_policies():
        unregister_policy(name)


# ---------------------------------------------------------------------------
# pinning plans
# ---------------------------------------------------------------------------

def test_plan_pinning_disjoint_on_big_topology():
    plan = plan_pinning(4, synthetic_topology(8))
    assert plan.n_executors == 4
    assert plan.disjoint
    assert all(len(c) == 2 for c in plan.assignments)
    assert "disjoint=True" in plan.describe()


def test_plan_pinning_oversubscribed_overlaps():
    plan = plan_pinning(4, synthetic_topology(2))
    assert plan.n_executors == 4
    assert not plan.disjoint
    assert plan.cpus_for(0) == plan.cpus_for(2)   # round-robin wrap
    assert plan.cpus_for(5) == plan.cpus_for(1)   # cpus_for itself wraps


def test_affinity_disabled_by_env(no_affinity):
    assert not affinity_supported()


# ---------------------------------------------------------------------------
# graceful degradation: unpinned no-op with a single warning (acceptance)
# ---------------------------------------------------------------------------

def test_pin_pool_without_affinity_is_noop_with_single_warning(no_affinity):
    plan = plan_pinning(2, synthetic_topology(2))
    pool = ExecutorPool(2)
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            first = pin_pool(pool, plan)
            second = pin_pool(pool, plan)     # re-pin (serve re-lease path)
            assert not pin_current_thread((0,))
        assert not first.pinned and not second.pinned
        runtime_warnings = [x for x in w
                            if issubclass(x.category, RuntimeWarning)]
        assert len(runtime_warnings) == 1, \
            f"expected exactly one warning, got {len(runtime_warnings)}"
        assert "unpinned" in str(runtime_warnings[0].message) or \
            "OS-scheduled" in str(runtime_warnings[0].message)
    finally:
        pool.close()


@pytest.mark.skipif(not affinity_supported(),
                    reason="no sched_setaffinity on this platform")
def test_pin_pool_real_threads():
    pool = ExecutorPool(2)
    try:
        plan = plan_pinning(2)                 # detected (restricted) topo
        applied = pin_pool(pool, plan)
        assert applied.pinned
        assert applied.n_threads == 2
        tids = pool.executor_thread_ids()
        for ex, tid in enumerate(tids):
            assert os.sched_getaffinity(tid) == set(plan.cpus_for(ex))
        assert "pinned" in applied.describe()
    finally:
        pool.close()


@pytest.mark.skipif(not affinity_supported(),
                    reason="no sched_setaffinity on this platform")
def test_pin_pool_rolls_back_on_os_rejection(monkeypatch):
    """A mid-plan OS rejection (restricted cpuset) unpins the whole pool —
    half-pinned would crowd every accepted executor onto a core fraction."""
    pool = ExecutorPool(2)
    restored: list[tuple[int, tuple]] = []
    calls = {"n": 0}
    real = hwpin._set_affinity

    def flaky(tid, cpus):
        calls["n"] += 1
        if calls["n"] == 2:                     # second executor rejected
            raise OSError("simulated cpuset rejection")
        restored.append((tid, cpus))
        real(tid, cpus)

    monkeypatch.setattr(hwpin, "_set_affinity", flaky)
    try:
        plan = plan_pinning(2)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            applied = pin_pool(pool, plan)
        assert not applied.pinned
        assert applied.errors
        assert any("OS-scheduled" in str(x.message) for x in w)
        # the first pin was rolled back to the full mask (3rd call)
        assert calls["n"] == 3
        assert restored[-1][1] == tuple(
            sorted(c.cpu for c in plan.topology.cpus))
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# co-location harness
# ---------------------------------------------------------------------------

def _tiny_workloads():
    return default_workloads(scale=32)


def test_measure_interference_smoke():
    m = measure_interference(_tiny_workloads(), synthetic_topology(2),
                             iters=2, repeats=1)
    assert set(m.classes()) == {"gemm", "elementwise", "memory"}
    assert all(v > 0 for v in m.solo.values())
    assert len(m.pair) == 9
    for a in m.classes():
        for b in m.classes():
            assert m.slowdown(a, b) >= 1.0     # clamped at solo


def test_measure_interference_unpinned_mode_recorded():
    m = measure_interference(_tiny_workloads(), synthetic_topology(2),
                             iters=1, repeats=1, pinned=False)
    assert not m.pinned


def test_slowdown_clamps_and_defaults():
    m = InterferenceMatrix(solo={"gemm": 1.0},
                           pair={("gemm", "gemm"): 0.5})
    assert m.slowdown("gemm", "gemm") == 1.0   # noise can't be a speedup
    assert m.slowdown("gemm", "memory") == 1.0  # unmeasured pair
    assert m.slowdown("nope", "gemm") == 1.0    # unknown class


def test_custom_workload_classes_flow_through():
    wl = Workload("custom", lambda: 7, lambda s: s * 2)
    m = measure_interference([wl], synthetic_topology(1), iters=1, repeats=1)
    assert m.classes() == ["custom"]
    assert ("custom", "custom") in m.pair


# ---------------------------------------------------------------------------
# contention model
# ---------------------------------------------------------------------------

def _hot_model():
    return ContentionModel(
        solo={"gemm": 1e-3, "elementwise": 1e-4, "memory": 5e-4},
        pair_slowdown={("gemm", "gemm"): 1.8, ("gemm", "memory"): 1.1,
                       ("memory", "gemm"): 1.4,
                       ("elementwise", "elementwise"): 1.05},
        pinned=True)


def test_classify_kinds():
    g = Graph("k")
    assert classify(g.add_op("a", kind="gemm")) == "gemm"
    assert classify(g.add_op("b", kind="attention")) == "gemm"
    assert classify(g.add_op("c", kind="elementwise")) == "elementwise"
    assert classify(g.add_op("d", kind="input")) == "memory"
    assert classify(g.add_op("e", kind="exotic-new-kind")) == "elementwise"
    assert classify(object()) == "elementwise"  # no .kind at all


def test_model_from_matrix_and_dict_round_trip():
    m = InterferenceMatrix(
        solo={"gemm": 2.0, "memory": 1.0},
        pair={("gemm", "gemm"): 3.0, ("gemm", "memory"): 2.2,
              ("memory", "gemm"): 1.9, ("memory", "memory"): 1.1},
        pinned=True)
    model = ContentionModel.from_matrix(m, hot_threshold=1.3)
    assert model.pair_slowdown[("gemm", "gemm")] == pytest.approx(1.5)
    assert model.pinned
    clone = ContentionModel.from_dict(model.to_dict())
    assert clone.solo == model.solo
    assert clone.pair_slowdown == model.pair_slowdown
    assert clone.hot_threshold == model.hot_threshold
    assert clone.pinned == model.pinned


def test_multiplier_is_worst_pairwise_not_product():
    model = _hot_model()
    # beside both a gemm and a memory op: max(1.8, 1.1), never 1.8 * 1.1
    assert model.multiplier("gemm", ["gemm", "memory"]) == pytest.approx(1.8)
    assert model.multiplier("gemm", []) == 1.0
    assert model.multiplier("unknown", ["gemm"]) == 1.0


def test_pair_cost_takes_worse_direction():
    model = _hot_model()
    assert model.pair_cost("gemm", "memory") == pytest.approx(1.4)
    assert model.pair_cost("memory", "gemm") == pytest.approx(1.4)


def test_hot_classes_threshold():
    model = _hot_model()
    assert model.hot_classes() == {"gemm", "memory"}
    cool = ContentionModel(pair_slowdown={("a", "b"): 1.1})
    assert cool.hot_classes() == set()


# ---------------------------------------------------------------------------
# simulator integration: SimConfig.contention
# ---------------------------------------------------------------------------

def _parallel_gemms(n=4):
    g = Graph("par")
    for i in range(n):
        g.add_op(f"g{i}", kind="gemm", flops=1e9, bytes_in=1e6, bytes_out=1e6)
    return g


def test_simulate_contention_inflates_coresident_ops():
    g = _parallel_gemms(4)
    model = ContentionModel(pair_slowdown={("gemm", "gemm"): 2.0})
    base = simulate(g, KNL7250, SimConfig(n_executors=4, team_size=4))
    slow = simulate(g, KNL7250, SimConfig(n_executors=4, team_size=4,
                                          contention=model))
    assert slow.makespan > base.makespan * 1.5   # co-residents pay ~2x


def test_simulate_contention_no_overlap_no_inflation():
    g = _parallel_gemms(2)
    model = ContentionModel(pair_slowdown={("gemm", "gemm"): 2.0})
    base = simulate(g, KNL7250, SimConfig(n_executors=1, team_size=4))
    seq = simulate(g, KNL7250, SimConfig(n_executors=1, team_size=4,
                                         contention=model))
    # one executor: ops never co-resident, the model must not fire
    assert seq.makespan == pytest.approx(base.makespan)


# ---------------------------------------------------------------------------
# cpf-contention placement policy
# ---------------------------------------------------------------------------

def _mixed_graph():
    g = Graph("mixed")
    for i in range(2):
        g.add_op(f"g{i}", kind="gemm", flops=1e9)
        g.add_op(f"e{i}", kind="elementwise", flops=1e9)
    return g


def test_contention_policy_registers_and_replaces():
    try:
        p1 = install_contention_policy(_hot_model())
        assert get_policy("cpf-contention") is p1
        p2 = install_contention_policy(_hot_model())   # re-measured model
        assert get_policy("cpf-contention") is p2
    finally:
        _cleanup_policy()


def test_contention_policy_degenerates_to_cpf_without_hot_pairs():
    """With a contention-free model the placement hook is CPF exactly —
    same trace, op for op (the bench's never-worsens gate, exact form)."""
    unit = ContentionModel()                    # no measured pairs at all
    policy = ContentionAwareCPF(unit)
    g = _mixed_graph()
    cfg = dict(n_executors=2, team_size=4)
    a = simulate(g, KNL7250, SimConfig(policy="cpf", **cfg))
    b = simulate(g, KNL7250, SimConfig(policy=policy, **cfg))
    assert b.makespan == pytest.approx(a.makespan)
    assert [(e.op, e.executor) for e in b.trace] == \
        [(e.op, e.executor) for e in a.trace]


def test_contention_policy_never_worsens_simulated_makespan():
    model = _hot_model()
    policy = ContentionAwareCPF(model)
    g = _mixed_graph()
    for n in (2, 4):
        cfg = dict(n_executors=n, team_size=2, contention=model)
        base = simulate(g, KNL7250, SimConfig(policy="cpf", **cfg))
        aware = simulate(g, KNL7250, SimConfig(policy=policy, **cfg))
        assert aware.makespan <= base.makespan * (1 + 1e-9)


def test_assign_executor_steers_hot_class_away():
    model = _hot_model()                        # gemm|gemm is hot (1.8)
    policy = ContentionAwareCPF(model)
    g = _mixed_graph()
    from repro.core.policies import PolicyContext

    ctx = PolicyContext(graph=g, costs={}, levels={}, depths={},
                        n_executors=2)
    # executor 0 last ran a gemm; a new gemm must pick executor 1
    ctx.scratch["contention.exec_class"] = {0: "gemm"}
    assert policy.assign_executor(ctx, "g1", (0, 1)) == 1
    # ties (both neutral) break to the lowest executor id
    ctx.scratch["contention.exec_class"] = {}
    ctx.scratch.pop("contention.hot", None)
    assert policy.assign_executor(ctx, "g0", (0, 1)) == 0
    assert policy.assign_executor(ctx, "e0", (1, 0)) == 1  # cool class: FIFO
    assert policy.assign_executor(ctx, "g0", ()) is None


# ---------------------------------------------------------------------------
# Runtime integration
# ---------------------------------------------------------------------------

def test_runtime_rejects_bad_pinning_mode():
    from repro.runtime import Runtime

    with pytest.raises(ValueError, match="pinning"):
        Runtime(2, pinning="sideways")
    rt = Runtime(2)
    try:
        with pytest.raises(ValueError, match="pinning"):
            rt.set_pinning("sideways")
    finally:
        rt.close()


def test_runtime_pinning_auto_is_silent_without_affinity(no_affinity):
    from repro.runtime import Runtime

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with Runtime(2, pinning="auto") as rt:
            rt.pool                              # force lazy pool creation
            assert rt.pinning_applied is None    # auto: silent no-op
    assert not [x for x in w if issubclass(x.category, RuntimeWarning)]


def test_runtime_pinning_on_without_affinity_warns_once_and_executes(
        no_affinity):
    """Acceptance criterion: pinning='on' on a platform without
    sched_setaffinity runs the whole stack unpinned with ONE warning."""
    import jax.numpy as jnp

    from repro import api
    from repro.runtime import Runtime

    def fn(x):
        return jnp.tanh(x @ x).sum()

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with Runtime(2, pinning="on") as rt:
            exe = api.compile(fn, jnp.ones((8, 8), jnp.float32),
                              backend="host", runtime=rt)
            out = exe(jnp.ones((8, 8), jnp.float32))
            assert float(out) == pytest.approx(
                float(fn(jnp.ones((8, 8), jnp.float32))))
            assert rt.pinning_applied is not None
            assert not rt.pinning_applied.pinned
            assert "pinning=on:no-op" in rt.describe()
    runtime_warnings = [x for x in w if issubclass(x.category, RuntimeWarning)]
    assert len(runtime_warnings) == 1


@pytest.mark.skipif(not affinity_supported(),
                    reason="no sched_setaffinity on this platform")
def test_runtime_pinning_on_pins_pool_threads():
    from repro.runtime import Runtime

    with Runtime(2, pinning="on") as rt:
        rt.pool
        assert rt.pinning_applied is not None
        assert rt.pinning_applied.pinned
        assert "pinning=on:pinned" in rt.describe()


def test_compile_pinning_kwarg_threads_to_runtime():
    from repro import api
    from repro.runtime import Runtime

    with Runtime(2) as rt:
        assert rt.pinning == "off"
        g = Graph("p")
        g.add_op("a", flops=1e6)
        api.compile(g, backend="sim", runtime=rt, pinning="auto")
        assert rt.pinning == "auto"


def test_runtime_installs_contention_policy_from_store(tmp_path):
    from repro.runtime import CalibrationStore, Runtime

    path = str(tmp_path / "cal.json")
    CalibrationStore(path).put_interference(_hot_model().to_dict())
    _cleanup_policy()
    try:
        rt = Runtime(2, calibration_path=path)
        try:
            assert "cpf-contention" in list_policies()
            model = rt.contention_model()
            assert model is not None
            assert model.pair_slowdown[("gemm", "gemm")] == pytest.approx(1.8)
            assert rt.contention_model() is model     # cached
        finally:
            rt.close()
    finally:
        _cleanup_policy()


def test_runtime_set_contention_model_persists_and_installs(tmp_path):
    from repro.runtime import CalibrationStore, Runtime

    path = str(tmp_path / "cal.json")
    _cleanup_policy()
    try:
        with Runtime(2, calibration_path=path) as rt:
            assert rt.contention_model() is None
            rt.set_contention_model(_hot_model())
            assert "cpf-contention" in list_policies()
        stored = CalibrationStore(path).get_interference()
        assert stored == _hot_model().to_dict()
    finally:
        _cleanup_policy()
