"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret=True on this
CPU box) asserted allclose against its ref.py pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention, paged_decode_attention
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.lstm_cell import lstm_cell_fused
from repro.kernels.lstm_cell.ref import lstm_cell_ref
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.moe_gmm.ref import moe_gmm_ref
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssm_scan import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def tol_for(dt):
    return dict(rtol=3e-2, atol=3e-2) if dt == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


def allclose(a, b, dt):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), **tol_for(dt)
    )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Skv,Hq,Hkv,hd,causal,window",
    [
        (2, 128, 128, 4, 1, 64, True, None),    # MQA causal
        (1, 256, 256, 8, 2, 32, True, 64),      # GQA sliding window
        (2, 64, 64, 4, 4, 16, False, None),     # MHA bidirectional
        (1, 128, 256, 4, 2, 64, False, None),   # cross-attn (Sq != Skv)
        (1, 192, 192, 2, 1, 128, True, None),   # non-pow2 seq, big head
    ],
)
def test_flash_attention(B, Sq, Skv, Hq, Hkv, hd, causal, window, dt):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, hd), dt)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, hd), dt)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, hd), dt)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    allclose(out, ref, dt)


def test_flash_attention_matches_model_layer():
    """Kernel agrees with the chunked_attention the models actually run."""
    from repro.models.layers import chunked_attention

    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 128, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 2, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    ref = chunked_attention(q, k, v, causal=True, chunk=64, q_chunk=64)
    allclose(out, ref, jnp.float32)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,Hq,Hkv,hd,window,fill",
    [
        (2, 256, 8, 1, 64, None, 200),   # MQA partial cache
        (1, 512, 16, 4, 32, 128, 512),   # GQA ring/window
        (2, 128, 4, 4, 16, None, 60),
    ],
)
def test_decode_attention(B, S, Hq, Hkv, hd, window, fill, dt):
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, Hq, hd), dt)
    kc = jax.random.normal(ks[1], (B, S, Hkv, hd), dt)
    vc = jax.random.normal(ks[2], (B, S, Hkv, hd), dt)
    kv_pos = jnp.where(jnp.arange(S) < fill, jnp.arange(S), -1)
    q_pos = jnp.asarray(fill, jnp.int32)
    out = decode_attention(q, kc, vc, kv_pos, q_pos, window=window,
                           block_k=64, interpret=True)
    ref = decode_attention_ref(q, kc, vc, kv_pos, q_pos, window=window)
    allclose(out, ref, dt)


def test_decode_attention_ring_buffer():
    """Ring-buffer slots (shuffled absolute positions) mask correctly."""
    B, S, H, hd = 1, 64, 4, 32
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    # slot s holds absolute position (s + 17) % 96 — some beyond q_pos
    kv_pos = (jnp.arange(S) + 17) % 96
    q_pos = jnp.asarray(48, jnp.int32)
    out = decode_attention(q, kc, vc, kv_pos, q_pos, window=32, block_k=32, interpret=True)
    ref = decode_attention_ref(q, kc, vc, kv_pos, q_pos, window=32)
    allclose(out, ref, jnp.float32)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------

def _paged_case(key, B, P, ps, n_pt, Hq, Hkv, hd, lens, dt):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, hd), dt)
    kp = jax.random.normal(ks[1], (P, ps, Hkv, hd), dt)
    vp = jax.random.normal(ks[2], (P, ps, Hkv, hd), dt)
    # each row maps ceil(len/ps) distinct pages, rest unmapped
    table = np.full((B, n_pt), -1, np.int32)
    nxt = 0
    for b, n in enumerate(lens):
        for j in range(-(-n // ps)):
            table[b, j] = nxt % P
            nxt += 1
    q_pos = jnp.asarray([n - 1 for n in lens], jnp.int32)
    return q, kp, vp, jnp.asarray(table), q_pos


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,P,ps,n_pt,Hq,Hkv,hd,lens,window",
    [
        (2, 16, 16, 4, 8, 1, 64, (50, 17), None),   # MQA, mixed fill
        (1, 8, 32, 3, 4, 2, 32, (70,), 48),         # GQA sliding window
        (3, 12, 8, 6, 4, 4, 16, (48, 1, 23), None), # MHA, full/empty rows
    ],
)
def test_paged_decode_attention(B, P, ps, n_pt, Hq, Hkv, hd, lens, window, dt):
    q, kp, vp, table, q_pos = _paged_case(
        jax.random.key(10), B, P, ps, n_pt, Hq, Hkv, hd, lens, dt)
    out = paged_decode_attention(q, kp, vp, table, q_pos, window=window,
                                 use_kernel=True, interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, table, q_pos, window=window)
    allclose(out, ref, dt)
    # the jnp fallback path must agree too (it is what captured graphs run)
    jnp_out = paged_decode_attention(q, kp, vp, table, q_pos, window=window,
                                     use_kernel=False)
    allclose(jnp_out, ref, dt)


def test_paged_matches_linear_decode_attention():
    """A paged cache laid out contiguously == the linear-cache kernel."""
    B, S, ps, H, hd = 2, 64, 16, 4, 32
    ks = jax.random.split(jax.random.key(11), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    fill = 50
    kv_pos = jnp.where(jnp.arange(S) < fill, jnp.arange(S), -1)
    q_pos = jnp.asarray(fill - 1, jnp.int32)
    ref = decode_attention_ref(q, kc, vc, kv_pos, q_pos)
    # repack row b's cache as pages b*n_pt + j
    n_pt = S // ps
    kp = kc.reshape(B * n_pt, ps, H, hd)
    vp = vc.reshape(B * n_pt, ps, H, hd)
    table = jnp.arange(B * n_pt, dtype=jnp.int32).reshape(B, n_pt)
    out = paged_decode_attention(q, kp, vp, table,
                                 jnp.full((B,), fill - 1, jnp.int32),
                                 use_kernel=True, interpret=True)
    allclose(out, ref, jnp.float32)


# ---------------------------------------------------------------------------
# lstm cell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,H,bn,bh", [(64, 128, 32, 64), (32, 256, 32, 128), (128, 64, 64, 64)])
def test_lstm_cell(N, H, bn, bh, dt):
    ks = jax.random.split(jax.random.key(4), 4)
    gx = jax.random.normal(ks[0], (N, 4 * H), dt)
    gh = jax.random.normal(ks[1], (N, 4 * H), dt)
    b = jax.random.normal(ks[2], (4 * H,), dt)
    c = jax.random.normal(ks[3], (N, H), dt)
    h1, c1 = lstm_cell_fused(gx, gh, b, c, block_n=bn, block_h=bh, interpret=True)
    h2, c2 = lstm_cell_ref(gx, gh, b, c)
    allclose(h1, h2, dt)
    allclose(c1, c2, dt)


def test_lstm_cell_matches_wavefront_cell():
    """Kernel math == core.wavefront.lstm_cell (the scheduling demo's cell)."""
    from repro.core.wavefront import lstm_cell

    ks = jax.random.split(jax.random.key(5), 5)
    B, D, H = 8, 32, 32
    params = {
        "Wx": jax.random.normal(ks[0], (D, 4 * H)) * 0.1,
        "Wh": jax.random.normal(ks[1], (H, 4 * H)) * 0.1,
        "b": jax.random.normal(ks[2], (4 * H,)) * 0.1,
    }
    x = jax.random.normal(ks[3], (B, D))
    h = jax.random.normal(ks[4], (B, H))
    c = jnp.zeros((B, H))
    h_ref, c_ref = lstm_cell(params, x, h, c)
    h_k, c_k = lstm_cell_fused(
        x @ params["Wx"], h @ params["Wh"], params["b"], c,
        block_n=8, block_h=32, interpret=True,
    )
    allclose(h_k, h_ref, jnp.float32)
    allclose(c_k, c_ref, jnp.float32)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,D,St,bd,bs", [(2, 128, 64, 8, 32, 32), (1, 256, 32, 16, 32, 64)])
def test_ssm_scan(B, S, D, St, bd, bs):
    ks = jax.random.split(jax.random.key(6), 3)
    a = jax.random.uniform(ks[0], (B, S, D, St), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(ks[1], (B, S, D, St), jnp.float32) * 0.1
    c = jax.random.normal(ks[2], (B, S, St), jnp.float32)
    y1, h1 = ssm_scan(a, b, c, block_d=bd, block_s=bs, interpret=True)
    y2, h2 = ssm_scan_ref(a, b, c, jnp.zeros((B, D, St), jnp.float32))
    allclose(y1, y2, jnp.float32)
    allclose(h1, h2, jnp.float32)


def test_ssm_scan_state_carries_across_chunks():
    """Decay ~1 makes early inputs visible at the end — catches chunk-reset bugs."""
    B, S, D, St = 1, 128, 8, 4
    a = jnp.full((B, S, D, St), 0.999, jnp.float32)
    b = jnp.zeros((B, S, D, St)).at[:, 0].set(1.0)
    c = jnp.ones((B, S, St), jnp.float32)
    y, h_last = ssm_scan(a, b, c, block_d=8, block_s=16, interpret=True)
    # h at t decays as 0.999^t; y_t = sum_s h_t
    expect = St * 0.999 ** (S - 1)
    np.testing.assert_allclose(float(y[0, -1, 0]), expect, rtol=1e-4)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,R,br,bs", [(2, 256, 128, 64, 64), (1, 128, 64, 64, 32)])
def test_rglru_scan(B, S, R, br, bs):
    ks = jax.random.split(jax.random.key(7), 2)
    a = jax.random.uniform(ks[0], (B, S, R), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(ks[1], (B, S, R), jnp.float32) * 0.1
    hs1, hl1 = rglru_scan(a, b, block_r=br, block_s=bs, interpret=True)
    hs2, hl2 = rglru_scan_ref(a, b, jnp.zeros((B, R), jnp.float32))
    allclose(hs1, hs2, jnp.float32)
    allclose(hl1, hl2, jnp.float32)


def test_rglru_matches_model_recurrence():
    """Kernel == the chunked pure-jnp recurrence the models run."""
    from repro.models.layers import linear_recurrence_chunked

    ks = jax.random.split(jax.random.key(8), 2)
    B, S, R = 2, 128, 64
    a = jax.random.uniform(ks[0], (B, S, R), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(ks[1], (B, S, R), jnp.float32) * 0.1
    hs_k, hl_k = rglru_scan(a, b, block_r=64, block_s=32, interpret=True)
    hs_m, hl_m = linear_recurrence_chunked(a, b, jnp.zeros((B, R), jnp.float32), chunk=64)
    allclose(hs_k, hs_m, jnp.float32)
    allclose(hl_k, hl_m, jnp.float32)


# ---------------------------------------------------------------------------
# moe gmm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [(4, 64, 128, 96), (8, 32, 64, 64), (2, 128, 32, 128)])
def test_moe_gmm(E, C, D, F, dt):
    ks = jax.random.split(jax.random.key(9), 2)
    x = jax.random.normal(ks[0], (E, C, D), dt)
    w = jax.random.normal(ks[1], (E, D, F), dt) * (1.0 / np.sqrt(D))
    o1 = moe_gmm(x, w, block_c=32, block_f=32, block_d=32, interpret=True)
    o2 = moe_gmm_ref(x, w)
    allclose(o1, o2, dt)
