"""The redesigned public API: ``repro.api.compile`` / ``Executable`` over
the process-wide :class:`repro.Runtime`, and the HostScheduler dispatch
redesign (multi-completion drain + honored ``buffer_depth``).
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import api as graphi
from repro.core import KNL7250, Graph, HostScheduler, SimResult


def stat_diamond() -> Graph:
    g = Graph("stat")
    g.add_op("a", flops=1e9)
    g.add_op("b", flops=2e9, deps=("a",))
    g.add_op("c", flops=3e9, deps=("a",))
    g.add_op("d", flops=4e9, deps=("b", "c"))
    return g


def fn_branches(x, w):
    ys = [jnp.tanh(x @ (w * (0.1 * (i + 1)))) for i in range(4)]
    return jnp.sum(sum(ys) ** 2)


def _xw(n=32):
    rng = np.random.default_rng(0)
    return (jnp.asarray(rng.normal(size=(n, n)), jnp.float32),
            jnp.asarray(rng.normal(size=(n, n)), jnp.float32))


# ---------------------------------------------------------------------------
# Executable surface
# ---------------------------------------------------------------------------

def test_compile_graph_artifacts_are_lazy_and_cached():
    exe = graphi.compile(stat_diamond(), hw=KNL7250, backend="sim")
    assert exe._profile is None and exe._schedule is None
    p = exe.profile
    assert exe._profile is p and exe.profile is p          # cached
    sched = exe.schedule
    assert exe.schedule is sched
    sched.validate(exe.graph)
    assert exe.slots and all(exe.slots)


def test_compile_graph_rejects_specs_and_bad_backend():
    with pytest.raises(TypeError):
        graphi.compile(stat_diamond(), jnp.ones(3))
    with pytest.raises(ValueError):
        graphi.compile(stat_diamond(), backend="tpu")


def test_sim_backend_call_returns_simresult():
    exe = graphi.compile(stat_diamond(), hw=KNL7250, backend="sim")
    res = exe()
    assert isinstance(res, SimResult)
    assert res.makespan > 0
    assert exe.last_run is res


def test_pinned_executor_config_skips_search():
    exe = graphi.compile(stat_diamond(), hw=KNL7250, backend="sim",
                         n_executors=2, team_size=8)
    sched = exe.schedule
    assert sched.n_executors == 2 and sched.team_size == 8
    assert exe._profile is None      # pinning avoided the config search


def test_critical_path_property_ends_at_sink():
    exe = graphi.compile(stat_diamond(), hw=KNL7250, backend="sim")
    length, path = exe.critical_path
    assert path[0] == "a" and path[-1] == "d"
    assert length > 0


def test_compiled_fn_host_backend_matches_direct_call():
    x, w = _xw()
    exe = repro.compile(fn_branches, x, w)
    out = exe(x, w)
    assert float(jnp.abs(out - fn_branches(x, w))) < 1e-4
    assert len({e.executor for e in exe.last_run.trace}) >= 2


def test_mesh_backend_executes_static_plan():
    import jax
    from jax.sharding import Mesh

    x, w = _xw(16)
    devs = np.array(jax.devices()[:4]).reshape(4, 1)
    mesh = Mesh(devs, ("data", "model"))
    exe = graphi.compile(fn_branches, x, w, backend="mesh", mesh=mesh,
                         n_executors=4, team_size=2)
    out = exe(x, w)
    assert float(jnp.abs(out - fn_branches(x, w))) < 1e-4
    assert exe.last_plan.n_executors == 4


def test_describe_mentions_config_and_path():
    exe = graphi.compile(stat_diamond(), hw=KNL7250, backend="sim")
    text = exe.describe()
    assert "executors" in text and "critical path" in text


# ---------------------------------------------------------------------------
# GraphiEngine: removed after its PR-2 deprecation cycle
# ---------------------------------------------------------------------------

def test_graphi_engine_shim_is_gone():
    import repro.core
    import repro.core.engine

    with pytest.raises(AttributeError):
        repro.GraphiEngine  # noqa: B018 — the attribute access is the test
    assert not hasattr(repro.core, "GraphiEngine")
    assert not hasattr(repro.core.engine, "GraphiEngine")


# ---------------------------------------------------------------------------
# HostScheduler: buffer_depth honored, completions drained in batches
# ---------------------------------------------------------------------------

def _sources(n, dur=0.0):
    g = Graph("wide")
    for i in range(n):
        g.add_op(f"s{i}", flops=1.0,
                 fn=(lambda i=i: (time.sleep(dur), i)[1]))
    g.add_op("sum", deps=tuple(f"s{i}" for i in range(n)),
             flops=1.0, fn=lambda *xs: sum(xs))
    return g


def test_buffer_depth_queues_ahead():
    g = _sources(3, dur=0.02)
    res = HostScheduler(g, 1, buffer_depth=2).run()
    assert res.outputs["sum"] == 3
    # one executor, three ready sources: depth-2 buffer holds two at once
    assert res.peak_inflight == 2


def test_buffer_depth_one_never_queues_ahead():
    g = _sources(3, dur=0.005)
    res = HostScheduler(g, 1, buffer_depth=1).run()
    assert res.outputs["sum"] == 3
    assert res.peak_inflight == 1


def test_invalid_construction_rejected():
    g = _sources(2)
    with pytest.raises(ValueError):
        HostScheduler(g, 0)
    with pytest.raises(ValueError):
        HostScheduler(g, 2, buffer_depth=0)


def test_drain_refills_all_idle_executors():
    # 4 ops all complete while the scheduler is blocked on the first
    # triggered.get(); the drain must refill every executor in one round,
    # letting the second wave run concurrently
    barrier = threading.Barrier(4, timeout=5)

    def wave1(i):
        barrier.wait()       # all four finish together
        return i

    g = Graph("drain")
    for i in range(4):
        g.add_op(f"a{i}", flops=1.0, fn=lambda i=i: wave1(i))
    for i in range(4):
        g.add_op(f"b{i}", deps=(f"a{i}",), flops=1.0,
                 fn=lambda v: (time.sleep(0.03), v * 10)[1])
    g.add_op("out", deps=tuple(f"b{i}" for i in range(4)),
             flops=1.0, fn=lambda *xs: sum(xs))
    res = HostScheduler(g, 4).run()
    assert res.outputs["out"] == (0 + 10 + 20 + 30)
    b_evts = [e for e in res.trace if e.op.startswith("b")]
    assert len({e.executor for e in b_evts}) == 4
    # the second wave overlapped: total b-span far below 4 sequential sleeps
    span = max(e.end for e in b_evts) - min(e.start for e in b_evts)
    assert span < 4 * 0.03


def test_scheduler_rejects_graph_mutation_between_runs():
    # per-graph immutables are hoisted to __init__; a node added after
    # construction must fail loudly, not silently never execute
    g = _sources(2)
    sched = HostScheduler(g, 1)
    assert sched.run().outputs["sum"] == 1
    g.add_op("extra", deps=("sum",), flops=1.0, fn=lambda v: v)
    with pytest.raises(RuntimeError, match="mutated"):
        sched.run()


def test_executor_exception_propagates_not_deadlocks():
    g = Graph("boom")
    g.add_op("a", flops=1.0, fn=lambda: 1)
    g.add_op("b", deps=("a",), flops=1.0,
             fn=lambda v: (_ for _ in ()).throw(ValueError("boom")))
    with pytest.raises(RuntimeError, match="'b' failed"):
        HostScheduler(g, 2).run()


def test_explicit_executor_count_is_honored():
    x, w = _xw(16)
    exe = graphi.compile(fn_branches, x, w, backend="host")
    exe.execute_host(exe.captured.bind((x, w)), n_executors=1)
    assert {e.executor for e in exe.last_run.trace} == {0}


def test_mesh_backend_raw_graph_uses_static_plan():
    import jax
    from jax.sharding import Mesh

    g = Graph("run")
    g.add_op("x", fn=lambda: 2.0)
    g.add_op("y", deps=("x",), flops=1.0, fn=lambda a: a * 3)
    devs = np.array(jax.devices()[:4]).reshape(4, 1)
    mesh = Mesh(devs, ("data", "model"))
    exe = graphi.compile(g, backend="mesh", mesh=mesh,
                         n_executors=2, team_size=2)
    out = exe()
    assert out["y"] == 6.0
    assert exe.last_plan is not None
    assert exe.static_plan() is exe.last_plan     # cached default plan


def test_compile_captured_graph_rejects_specs():
    from repro.core.capture import capture

    cg = capture(lambda v: v * 2, jnp.ones((3,)))
    with pytest.raises(TypeError):
        graphi.compile(cg, jnp.ones((3,)))
    exe = graphi.compile(cg)
    assert exe.captured is cg


def test_host_scheduler_random_dag_matches_interpreter():
    rng = np.random.default_rng(7)
    g = Graph("rand")
    for i in range(40):
        deps = tuple(f"n{d}" for d in rng.choice(i, size=min(i, rng.integers(0, 4)),
                                                 replace=False)) if i else ()
        g.add_op(f"n{i}", flops=float(rng.integers(1, 100)), deps=deps,
                 fn=(lambda *xs, i=i: float(i) + sum(xs)))
    res = HostScheduler(g, 3, buffer_depth=3).run()
    assert res.outputs == g.execute()
    assert res.peak_inflight >= 1
