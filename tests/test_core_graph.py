"""Unit + property tests for the graph IR."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Graph, GraphValidationError


def diamond() -> Graph:
    g = Graph("diamond")
    g.add_op("a", flops=1.0)
    g.add_op("b", flops=2.0, deps=("a",))
    g.add_op("c", flops=3.0, deps=("a",))
    g.add_op("d", flops=4.0, deps=("b", "c"))
    return g


def test_duplicate_rejected():
    g = Graph()
    g.add_op("a")
    with pytest.raises(GraphValidationError):
        g.add_op("a")


def test_unknown_dep_rejected():
    g = Graph()
    with pytest.raises(GraphValidationError):
        g.add_op("b", deps=("missing",))


def test_topo_order_diamond():
    g = diamond()
    order = g.topo_order()
    assert order[0] == "a" and order[-1] == "d"
    assert set(order) == {"a", "b", "c", "d"}


def test_sources_sinks_width():
    g = diamond()
    assert g.sources() == ["a"]
    assert g.sinks() == ["d"]
    assert g.width() == 2


def test_successors_cached_tuple_invalidated_on_add():
    g = diamond()
    succ = g.successors("a")
    assert succ == ("b", "c") and isinstance(succ, tuple)
    assert g.successors("a") is succ                 # cached, not a copy
    assert g.predecessors("d") == ("b", "c")
    assert g.predecessors("a") == ()
    g.add_op("e", deps=("a", "d"))
    assert g.successors("a") == ("b", "c", "e")      # cache invalidated
    assert g.successors("d") == ("e",)


def test_levels_and_critical_path():
    g = diamond()
    costs = {n.name: n.flops for n in g.nodes}
    lev = g.levels(costs)
    # level = own cost + longest tail
    assert lev["d"] == 4.0
    assert lev["b"] == 2.0 + 4.0
    assert lev["c"] == 3.0 + 4.0
    assert lev["a"] == 1.0 + 7.0
    length, path = g.critical_path(costs)
    assert length == 8.0
    assert path == ["a", "c", "d"]


def test_critical_path_keeps_zero_cost_tail():
    # regression: a zero-cost sink (free concat/loss op) used to truncate
    # the reported path at its last costly ancestor
    g = Graph("tail")
    g.add_op("a", flops=5.0)
    g.add_op("b", flops=3.0, deps=("a",))
    g.add_op("loss", flops=0.0, deps=("b",))
    length, path = g.critical_path({"a": 5.0, "b": 3.0, "loss": 0.0})
    assert length == 8.0
    assert path == ["a", "b", "loss"]


def test_critical_path_all_zero_costs_spans_source_to_sink():
    g = diamond()
    length, path = g.critical_path({n: 0.0 for n in g.names})
    assert length == 0.0
    assert path[0] == "a" and path[-1] == "d"


def test_execute_sequential():
    g = Graph()
    g.add_op("x", fn=lambda: 3)
    g.add_op("y", fn=lambda: 4)
    g.add_op("z", deps=("x", "y"), fn=lambda a, b: a * b)
    assert g.execute()["z"] == 12


def test_execute_with_inputs():
    g = Graph()
    g.add_op("x")
    g.add_op("y", deps=("x",), fn=lambda v: v + 1)
    assert g.execute({"x": 41})["y"] == 42


def test_subgraph():
    g = diamond()
    sub = g.subgraph(["a", "b"])
    assert len(sub) == 2
    assert sub.sinks() == ["b"]


# ---------------------------------------------------------------------------
# Property tests: random DAGs
# ---------------------------------------------------------------------------

@st.composite
def random_dag(draw):
    n = draw(st.integers(2, 25))
    g = Graph("rand")
    for i in range(n):
        # only depend on earlier nodes => acyclic by construction
        pool = list(range(i))
        deps = draw(
            st.lists(st.sampled_from(pool), max_size=min(3, i), unique=True)
        ) if pool else []
        cost = draw(st.floats(1e-6, 1e-2, allow_nan=False))
        g.add_op(f"n{i}", flops=cost * 1e9, deps=tuple(f"n{d}" for d in deps))
    return g


@settings(max_examples=50, deadline=None)
@given(random_dag())
def test_topo_order_respects_deps(g):
    pos = {n: i for i, n in enumerate(g.topo_order())}
    for node in g.nodes:
        for d in node.deps:
            assert pos[d] < pos[node.name]


@settings(max_examples=50, deadline=None)
@given(random_dag())
def test_levels_monotone_along_edges(g):
    costs = {n.name: max(n.flops, 1.0) for n in g.nodes}
    lev = g.levels(costs)
    for node in g.nodes:
        for d in node.deps:
            # a dep's level strictly exceeds its consumer's (positive costs)
            assert lev[d] > lev[node.name]


@settings(max_examples=50, deadline=None)
@given(random_dag())
def test_critical_path_is_valid_path_and_max(g):
    costs = {n.name: max(n.flops, 1.0) for n in g.nodes}
    length, path = g.critical_path(costs)
    # path edges exist
    for a, b in zip(path, path[1:]):
        assert a in g.predecessors(b)
    assert length == pytest.approx(sum(costs[p] for p in path))
    # no single node exceeds it; total >= longest node
    assert length >= max(costs.values()) - 1e-9
    assert length <= sum(costs.values()) + 1e-9
