"""Fault-tolerant trainer: injected failures, bit-exact recovery, straggler
watchdog, restart-from-latest, and an end-to-end small-LM descent check."""
import tempfile
import time

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.data import DataConfig, SyntheticTokens
from repro.train.step import TrainStepConfig, init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def _toy_step(state, batch):
    w = state["w"]
    target = jnp.asarray(batch["tokens"], jnp.float32).mean() / 100.0
    g = 2 * (w - target)
    return {"w": w - 0.1 * g}, {"loss": (w - target) ** 2}


def _toy_data():
    return SyntheticTokens(DataConfig(vocab_size=100, seq_len=8, global_batch=4, seed=0))


def test_recovery_is_bit_exact_with_failure_free_run():
    data = _toy_data()
    fired = set()

    def fault(step):
        if step in (23, 57) and step not in fired:
            fired.add(step)
            raise RuntimeError("injected")

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3, async_save=False)
        tr = Trainer(_toy_step, {"w": jnp.asarray(5.0)}, data.batch,
                     TrainerConfig(total_steps=80, checkpoint_every=10, log_every=100),
                     checkpoint=mgr, fault_hook=fault)
        rep = tr.run()
        assert rep.restarts == 2
        cur = {"w": jnp.asarray(5.0)}
        for s in range(80):
            cur, _ = _toy_step(cur, data.batch(s))
        assert float(cur["w"]) == pytest.approx(float(tr.state["w"]), abs=1e-7)


def test_failure_before_first_checkpoint_raises():
    data = _toy_data()

    def always_fail(step):
        raise RuntimeError("dead node")

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        tr = Trainer(_toy_step, {"w": jnp.asarray(1.0)}, data.batch,
                     TrainerConfig(total_steps=10, checkpoint_every=5),
                     checkpoint=mgr, fault_hook=always_fail)
        with pytest.raises(RuntimeError):
            tr.run()


def test_max_restarts_enforced():
    data = _toy_data()

    def flaky(step):
        if step == 7:
            raise RuntimeError("permanently broken step")

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        tr = Trainer(_toy_step, {"w": jnp.asarray(1.0)}, data.batch,
                     TrainerConfig(total_steps=20, checkpoint_every=5, max_restarts=3),
                     checkpoint=mgr, fault_hook=flaky)
        with pytest.raises(RuntimeError, match="max_restarts"):
            tr.run()


def test_straggler_watchdog_fires():
    data = _toy_data()
    seen = []

    def slow_batch(step):
        if step == 30:
            time.sleep(0.25)
        return data.batch(step)

    tr = Trainer(_toy_step, {"w": jnp.asarray(1.0)}, slow_batch,
                 TrainerConfig(total_steps=50, straggler_factor=3.0),
                 on_straggler=lambda s, ratio: seen.append((s, ratio)))
    rep = tr.run()
    assert 30 in rep.stragglers
    assert any(s == 30 for s, _ in seen)


def test_resume_from_latest_checkpoint_on_new_trainer():
    data = _toy_data()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3, async_save=False)
        tr1 = Trainer(_toy_step, {"w": jnp.asarray(5.0)}, data.batch,
                      TrainerConfig(total_steps=30, checkpoint_every=10),
                      checkpoint=mgr)
        tr1.run()
        # "process restart": fresh trainer, same dir -> resumes at 30
        tr2 = Trainer(_toy_step, {"w": jnp.asarray(5.0)}, data.batch,
                      TrainerConfig(total_steps=60, checkpoint_every=10),
                      checkpoint=CheckpointManager(d, keep=3, async_save=False))
        rep2 = tr2.run()
        assert rep2.steps_run == 30  # only the remaining steps
        cur = {"w": jnp.asarray(5.0)}
        for s in range(60):
            cur, _ = _toy_step(cur, data.batch(s))
        assert float(cur["w"]) == pytest.approx(float(tr2.state["w"]), abs=1e-7)


def test_small_lm_loss_descends_through_faults():
    """End-to-end: real model + real train step + injected failure, loss
    still descends below the uniform baseline."""
    from repro.optim.adamw import AdamWConfig

    cfg = get_config("gemma-2b", smoke=True)
    tcfg = TrainStepConfig(microbatches=1, remat=False,
                           adamw=AdamWConfig(lr=3e-3),
                           warmup_steps=5, total_steps=40)
    state = init_train_state(cfg, jax.random.key(0), tcfg.adamw)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=8, kind="bigram"))
    fired = []

    def fault(s):
        if s == 25 and not fired:
            fired.append(s)
            raise RuntimeError("injected")

    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(step, state, data.batch,
                     TrainerConfig(total_steps=40, checkpoint_every=10, log_every=5),
                     checkpoint=CheckpointManager(d, keep=2, async_save=False),
                     fault_hook=fault)
        rep = tr.run()
    assert rep.restarts == 1
    losses = [r["loss"] for r in rep.history if "loss" in r]
    assert losses[-1] < losses[0] - 0.3, losses
