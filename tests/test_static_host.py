"""Compiled static host plans (ISSUE 4): bit-exact parity with the
sequential ``Graph.execute`` oracle across the captured model families,
op-exception propagation out of a static run, and dynamic-vs-static
coexistence on one shared :class:`ExecutorPool`."""
import threading
import time

import numpy as np
import pytest

import repro
from repro.core import KNL7250, Graph, GraphValidationError, make_schedule
from repro.core.engine import ExecutorPool, HostScheduler
from repro.core.static_host import compile_host_plan, layered_graph as layered
from repro.train.step import lm_loss_fn
from test_capture import TINY, _setup


# ---------------------------------------------------------------------------
# parity: static plan execution == sequential interpreter, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(TINY))
def test_static_parity_model_families(family):
    cfg, params, batch = _setup(family)
    exe = repro.compile(lm_loss_fn(cfg), params, batch, backend="host",
                        host_mode="static", n_executors=4, team_size=2)
    oracle = exe.captured.run(params, batch)        # Graph.execute
    got = exe(params, batch)
    # same fns applied to the same values in dependency order: the static
    # run must be *bit-identical* to the sequential oracle, not just close
    np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))
    again = exe(params, batch)                      # plan replay, same result
    np.testing.assert_array_equal(np.asarray(again), np.asarray(oracle))
    assert exe.host_plan().n_ops >= 20


def test_static_matches_oracle_random_dag():
    rng = np.random.default_rng(11)
    g = Graph("rand")
    for i in range(40):
        deps = tuple(f"n{d}" for d in rng.choice(i, size=min(i, rng.integers(0, 4)),
                                                 replace=False)) if i else ()
        g.add_op(f"n{i}", flops=float(rng.integers(1, 100)), deps=deps,
                 fn=(lambda *xs, i=i: float(i) + sum(xs)))
    sched = make_schedule(g, KNL7250, n_executors=3, team_size=2)
    plan = compile_host_plan(g, sched)
    assert plan.run().outputs == g.execute()        # ephemeral pool
    with ExecutorPool(3) as pool:
        for _ in range(5):                          # replay on a shared pool
            assert plan.run(pool=pool).outputs == g.execute()


def test_static_run_with_trace_covers_every_op():
    g = layered()
    exe = repro.compile(g, hw=KNL7250, backend="host", host_mode="static",
                        n_executors=3, team_size=2)
    res = exe.execute_host({"x": 1}, collect_trace=True)
    assert res.outputs == g.execute({"x": 1})
    assert len(res.trace) == exe.host_plan().n_ops
    assert len({ev.executor for ev in res.trace}) >= 2
    assert res.makespan >= max(ev.end for ev in res.trace) - 1e-9
    # default runs skip tracing — timestamps are the overhead being removed
    assert exe.execute_host({"x": 1}).trace == []


# ---------------------------------------------------------------------------
# plan structure
# ---------------------------------------------------------------------------

def test_plan_structure_partitions_ops():
    g = layered()
    sched = make_schedule(g, KNL7250, n_executors=3, team_size=2)
    plan = compile_host_plan(g, sched)
    assert plan.n_executors == 3
    placed = [i for prog in plan.programs for i in prog]
    executed = [plan.ids[n] for n in g.names if g[n].fn is not None]
    assert sorted(placed) == sorted(executed)       # exact partition
    for e, prog in enumerate(plan.programs):
        assert all(plan.owner[i] == e for i in prog)
    assert plan.input_ids == (plan.ids["x"],)
    assert plan.owner[plan.ids["x"]] == -1
    # layer-0 ops wait only on the inline-resolved input: they are seeds
    seeds = {i for s in plan.seeds for i in s}
    assert seeds == {plan.ids[f"l0w{w}"] for w in range(3)}
    assert "3 executors" in plan.describe()


def test_plan_folds_onto_fewer_executors():
    g = layered()
    sched = make_schedule(g, KNL7250, n_executors=4, team_size=2)
    plan = compile_host_plan(g, sched, n_executors=2)
    assert plan.n_executors == 2
    assert all(0 <= plan.owner[i] < 2 for prog in plan.programs for i in prog)
    assert plan.run({"x": 3}).outputs == g.execute({"x": 3})


def test_plan_rejects_fnless_node_with_deps():
    g = Graph("bad")
    g.add_op("a", fn=lambda: 1)
    g.add_op("b", deps=("a",))                      # no fn, has deps
    sched = make_schedule(g, KNL7250, n_executors=2, team_size=1)
    with pytest.raises(GraphValidationError, match="deps but no fn"):
        compile_host_plan(g, sched)


def test_plan_cached_per_executor_count():
    exe = repro.compile(layered(), hw=KNL7250, backend="host",
                        host_mode="static", n_executors=3, team_size=2)
    p3 = exe.host_plan(3)
    assert exe.host_plan(3) is p3                   # cached
    p2 = exe.host_plan(2)
    assert p2 is not p3 and p2.n_executors == 2
    exe.execute_host({"x": 0})                      # static by default
    assert exe.host_plan() in (p2, p3)              # run reused the cache


def test_wide_shared_pool_does_not_widen_the_plan():
    g = layered()
    with ExecutorPool(4) as pool:
        exe = repro.compile(g, hw=KNL7250, backend="host", host_mode="static",
                            n_executors=2, team_size=1, pool=pool)
        # planned width (2) wins over the pool's width (4): a plan frozen
        # wider than the profiled config pays wakeups it chose to avoid
        assert exe.host_plan().n_executors == 2
        assert exe.execute_host({"x": 5}).outputs == g.execute({"x": 5})


def test_poolless_static_executable_leases_from_runtime():
    g = layered()
    with repro.Runtime(n_workers=2) as rt:
        with rt.compile(g, backend="host", host_mode="static",
                        n_executors=2, team_size=1) as exe:
            assert not hasattr(exe, "_auto_pool")   # private pools are gone
            assert exe.execute_host({"x": 1}).outputs == g.execute({"x": 1})
            exe.execute_host({"x": 2})
            # every run leased the runtime's executors and gave them back
            assert exe.runtime is rt
            assert rt.leased_executors == 0
            assert len(rt.pool._threads) == rt.n_workers


def test_calibrate_freezes_measured_costs_into_plans():
    g = layered()
    exe = repro.compile(g, hw=KNL7250, backend="host", host_mode="static")
    p0 = exe.host_plan()
    prof = exe.calibrate(inputs={"x": 1})
    assert prof is exe.profile                      # re-cached
    assert exe.host_plan() is not p0                # replanned
    sched = exe.schedule
    executed = [n for n in g.names if g[n].fn is not None]
    assert all(sched.op_costs[n] > 0 for n in executed)   # measured, not flops
    assert exe.execute_host({"x": 4}).outputs == g.execute({"x": 4})
    # later re-profiles keep the measured table: the config search and the
    # frozen placements must agree on one cost model
    prof2 = exe.profile_with(max_executors=2)
    assert prof2.op_costs == dict(exe._measured(prof2.best_team_size))
    with pytest.raises(TypeError, match="captured"):
        exe.calibrate(1)                            # raw graphs need inputs=


def test_profile_with_invalidates_cached_plans():
    exe = repro.compile(layered(), hw=KNL7250, backend="host",
                        host_mode="static")
    plan = exe.host_plan()
    assert exe._host_plans                          # populated
    exe.profile_with()                              # new profile -> new schedule
    assert not exe._host_plans                      # plans froze the old one
    assert exe.host_plan() is not plan
    assert exe.execute_host({"x": 2}).outputs == layered().execute({"x": 2})


# ---------------------------------------------------------------------------
# failure + validation
# ---------------------------------------------------------------------------

def test_op_exception_propagates_and_pool_survives():
    bad = Graph("boom")
    bad.add_op("a", flops=1.0, fn=lambda: 1)
    bad.add_op("b", deps=("a",), flops=1.0,
               fn=lambda v: (_ for _ in ()).throw(ValueError("boom")))
    bad.add_op("c", deps=("b",), flops=1.0, fn=lambda v: v + 1)
    sched = make_schedule(bad, KNL7250, n_executors=2, team_size=1)
    plan = compile_host_plan(bad, sched)
    with ExecutorPool(2) as pool:
        with pytest.raises(RuntimeError, match="'b' failed"):
            plan.run(pool=pool)
        # every segment exited on the poison ids; the pool still serves
        g = layered()
        good = compile_host_plan(
            g, make_schedule(g, KNL7250, n_executors=2, team_size=1))
        assert good.run({"x": 2}, pool=pool).outputs == g.execute({"x": 2})


def test_missing_input_raises():
    g = layered()
    plan = compile_host_plan(
        g, make_schedule(g, KNL7250, n_executors=2, team_size=1))
    with pytest.raises(GraphValidationError, match="no fn and no input"):
        plan.run({})


def test_plan_wider_than_pool_rejected():
    g = layered()
    plan = compile_host_plan(
        g, make_schedule(g, KNL7250, n_executors=4, team_size=1))
    with ExecutorPool(2) as pool:
        with pytest.raises(ValueError, match="recompile the plan"):
            plan.run({"x": 0}, pool=pool)


def test_host_mode_validation():
    with pytest.raises(ValueError, match="host_mode"):
        repro.compile(layered(), hw=KNL7250, backend="host", host_mode="turbo")
    exe = repro.compile(layered(), hw=KNL7250, backend="host",
                        n_executors=2, team_size=1)
    with pytest.raises(ValueError, match="host_mode"):
        exe.execute_host({"x": 0}, host_mode="turbo")
    # per-run override in both directions
    assert exe.host_mode == "dynamic"
    oracle = layered().execute({"x": 7})
    assert exe.execute_host({"x": 7}, host_mode="static").outputs == oracle
    assert exe.execute_host({"x": 7}, host_mode="dynamic").outputs == oracle


# ---------------------------------------------------------------------------
# coexistence: static plan runs alongside an in-flight dynamic run
# ---------------------------------------------------------------------------

def test_static_and_dynamic_share_one_pool():
    slow = Graph("slow")
    slow.add_op("s0", flops=1.0, fn=lambda: (time.sleep(0.01), 1)[1])
    for i in range(1, 8):
        slow.add_op(f"s{i}", deps=(f"s{i-1}",), flops=1.0,
                    fn=lambda v: (time.sleep(0.01), v + 1)[1])
    g = layered()
    with ExecutorPool(2) as pool:
        plan = compile_host_plan(
            g, make_schedule(g, KNL7250, n_executors=2, team_size=1))
        box = {}

        def dynamic_run():
            box["dyn"] = HostScheduler(slow, 2, pool=pool).run().outputs["s7"]

        th = threading.Thread(target=dynamic_run)
        th.start()
        outs = [plan.run({"x": k}, pool=pool).outputs["out"] for k in range(6)]
        th.join(timeout=30)
        assert not th.is_alive()
        assert box["dyn"] == 8
        assert outs == [g.execute({"x": k})["out"] for k in range(6)]


def test_two_static_plans_interleave_on_one_pool():
    ga, gb = layered(L=4), layered(L=7, W=2)
    with ExecutorPool(2) as pool:
        pa = compile_host_plan(
            ga, make_schedule(ga, KNL7250, n_executors=2, team_size=1))
        pb = compile_host_plan(
            gb, make_schedule(gb, KNL7250, n_executors=2, team_size=1))
        box = {}

        def run_b():
            box["b"] = [pb.run({"x": k}, pool=pool).outputs["out"]
                        for k in range(8)]

        th = threading.Thread(target=run_b)
        th.start()
        outs_a = [pa.run({"x": k}, pool=pool).outputs["out"] for k in range(8)]
        th.join(timeout=30)
        assert not th.is_alive()
        assert outs_a == [ga.execute({"x": k})["out"] for k in range(8)]
        assert box["b"] == [gb.execute({"x": k})["out"] for k in range(8)]


def test_serve_engine_static_decode_matches_dynamic():
    import jax
    from repro.configs.base import get_config
    from repro.models import transformer
    from repro.serve.engine import ContinuousEngine, Request, ServeConfig

    cfg = get_config("gemma-2b", smoke=True).reduced(vocab_size=200)
    params = transformer.init_params(cfg, jax.random.key(5))
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 9)]

    outs = {}
    for mode in ("static", "dynamic"):
        with ContinuousEngine(cfg, params, ServeConfig(max_batch=2, max_len=24),
                              decode_host_mode=mode) as eng:
            assert eng.decode_host_mode == mode
            for i, pr in enumerate(prompts):
                eng.submit(Request(request_id=i, prompt=pr, max_new_tokens=5))
            outs[mode] = [r.output for r in eng.run()]
    assert outs["static"] == outs["dynamic"]
