"""repro.hwperf.topology: synthetic shapes, sysfs parsing, fallbacks, and
the disjoint core-set planner (PR 10 tentpole)."""
import os

import pytest

from repro.hwperf.topology import (CpuTopology, LogicalCpu, detect_topology,
                                   disjoint_core_sets, synthetic_topology)


# ---------------------------------------------------------------------------
# synthetic shapes
# ---------------------------------------------------------------------------

def test_synthetic_flat():
    t = synthetic_topology(4)
    assert t.n_cpus == 4
    assert t.sockets == (0,)
    assert not t.smt
    assert t.physical_cores() == [(0,), (1,), (2,), (3,)]


def test_synthetic_smt_pairs_linux_enumeration():
    # 8 cpus, smt=2: cores 0-3 carry cpus (0,4), (1,5), (2,6), (3,7) — the
    # Linux convention (first one cpu per core, then the siblings)
    t = synthetic_topology(8, smt=2)
    assert t.smt
    assert t.physical_cores() == [(0, 4), (1, 5), (2, 6), (3, 7)]
    assert t.smt_siblings(1) == (1, 5)
    assert t.smt_siblings(5) == (1, 5)


def test_synthetic_two_sockets():
    t = synthetic_topology(8, sockets=2)
    assert t.sockets == (0, 1)
    assert t.cpus_of_socket(0) == (0, 1, 2, 3)
    assert t.cpus_of_socket(1) == (4, 5, 6, 7)
    assert t.nodes == (0, 1)


def test_synthetic_rejects_bad_args():
    with pytest.raises(ValueError):
        synthetic_topology(0)
    with pytest.raises(ValueError):
        synthetic_topology(4, sockets=0)
    with pytest.raises(ValueError):
        synthetic_topology(4, smt=0)


def test_smt_siblings_unknown_cpu_raises():
    t = synthetic_topology(2)
    with pytest.raises(ValueError, match="cpu 9"):
        t.smt_siblings(9)


def test_describe_mentions_shape():
    d = synthetic_topology(8, sockets=2, smt=2).describe()
    assert "8 cpus" in d and "4 cores" in d and "2 socket(s)" in d
    assert "smt=on" in d


# ---------------------------------------------------------------------------
# detection: fake sysfs tree, fallback, real machine
# ---------------------------------------------------------------------------

def _fake_sysfs(root, layout):
    """layout: {cpu: (core, socket, node|None)}"""
    for cpu, (core, socket, node) in layout.items():
        d = os.path.join(root, "devices", "system", "cpu", f"cpu{cpu}",
                         "topology")
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "core_id"), "w") as f:
            f.write(f"{core}\n")
        with open(os.path.join(d, "physical_package_id"), "w") as f:
            f.write(f"{socket}\n")
        if node is not None:
            os.makedirs(os.path.join(d, os.pardir, f"node{node}"),
                        exist_ok=True)


def test_detect_parses_fake_sysfs(tmp_path, monkeypatch):
    # pretend the process may run on cpus 0 and 1 of a 2-smt single core
    monkeypatch.setattr("repro.hwperf.topology._usable_cpus", lambda: [0, 1])
    _fake_sysfs(str(tmp_path), {0: (0, 0, 0), 1: (0, 0, 0)})
    t = detect_topology(sysfs=str(tmp_path))
    assert t.source == "sys"
    assert t.n_cpus == 2
    assert t.physical_cores() == [(0, 1)]   # SMT siblings grouped
    assert t.smt


def test_detect_partial_sysfs_falls_back_flat(tmp_path, monkeypatch):
    # cpu1's files are missing: the whole detection degrades to flat —
    # never fabricate an asymmetric machine from a partial read
    monkeypatch.setattr("repro.hwperf.topology._usable_cpus", lambda: [0, 1])
    _fake_sysfs(str(tmp_path), {0: (0, 0, None)})
    t = detect_topology(sysfs=str(tmp_path))
    assert t.source == "flat"
    assert t.n_cpus == 2
    assert not t.smt


def test_detect_real_machine_restricted_to_affinity():
    t = detect_topology()
    assert t.n_cpus >= 1
    assert t.source in ("sys", "flat")
    if hasattr(os, "sched_getaffinity"):
        assert t.n_cpus == len(os.sched_getaffinity(0))


# ---------------------------------------------------------------------------
# disjoint core sets
# ---------------------------------------------------------------------------

def test_disjoint_sets_partition_whole_cores():
    t = synthetic_topology(8, smt=2)          # cores (0,4) (1,5) (2,6) (3,7)
    sets = disjoint_core_sets(t, 2)
    assert len(sets) == 2
    seen = [c for s in sets for c in s]
    assert len(seen) == len(set(seen))        # disjoint
    # SMT siblings never split across sets
    for s in sets:
        for cpu in s:
            assert all(sib in s for sib in t.smt_siblings(cpu))


def test_disjoint_sets_stay_on_one_socket_when_possible():
    t = synthetic_topology(8, sockets=2)
    sets = disjoint_core_sets(t, 2)
    for s in sets:
        sockets = {next(c.socket for c in t.cpus if c.cpu == cpu)
                   for cpu in s}
        assert len(sockets) == 1


def test_oversubscribed_round_robins_single_cpus():
    t = synthetic_topology(2)
    sets = disjoint_core_sets(t, 5)
    assert len(sets) == 5
    assert all(len(s) == 1 for s in sets)
    assert sets[0] != sets[1]                  # round-robin, not all-on-one
    assert sets[0] == sets[2]                  # wraps


def test_cpus_per_set_clamped_to_even_split():
    t = synthetic_topology(8)
    sets = disjoint_core_sets(t, 4, cpus_per_set=100)
    assert all(len(s) == 2 for s in sets)


def test_n_sets_must_be_positive():
    with pytest.raises(ValueError):
        disjoint_core_sets(synthetic_topology(2), 0)


def test_logical_cpu_is_frozen():
    c = LogicalCpu(cpu=0, core=0, socket=0, node=0)
    with pytest.raises(AttributeError):
        c.cpu = 1


def test_topology_is_value_like():
    a = synthetic_topology(4)
    b = synthetic_topology(4)
    assert a == b
    assert isinstance(a, CpuTopology)
