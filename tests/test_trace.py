"""core.trace timeline rendering and its Executable surface
(``describe(trace=)`` / ``render_trace`` — ISSUE 10 satellite: both exports
previously had zero callers and zero tests)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api as graphi
from repro.core import KNL7250, Graph
from repro.core.simulate import TraceEvent
from repro.core.trace import ascii_timeline, trace_csv


def _trace():
    return [
        TraceEvent(op="a", executor=0, start=0.0, end=10e-6),
        TraceEvent(op="b", executor=1, start=2e-6, end=8e-6),
        TraceEvent(op="c", executor=0, start=10e-6, end=20e-6),
    ]


# ---------------------------------------------------------------------------
# renderers
# ---------------------------------------------------------------------------

def test_ascii_timeline_one_row_per_executor():
    out = ascii_timeline(_trace(), 2)
    lines = out.splitlines()
    assert lines[0].startswith("E00 |")
    assert lines[1].startswith("E01 |")
    assert len(lines) == 3                       # 2 executors + time axis
    assert "20.0us" in lines[-1]
    # ops render as their trailing name character on their own row
    assert "a" in lines[0] and "c" in lines[0]
    assert "b" in lines[1]
    assert "b" not in lines[0]


def test_ascii_timeline_overlap_marks_hash():
    # two ops on one executor overlapping in time render as '#'
    t = [TraceEvent("x", 0, 0.0, 1.0), TraceEvent("y", 0, 0.0, 1.0)]
    out = ascii_timeline(t, 1)
    assert "#" in out


def test_ascii_timeline_empty():
    assert ascii_timeline([], 4) == "(empty trace)"


def test_trace_csv_sorted_with_durations():
    out = trace_csv(_trace())
    lines = out.splitlines()
    assert lines[0] == "op,executor,start_us,end_us,duration_us"
    assert lines[1].startswith("a,0,0.000,10.000,10.000")
    assert lines[2].startswith("b,1,2.000,8.000,6.000")   # sorted by start
    assert len(lines) == 4


# ---------------------------------------------------------------------------
# Executable surface
# ---------------------------------------------------------------------------

def _diamond():
    g = Graph("tr")
    g.add_op("a", flops=1e8)
    g.add_op("b", flops=2e8, deps=("a",))
    g.add_op("c", flops=3e8, deps=("a",))
    g.add_op("d", flops=1e8, deps=("b", "c"))
    return g


def test_describe_trace_appends_simulated_timeline():
    exe = graphi.compile(_diamond(), hw=KNL7250, backend="sim")
    plain = exe.describe()
    assert "trace (" not in plain
    with_trace = exe.describe(trace=True)
    assert with_trace.startswith(plain)
    assert "trace (simulated" in with_trace
    assert "E00 |" in with_trace


def test_describe_trace_csv():
    exe = graphi.compile(_diamond(), hw=KNL7250, backend="sim")
    out = exe.describe(trace="csv")
    assert "op,executor,start_us,end_us,duration_us" in out


def test_render_trace_measured_after_host_run():
    def fn(x):
        y = jnp.tanh(x @ x)
        return (y @ x).sum()

    x = jnp.asarray(np.random.default_rng(0).normal(size=(16, 16)),
                    jnp.float32)
    exe = graphi.compile(fn, x, hw=KNL7250, backend="host")
    exe.execute_host(exe.captured.bind((x,)), collect_trace=True)
    out = exe.render_trace()
    assert "measured" in out.splitlines()[0]


def test_render_trace_rejects_unknown_format():
    exe = graphi.compile(_diamond(), hw=KNL7250, backend="sim")
    with pytest.raises(ValueError, match="fmt"):
        exe.render_trace(fmt="svg")
