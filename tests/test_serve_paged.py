"""Paged-KV serving engine: bit-exact parity with unbatched greedy decode
and the per-slot ContinuousEngine, prefix sharing with copy-on-write,
eviction/recompute under memory pressure, and chunked-prefill admission."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import transformer
from repro.serve.engine import ContinuousEngine, Request, ServeConfig
from repro.serve.paged import PagedConfig, PagedEngine, PagePool
from repro.serve.step import mask_pad_vocab


@pytest.fixture(scope="module")
def model():
    # padded_vocab (512) > vocab_size (260): the pad-mask is load-bearing
    cfg = get_config("gemma-2b", smoke=True).reduced(vocab_size=260)
    params = transformer.init_params(cfg, jax.random.key(3))
    return cfg, params


@pytest.fixture(scope="module")
def engine(model):
    cfg, params = model
    eng = PagedEngine(cfg, params, ServeConfig(max_batch=2, max_len=48),
                      paged=PagedConfig(page_size=8, prefill_chunk=8))
    yield eng
    eng.close()


def _reference_decode(cfg, params, prompt, n_new):
    """Unbatched greedy reference (pad-masked argmax)."""
    cache = transformer.init_cache(cfg, 1, len(prompt) + n_new + 1)
    logits, cache = transformer.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt)[None]}, cache)
    out = []
    for _ in range(n_new):
        t = int(jnp.argmax(mask_pad_vocab(logits, cfg.vocab_size), -1)[0])
        out.append(t)
        logits, cache = transformer.decode_step(
            cfg, params, jnp.asarray([[t]], jnp.int32), cache)
    return out


# ---------------------------------------------------------------------------
# PagePool: pure host-side allocator semantics
# ---------------------------------------------------------------------------

def test_page_pool_refcounts_and_cold_reclaim():
    from repro.serve.paged import PoolExhausted

    pool = PagePool(n_pages=2, page_size=4)
    a, b = pool.alloc(), pool.alloc()
    assert pool.used() == 2 and pool.peak_used == 2
    pool.register(a, np.arange(8), 0, 4)
    pool.share(a)
    pool.release(a)
    assert pool.used() == 2                 # still mapped once
    pool.release(a)
    assert pool.used() == 2 and a in pool.cold   # registered -> cold, not free
    pool.release(b)
    assert pool.used() == 1                 # unregistered -> freed
    pool.alloc()                            # takes the free page...
    pool.alloc()                            # ...then reclaims cold a
    assert pool.n_cold_reclaims == 1
    assert not pool.full_map and not pool.meta   # a's registration dropped
    with pytest.raises(PoolExhausted):
        pool.alloc()


def test_page_pool_prefix_matching():
    pool = PagePool(n_pages=8, page_size=4)
    toks = np.arange(100, 110, dtype=np.int32)   # 10 tokens: 2 full + tail
    pids = [pool.alloc() for _ in range(3)]
    for j, pid in enumerate(pids):
        pool.register(pid, toks, j * 4, min(4, 10 - j * 4))
    # identical prompt, limit one short of the end: both full pages match,
    # then the tail page partially
    full, partial = pool.match_prefix(toks, limit=9)
    assert full == pids[:2]
    assert partial == (pids[2], 1)
    # divergence inside page 1: only page 0 matches fully, page 1 partially
    div = toks.copy()
    div[6] = 7
    full, partial = pool.match_prefix(div, limit=9)
    assert full == pids[:1]
    assert partial == (pids[1], 2)
    # nothing shared
    full, partial = pool.match_prefix(np.arange(5, dtype=np.int32), limit=4)
    assert full == [] and partial is None


# ---------------------------------------------------------------------------
# parity: paged mixed-length decode is bit-identical per request
# ---------------------------------------------------------------------------

def test_mixed_lengths_bit_identical_to_unbatched(model, engine):
    """4 mixed-length requests through 2 slots: chunked prefills, slot
    reuse, idle-row drop-writes — every stream must match unbatched greedy
    AND the per-slot ContinuousEngine on the same workload."""
    cfg, params = model
    rng = np.random.default_rng(0)
    lens = [5, 11, 17, 8]
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]
    for i, pr in enumerate(prompts):
        engine.submit(Request(request_id=i, prompt=pr, max_new_tokens=6))
    done = engine.run()
    assert [r.request_id for r in done] == [0, 1, 2, 3]       # submit order
    for r in done:
        ref = _reference_decode(cfg, params, r.prompt, 6)
        assert r.output == ref, (r.request_id, r.output, ref)
        assert all(t < cfg.vocab_size for t in r.output)
    # chunked prefill really ran (17-token prompt needs 3 chunks of 8)
    assert engine.n_chunks > len(prompts)
    # per-slot engine parity on the identical workload
    with ContinuousEngine(cfg, params,
                          ServeConfig(max_batch=2, max_len=48)) as cont:
        for i, pr in enumerate(prompts):
            cont.submit(Request(request_id=i, prompt=pr, max_new_tokens=6))
        cont_done = cont.run()
    assert [r.output for r in done] == [r.output for r in cont_done]


def test_prefix_sharing_maps_pages_and_stays_exact(model, engine):
    """Two prompts sharing a 2-page prefix: the second maps the first's
    pages (no recompute) and still decodes bit-identically."""
    cfg, params = model
    rng = np.random.default_rng(1)
    base = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
    tails = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
             for n in (4, 7)]
    pa, pb = (np.concatenate([base, t]) for t in tails)
    engine.submit(Request(request_id=10, prompt=pa, max_new_tokens=5))
    ra = engine.run()[0]
    shared0, chunks0 = engine.n_shared_pages, engine.n_chunks
    engine.submit(Request(request_id=11, prompt=pb, max_new_tokens=5))
    rb = engine.run()[0]
    assert engine.n_shared_pages - shared0 == 2      # both full base pages
    assert engine.n_chunks - chunks0 == 1            # only the tail prefilled
    assert ra.output == _reference_decode(cfg, params, pa, 5)
    assert rb.output == _reference_decode(cfg, params, pb, 5)


def test_cow_mid_page_divergence_no_corruption(model, engine):
    """A prompt diverging mid-page CoWs the partial match; the original
    prompt's stream must be unchanged afterwards (the shared page was
    copied, not mutated)."""
    cfg, params = model
    rng = np.random.default_rng(2)
    pa = rng.integers(1, cfg.vocab_size, size=20).astype(np.int32)
    engine.submit(Request(request_id=20, prompt=pa, max_new_tokens=5))
    ra = engine.run()[0]
    assert ra.output == _reference_decode(cfg, params, pa, 5)
    # diverge at position 19 — inside the third 8-token page
    pc = pa.copy()
    pc[19] = int(pa[19] % (cfg.vocab_size - 1)) + 1
    cow0 = engine.n_cow_copies
    engine.submit(Request(request_id=21, prompt=pc, max_new_tokens=5))
    rc = engine.run()[0]
    assert engine.n_cow_copies > cow0
    assert rc.output == _reference_decode(cfg, params, pc, 5)
    # the original prefix pages were not corrupted by the divergent request
    engine.submit(Request(request_id=22, prompt=pa, max_new_tokens=5))
    assert engine.run()[0].output == ra.output


def test_pool_exhaustion_evicts_and_recomputes_identically(model):
    """A pool too small for both requests: the younger is evicted mid-
    flight, requeued, and recomputed — both token streams stay exact."""
    cfg, params = model
    rng = np.random.default_rng(3)
    p1, p2 = (rng.integers(1, cfg.vocab_size, size=20).astype(np.int32)
              for _ in range(2))
    with PagedEngine(cfg, params, ServeConfig(max_batch=2, max_len=48),
                     paged=PagedConfig(page_size=8, prefill_chunk=8,
                                       n_pages=6, share_prefix=False)) as eng:
        eng.submit(Request(request_id=0, prompt=p1, max_new_tokens=8))
        eng.submit(Request(request_id=1, prompt=p2, max_new_tokens=8))
        done = eng.run()
        assert eng.n_evictions > 0
        assert eng.page_pool.peak_used <= 6
    assert [r.request_id for r in done] == [0, 1]
    assert done[0].output == _reference_decode(cfg, params, p1, 8)
    assert done[1].output == _reference_decode(cfg, params, p2, 8)


def test_chunked_prefill_keeps_decode_flowing(model, engine):
    """While a long prompt prefills chunk by chunk, the active request keeps
    emitting one token per step — decode latency is bounded by the chunk
    size, never by a stranger's prompt length."""
    cfg, params = model
    rng = np.random.default_rng(4)
    pa = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)
    a = Request(request_id=30, prompt=pa, max_new_tokens=12)
    engine.submit(a)
    while not a.output:
        engine.step()
    plong = rng.integers(1, cfg.vocab_size, size=33).astype(np.int32)
    lg = Request(request_id=31, prompt=plong, max_new_tokens=4)
    engine.submit(lg)
    stalls, prefill_steps = 0, 0
    while not lg.output and not a.done:
        before = len(a.output)
        engine.step()
        prefill_steps += 1
        stalls += (len(a.output) == before)
    assert prefill_steps >= 4          # 33 tokens / 8-token chunks
    assert stalls == 0                 # a emitted on every one of those steps
    done = engine.run()
    assert a.output == _reference_decode(cfg, params, pa, 12)
    assert lg.output == _reference_decode(cfg, params, plong, 4)
    assert {r.request_id for r in done} == {30, 31}


# ---------------------------------------------------------------------------
# admission / construction guards
# ---------------------------------------------------------------------------

def test_submit_validation(engine):
    with pytest.raises(ValueError, match="empty prompt"):
        engine.submit(Request(request_id=0, prompt=np.empty(0, np.int32)))
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.submit(Request(request_id=0, prompt=np.ones(4, np.int32),
                              max_new_tokens=0))
    with pytest.raises(ValueError, match="exceeds max_len"):
        engine.submit(Request(request_id=0, prompt=np.ones(40, np.int32),
                              max_new_tokens=40))


def test_rejects_unsupported_archs(model):
    cfg, params = model
    with pytest.raises(ValueError, match="attention-only"):
        PagedEngine(cfg.reduced(frontend="audio"), params,
                    ServeConfig(max_batch=2, max_len=16))
    with pytest.raises(ValueError, match="cannot hold"):
        PagedEngine(cfg, params, ServeConfig(max_batch=2, max_len=48),
                    paged=PagedConfig(page_size=8, n_pages=2))


def test_static_decode_plan_is_default(engine):
    assert engine.decode_host_mode == "static"
    assert engine.n_executors >= 1


@pytest.mark.stress
def test_repeated_eviction_under_sustained_pressure_stays_exact(model):
    """ISSUE 9 satellite: a page pool held at the edge of exhaustion across
    a stream of staggered requests forces eviction + requeue + chunked
    recompute over and over; every stream must stay bit-exact and the pool
    must never exceed its physical page budget."""
    cfg, params = model
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(12, 24))).astype(np.int32)
               for _ in range(6)]
    with PagedEngine(cfg, params, ServeConfig(max_batch=2, max_len=48),
                     paged=PagedConfig(page_size=8, prefill_chunk=8,
                                       n_pages=6, share_prefix=False)) as eng:
        reqs = [Request(request_id=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        # staggered submission keeps admission churning against eviction
        for i, r in enumerate(reqs):
            eng.submit(r)
            if i % 2 == 1:
                for _ in range(3):
                    if eng.has_work:
                        eng.step()
        done = eng.run()
        assert eng.n_evictions >= 2, "pressure never forced repeat evictions"
        assert eng.page_pool.peak_used <= 6
    assert sorted(r.request_id for r in done) == list(range(6))
    for r in sorted(done, key=lambda r: r.request_id):
        assert r.output == _reference_decode(cfg, params, prompts[r.request_id], 6), \
            f"request {r.request_id} diverged after eviction/recompute"
